"""Concurrency auditor for the Truffle data plane.

Two layers:

* **Static** (:mod:`repro.analysis.lockgraph` + :mod:`repro.analysis.rules`):
  stdlib-``ast`` walk over ``src/repro/{core,runtime}`` that infers lock
  identities (``self._lock`` / ``self._cond`` aliases / module-level /
  function-local locks), propagates held-lock sets interprocedurally —
  including through ``EventBus.publish`` → subscriber callbacks and
  buffer/health callback attributes — and evaluates rules R1–R5
  (lock-order cycles, blocking calls under a lock, unlocked shared
  writes, ``_locked``-suffix misuse, silent broad excepts).
  Run it: ``python -m repro.analysis`` (exits nonzero on any violation
  not suppressed by ``analysis/baseline.json``).

* **Dynamic** (:mod:`repro.analysis.lockcheck`): opt-in
  (``TRUFFLE_LOCKCHECK=1``) instrumented-lock wrapper that records
  per-thread acquisition order at runtime under the real test suites,
  reports lock-order inversions and long holds, and dumps a witness
  trace (``TRUFFLE_LOCKCHECK_DUMP=<path>``).
"""
from repro.analysis.lockgraph import Program, analyze_paths  # noqa: F401
from repro.analysis.rules import Violation, evaluate, load_baseline  # noqa: F401
