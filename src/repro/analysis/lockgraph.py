"""Static lock-graph extraction for the Truffle runtime (stdlib ``ast`` only).

The model
---------
A **lock identity** is a string key naming the declaration site, not the
instance: ``"Buffer._lock"`` (every Buffer's ``self._lock``),
``"netsim:module:_GRANTS"`` (a module-level lock), or
``"workflow.WorkflowRunner.run:lock"`` (a function-local lock). Conditions
alias their underlying lock (``threading.Condition(self._lock)`` →
``Buffer._lock``); a bare ``Condition()`` owns its key. Collapsing
instances onto declaration sites is the classic lockdep trade: it can
merge two instances of one class into a false cycle, but it makes the
"global order over declaration sites" discipline checkable at all.

The walk
--------
Every method / module function / nested-and-returned closure is a root,
analyzed with an empty held set; each ``with <lock>:`` extends the held
set for its body, and calls are followed **interprocedurally** carrying
the caller's held set (memoized on ``(callee, held)``). Calls are
resolved through a light type environment: ``self``, annotated params,
dataclass field annotations, ``self.x = ClassName(...)`` assignments in
``__init__``, plus a documented table of repo wiring hints
(:data:`NAME_HINTS` / :data:`RETURN_HINTS`) for attributes the AST alone
can't type. Three special edges make the data plane's real re-entrancy
visible:

* ``bus.publish(topic, …)`` expands to every subscriber registered for
  that topic (constant-topic matching), analyzed with the *caller's*
  held set — the bus delivers callbacks after releasing its own lock,
  so the caller's locks are exactly what subscribers run under.
* callback attributes (``buffer.on_residency = digests.listener(n)``,
  ``health.on_degraded = cluster._on_node_degraded``) are bound by a
  global assignment scan; invoking the attribute expands to the bound
  targets (closure factories are followed into their returned ``def``).
* ``threading.Thread(target=f)`` / ``executor.submit(f, …)`` sever the
  held set: ``f`` runs on another thread, so it is enqueued as a fresh
  root instead of inheriting the spawner's locks.

Facts collected (consumed by :mod:`repro.analysis.rules`): lock
acquisition edges, blocking calls with the held set at the call site,
``self``-attribute writes with the held set, ``_locked``-suffix call
sites, and broad exception handlers.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

# ---------------------------------------------------------------- constants

#: attribute/parameter-name → class hints for receivers the AST can't type.
#: Applied only when the named class was actually parsed, so fixtures and
#: foreign trees are unaffected. This is repo wiring knowledge (Cluster's
#: attribute names), kept here so the analyzer stays annotation-free.
NAME_HINTS: Dict[str, str] = {
    "cluster": "Cluster", "bus": "EventBus", "_bus": "EventBus",
    "buffer": "Buffer", "_buffer": "Buffer", "buf": "Buffer",
    "digests": "DigestRegistry", "registry": "DigestRegistry",
    "relays": "RelayTable", "health": "NodeHealthMonitor",
    "scheduler": "Scheduler", "telemetry": "LinkTelemetry",
    "platform": "Platform", "truffle": "TruffleInstance",
    "watcher": "Watcher", "engine": "DataEngine",
    "prefetcher": "Prefetcher", "network": "NetworkFabric",
    "channel": "Channel", "ch": "Channel",
    "node": "Node", "target": "Node", "src": "Node", "dst": "Node",
    "fleet": "Fleet", "gate": "FleetGate", "pools": "WarmPools",
    "sharing": "CasSharing", "ledger": "TenantLedger",
}

#: (class, method) → class of the return value, for call-chain receivers.
RETURN_HINTS: Dict[Tuple[str, str], str] = {
    ("Cluster", "node"): "Node",
    ("NetworkFabric", "channel"): "Channel",
}

#: ``.attr(...)`` calls that block the calling thread (R2 candidates).
#: ``.wait`` is handled separately (own-condition exemption); ``.join``
#: is guarded against string/path joins; ``.publish`` only fires for
#: EventBus-typed/bus-named receivers (``DigestRegistry.publish`` is a
#: residency update, not a bus publish).
BLOCKING_ATTRS = {"sleep", "sleep_until", "result", "wait_for",
                  "stream", "transfer", "pace"}
#: bare-name calls that block (module-level helpers).
BLOCKING_NAMES = {"join_or_stall"}

#: methods where unlocked self-writes are construction, not sharing.
CONSTRUCTORS = {"__init__", "__post_init__"}

_LOCK_KINDS = {"Lock": "lock", "RLock": "rlock"}
_MAX_DEPTH = 14


# ------------------------------------------------------------------- facts

@dataclass(frozen=True)
class LockDecl:
    key: str
    kind: str           # lock | rlock | cond
    file: str
    line: int


@dataclass(frozen=True)
class AcqEdge:
    """Held ``src`` while acquiring ``dst`` (src None = root acquisition)."""
    src: Optional[str]
    dst: str
    context: str        # qualname of the method containing the acquire
    file: str
    line: int


@dataclass(frozen=True)
class BlockFact:
    """A blocking call made while ``held`` is non-empty."""
    context: str        # method whose body contains the call site
    call: str           # human-readable callee, e.g. "bus.publish"
    held: Tuple[str, ...]
    file: str
    line: int


@dataclass(frozen=True)
class WriteFact:
    cls: str
    method: str
    attr: str
    held: Tuple[str, ...]
    file: str
    line: int


@dataclass(frozen=True)
class LockedCallFact:
    """Call site of a ``*_locked`` method."""
    context: str
    callee: str
    recv_cls: Optional[str]
    held: Tuple[str, ...]
    file: str
    line: int


@dataclass(frozen=True)
class ExceptFact:
    """Broad handler (Exception/BaseException/bare) that swallows silently:
    no raise, no call, no reference to the bound exception name."""
    context: str
    exc: str
    file: str
    line: int


# ------------------------------------------------------------------- model

@dataclass
class ClassModel:
    name: str
    module: str
    file: str
    locks: Dict[str, LockDecl] = field(default_factory=dict)     # attr → decl
    cond_alias: Dict[str, str] = field(default_factory=dict)     # cond → lock attr
    methods: Dict[str, ast.FunctionDef] = field(default_factory=dict)
    attr_types: Dict[str, str] = field(default_factory=dict)     # attr → class
    callback_attrs: Set[str] = field(default_factory=set)

    def lock_keys(self) -> Set[str]:
        return {d.key for d in self.locks.values()}


@dataclass
class FuncEntry:
    qual: str                       # "Class.meth", "mod.fn", "Class.m::cb"
    node: ast.FunctionDef
    module: str
    file: str
    cls: Optional[str]              # class providing ``self`` (closures too)


class Program:
    """Parsed model of the analyzed tree + all facts from the walk."""

    def __init__(self) -> None:
        self.classes: Dict[str, ClassModel] = {}
        self.funcs: Dict[str, FuncEntry] = {}
        self.funcs_by_name: Dict[str, str] = {}       # bare module-fn → qual
        self.module_locks: Dict[Tuple[str, str], LockDecl] = {}
        self.constants: Dict[str, str] = {}           # NAME → str value
        self.subscriptions: List[Tuple[Optional[str], str]] = []
        # (owner class or "*", attr) → bound target quals
        self.bindings: Dict[Tuple[str, str], Set[str]] = {}
        self.decls: Dict[str, LockDecl] = {}          # key → decl
        # facts
        self.acqs: List[AcqEdge] = []
        self.blocks: List[BlockFact] = []
        self.writes: List[WriteFact] = []
        self.locked_calls: List[LockedCallFact] = []
        self.excepts: List[ExceptFact] = []

    # -- helpers ----------------------------------------------------------
    def class_hint(self, name: str) -> Optional[str]:
        c = NAME_HINTS.get(name)
        return c if c in self.classes else None

    def add_decl(self, decl: LockDecl) -> None:
        self.decls.setdefault(decl.key, decl)

    def kind_of(self, key: str) -> str:
        d = self.decls.get(key)
        return d.kind if d else "lock"


# ------------------------------------------------------------ AST helpers

def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    """``a.b.c`` → ["a","b","c"]; None for non-name chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return None


def _annotation_class(ann: Optional[ast.AST]) -> Optional[str]:
    """Extract a candidate class name from an annotation node."""
    if ann is None:
        return None
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        name = ann.value.strip().strip('"')
        return name.split("[")[-1].rstrip("]").split(".")[-1] or None
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Attribute):
        return ann.attr
    if isinstance(ann, ast.Subscript):        # Optional[X] / "Optional[X]"
        return _annotation_class(ann.slice)
    return None


def _is_threading_call(call: ast.Call, names: Set[str]) -> Optional[str]:
    """``threading.Lock()`` / bare ``Lock()`` → matched name, else None."""
    f = call.func
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
            and f.value.id == "threading" and f.attr in names:
        return f.attr
    if isinstance(f, ast.Name) and f.id in names:
        return f.id
    return None


def _returned_funcs(fn: ast.FunctionDef) -> List[ast.FunctionDef]:
    """Nested defs that the function returns (callback factories)."""
    nested = {n.name: n for n in fn.body if isinstance(n, ast.FunctionDef)}
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Name):
            if node.value.id in nested:
                out.append(nested.pop(node.value.id))
    return out


def _lockish_param(name: str) -> bool:
    low = name.lower()
    return "lock" in low or low.endswith(("cond", "_cv", "cv"))


# --------------------------------------------------------------- collection

def _collect_module(prog: Program, module: str, path: str,
                    tree: ast.Module) -> None:
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            _collect_class(prog, module, path, node)
        elif isinstance(node, ast.FunctionDef):
            qual = f"{module}.{node.name}"
            prog.funcs[qual] = FuncEntry(qual, node, module, path, None)
            prog.funcs_by_name.setdefault(node.name, qual)
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if isinstance(node.value, ast.Constant) \
                    and isinstance(node.value.value, str):
                prog.constants[name] = node.value.value
            elif isinstance(node.value, ast.Call):
                kind = _is_threading_call(node.value, set(_LOCK_KINDS))
                if kind:
                    decl = LockDecl(f"{module}:module:{name}",
                                    _LOCK_KINDS[kind], path, node.lineno)
                    prog.module_locks[(module, name)] = decl
                    prog.add_decl(decl)


def _collect_class(prog: Program, module: str, path: str,
                   cls: ast.ClassDef) -> None:
    cm = ClassModel(cls.name, module, path)
    prog.classes[cls.name] = cm
    for node in cls.body:
        if isinstance(node, ast.FunctionDef):
            cm.methods[node.name] = node
            qual = f"{cls.name}.{node.name}"
            prog.funcs[qual] = FuncEntry(qual, node, module, path, cls.name)
            for nested in _returned_funcs(node):
                nq = f"{qual}::{nested.name}"
                prog.funcs[nq] = FuncEntry(nq, nested, module, path, cls.name)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                           ast.Name):
            attr = node.target.id
            # dataclass field: lock via default_factory, type via annotation
            if isinstance(node.value, ast.Call):
                for kw in node.value.keywords:
                    if kw.arg == "default_factory":
                        chain = _attr_chain(kw.value) or []
                        leaf = chain[-1] if chain else ""
                        if leaf in _LOCK_KINDS:
                            decl = LockDecl(f"{cls.name}.{attr}",
                                            _LOCK_KINDS[leaf], path,
                                            node.lineno)
                            cm.locks[attr] = decl
                            prog.add_decl(decl)
            ann = _annotation_class(node.annotation)
            if ann and attr not in cm.locks:
                cm.attr_types[attr] = ann


def _infer_attrs(prog: Program) -> None:
    """Second pass: ``self.x = ...`` in every method → lock decls, condition
    aliases, attribute types, callback attributes."""
    for cm in prog.classes.values():
        for mname, fn in cm.methods.items():
            params = {a.arg: _annotation_class(a.annotation)
                      for a in fn.args.args}
            for node in ast.walk(fn):
                if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                    continue
                tgt = node.targets[0]
                if not (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    continue
                attr, val = tgt.attr, node.value
                if isinstance(val, ast.Call):
                    kind = _is_threading_call(val, set(_LOCK_KINDS))
                    if kind:
                        decl = LockDecl(f"{cm.name}.{attr}",
                                        _LOCK_KINDS[kind], cm.file,
                                        node.lineno)
                        cm.locks.setdefault(attr, decl)
                        prog.add_decl(decl)
                        continue
                    if _is_threading_call(val, {"Condition"}):
                        if val.args and isinstance(val.args[0], ast.Attribute) \
                                and isinstance(val.args[0].value, ast.Name) \
                                and val.args[0].value.id == "self":
                            cm.cond_alias[attr] = val.args[0].attr
                        else:
                            decl = LockDecl(f"{cm.name}.{attr}", "cond",
                                            cm.file, node.lineno)
                            cm.locks.setdefault(attr, decl)
                            prog.add_decl(decl)
                        continue
                    fname = _attr_chain(val.func)
                    if fname and fname[-1] in prog.classes:
                        cm.attr_types.setdefault(attr, fname[-1])
                    continue
                if isinstance(val, ast.Name):
                    pann = params.get(val.id)
                    if (pann in ("Lock", "RLock")
                            or (pann is None and mname in CONSTRUCTORS
                                and _lockish_param(val.id))):
                        kind = "rlock" if pann == "RLock" else "lock"
                        decl = LockDecl(f"{cm.name}.{attr}", kind,
                                        cm.file, node.lineno)
                        cm.locks.setdefault(attr, decl)
                        prog.add_decl(decl)
                    elif pann and pann in prog.classes:
                        cm.attr_types.setdefault(attr, pann)
                    elif prog.class_hint(val.id):
                        cm.attr_types.setdefault(attr, prog.class_hint(val.id))
                elif isinstance(val, ast.Constant) and val.value is None \
                        and mname in CONSTRUCTORS:
                    # ``self.on_residency = None`` style hook slots
                    cm.callback_attrs.add(attr)


def _collect_wiring(prog: Program) -> None:
    """Global scan for bus subscriptions and callback-attribute bindings."""
    for entry in list(prog.funcs.values()):
        cls = entry.cls
        for node in ast.walk(entry.node):
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr == "subscribe" \
                        and len(node.args) >= 2:
                    topic = _const_topic(prog, node.args[0])
                    for q in _callable_targets(prog, node.args[1], cls):
                        prog.subscriptions.append((topic, q))
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Attribute):
                tgt = node.targets[0]
                if isinstance(tgt.value, ast.Name) and tgt.value.id == "self":
                    continue                    # own attr, not a wiring site
                targets = _callable_targets(prog, node.value, cls)
                if targets:
                    owner = _owner_class(prog, tgt.value, cls) or "*"
                    key = (owner, tgt.attr)
                    prog.bindings.setdefault(key, set()).update(targets)


def _const_topic(prog: Program, node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Name):
        return prog.constants.get(node.id)
    return None


def _owner_class(prog: Program, recv: ast.AST, cls: Optional[str]
                 ) -> Optional[str]:
    chain = _attr_chain(recv)
    if not chain:
        return None
    if chain == ["self"] and cls:
        return cls
    cur: Optional[str] = None
    if chain[0] == "self" and cls:
        cur = cls
        chain = chain[1:]
    for part in chain:
        nxt = None
        if cur and cur in prog.classes:
            nxt = prog.classes[cur].attr_types.get(part)
        if nxt is None:
            nxt = prog.class_hint(part)
        cur = nxt
        if cur is None:
            return None
    return cur


def _callable_targets(prog: Program, val: ast.AST, cls: Optional[str]
                      ) -> List[str]:
    """Resolve an expression used as a callable to method quals."""
    if isinstance(val, ast.Attribute):
        owner = _owner_class(prog, val.value, cls)
        if owner and val.attr in prog.classes.get(owner, ClassModel(
                "", "", "")).methods:
            return [f"{owner}.{val.attr}"]
        return []
    if isinstance(val, ast.Name):
        q = prog.funcs_by_name.get(val.id)
        return [q] if q else []
    if isinstance(val, ast.Call):
        # closure factory: cluster wires buffer.on_residency =
        # digests.listener(name) — follow into the returned nested def
        for q in _callable_targets(prog, val.func, cls):
            nested = [k for k in prog.funcs if k.startswith(q + "::")]
            if nested:
                return nested
    return []


# ------------------------------------------------------------------ walker

class _Env:
    __slots__ = ("cls", "qual", "locals")

    def __init__(self, cls: Optional[str], qual: str,
                 locals_: Optional[dict] = None):
        self.cls = cls
        self.qual = qual
        self.locals: Dict[str, tuple] = locals_ or {}


class Walker:
    def __init__(self, prog: Program):
        self.p = prog
        self._memo: Set[Tuple[str, FrozenSet[str]]] = set()
        self._queue: List[Tuple[str, FrozenSet[str]]] = []

    # -- entry ------------------------------------------------------------
    def run(self) -> None:
        for qual, entry in self.p.funcs.items():
            # a ``*_locked`` method's contract is "caller holds the owning
            # lock" (R4 checks the call sites) — analyze its body under
            # that contract instead of flagging it against itself
            held = frozenset()
            name = qual.rsplit("::", 1)[-1].rsplit(".", 1)[-1]
            if name.endswith("_locked") and entry.cls in self.p.classes:
                key = self._primary_lock(entry.cls)
                if key:
                    held = frozenset({key})
            self._enqueue(qual, held)
        while self._queue:
            qual, held = self._queue.pop()
            entry = self.p.funcs.get(qual)
            if entry is None:
                continue
            env = self._env_for(entry)
            self._stmts(entry.node.body, env, held, entry, 0)

    def _primary_lock(self, cls: str) -> Optional[str]:
        cm = self.p.classes[cls]
        for attr in ("_lock", "lock"):
            if attr in cm.locks:
                return cm.locks[attr].key
        for decl in cm.locks.values():
            if decl.kind != "cond":
                return decl.key
        return next(iter(cm.lock_keys()), None)

    def _enqueue(self, qual: str, held: FrozenSet[str]) -> None:
        key = (qual, held)
        if key not in self._memo:
            self._memo.add(key)
            self._queue.append(key)

    def _env_for(self, entry: FuncEntry) -> _Env:
        env = _Env(entry.cls, entry.qual)
        if entry.cls:
            # covers closures too, where ``self`` is a free variable of
            # the enclosing method rather than a parameter
            env.locals["self"] = ("type", entry.cls)
        args = entry.node.args
        params = list(args.args) + list(args.kwonlyargs)
        for i, a in enumerate(params):
            if i == 0 and a.arg == "self" and entry.cls:
                env.locals["self"] = ("type", entry.cls)
                continue
            ann = _annotation_class(a.annotation)
            if ann and ann in self.p.classes:
                env.locals[a.arg] = ("type", ann)
            elif self.p.class_hint(a.arg):
                env.locals[a.arg] = ("type", self.p.class_hint(a.arg))
            elif _lockish_param(a.arg):
                key = f"{entry.qual}:param:{a.arg}"
                self.p.add_decl(LockDecl(key, "lock", entry.file,
                                         entry.node.lineno))
                env.locals[a.arg] = ("lock", key)
        return env

    # -- statements -------------------------------------------------------
    def _stmts(self, body, env, held, entry, depth) -> None:
        for st in body:
            self._stmt(st, env, held, entry, depth)

    def _stmt(self, st, env, held, entry, depth) -> None:
        p = self.p
        if isinstance(st, (ast.With, ast.AsyncWith)):
            new_held = held
            for item in st.items:
                self._expr(item.context_expr, env, new_held, entry, depth)
                key = self._lock_of(item.context_expr, env)
                if key is not None:
                    for h in sorted(new_held) or [None]:
                        p.acqs.append(AcqEdge(h, key, env.qual, entry.file,
                                              st.lineno))
                    new_held = new_held | {key}
            self._stmts(st.body, env, new_held, entry, depth)
        elif isinstance(st, ast.Assign):
            self._expr(st.value, env, held, entry, depth)
            for tgt in st.targets:
                self._write_target(tgt, env, held, entry)
            if len(st.targets) == 1 and isinstance(st.targets[0], ast.Name):
                b = self._binding(st.value, env, entry, st.targets[0].id)
                if b is not None:
                    env.locals[st.targets[0].id] = b
                else:
                    env.locals.pop(st.targets[0].id, None)
        elif isinstance(st, ast.AugAssign):
            self._expr(st.value, env, held, entry, depth)
            self._write_target(st.target, env, held, entry)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self._expr(st.value, env, held, entry, depth)
                self._write_target(st.target, env, held, entry)
        elif isinstance(st, ast.Try):
            self._stmts(st.body, env, held, entry, depth)
            for h in st.handlers:
                self._except(h, env, entry)
                self._stmts(h.body, env, held, entry, depth)
            self._stmts(st.orelse, env, held, entry, depth)
            self._stmts(st.finalbody, env, held, entry, depth)
        elif isinstance(st, ast.If):
            self._expr(st.test, env, held, entry, depth)
            self._stmts(st.body, env, held, entry, depth)
            self._stmts(st.orelse, env, held, entry, depth)
        elif isinstance(st, ast.While):
            self._expr(st.test, env, held, entry, depth)
            self._stmts(st.body, env, held, entry, depth)
            self._stmts(st.orelse, env, held, entry, depth)
        elif isinstance(st, ast.For):
            self._expr(st.iter, env, held, entry, depth)
            self._stmts(st.body, env, held, entry, depth)
            self._stmts(st.orelse, env, held, entry, depth)
        elif isinstance(st, ast.FunctionDef):
            nq = f"{env.qual}::{st.name}"
            if nq not in self.p.funcs:
                self.p.funcs[nq] = FuncEntry(nq, st, entry.module,
                                             entry.file, env.cls)
            env.locals[st.name] = ("method", nq)
            self._enqueue(nq, frozenset())
        elif isinstance(st, (ast.Return, ast.Expr, ast.Raise, ast.Assert,
                             ast.Delete)):
            for child in ast.iter_child_nodes(st):
                self._expr(child, env, held, entry, depth)
        else:
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    self._expr(child, env, held, entry, depth)

    def _write_target(self, tgt, env, held, entry) -> None:
        """Record self-attribute writes (plain and through a subscript)."""
        if isinstance(tgt, ast.Tuple):
            for el in tgt.elts:
                self._write_target(el, env, held, entry)
            return
        node = tgt
        if isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Attribute) and isinstance(node.value,
                                                          ast.Name) \
                and node.value.id == "self" and env.cls:
            cm = self.p.classes.get(env.cls)
            if cm is None or node.attr in cm.locks \
                    or node.attr in cm.cond_alias:
                return
            method = env.qual.split(".", 1)[-1]
            self.p.writes.append(WriteFact(env.cls, method, node.attr,
                                           tuple(sorted(held)), entry.file,
                                           tgt.lineno))

    def _except(self, h: ast.ExceptHandler, env, entry) -> None:
        broad = h.type is None or (
            isinstance(h.type, ast.Name)
            and h.type.id in ("Exception", "BaseException"))
        if not broad:
            return
        names = set()
        has_stmt = False
        for node in h.body:
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Raise, ast.Call)):
                    has_stmt = True
                if isinstance(sub, ast.Name):
                    names.add(sub.id)
        if has_stmt or (h.name and h.name in names):
            return
        exc = h.type.id if isinstance(h.type, ast.Name) else "bare"
        self.p.excepts.append(ExceptFact(env.qual, exc, entry.file, h.lineno))

    # -- expressions ------------------------------------------------------
    def _expr(self, node, env, held, entry, depth) -> None:
        if node is None:
            return
        for call in [n for n in ast.walk(node) if isinstance(n, ast.Call)]:
            self._call(call, env, held, entry, depth)

    def _call(self, call: ast.Call, env, held, entry, depth) -> None:
        p = self.p
        func = call.func
        name = None
        if isinstance(func, ast.Attribute):
            name = func.attr
        elif isinstance(func, ast.Name):
            name = func.id

        # thread spawn severs the held set: target runs elsewhere
        if self._thread_spawn(call, env, entry):
            return

        # blocking classification (R2 facts)
        if held and name:
            self._blocking(call, name, env, held, entry)

        # _locked-suffix discipline (R4 facts)
        if name and name.endswith("_locked") and isinstance(func,
                                                            ast.Attribute):
            recv = self._type_of(func.value, env)
            p.locked_calls.append(LockedCallFact(
                env.qual, name, recv, tuple(sorted(held)), entry.file,
                call.lineno))

        # interprocedural recursion
        for callee in self._callees(call, env):
            if depth < _MAX_DEPTH:
                self._inline(callee, held, depth + 1)

        # bus publish: expand subscribers with the CALLER's held set
        if name == "publish" and isinstance(func, ast.Attribute) \
                and self._is_bus(func.value, env):
            topic = _const_topic(p, call.args[0]) if call.args else None
            for sub_topic, sub_qual in p.subscriptions:
                if topic is None or sub_topic is None or topic == sub_topic:
                    if depth < _MAX_DEPTH:
                        self._inline(sub_qual, held, depth + 1)

        # callback attribute invocation: self.on_residency(...) / cb(...)
        for target in self._callback_targets(func, env):
            if depth < _MAX_DEPTH:
                self._inline(target, held, depth + 1)

    def _inline(self, qual: str, held: FrozenSet[str], depth: int) -> None:
        entry = self.p.funcs.get(qual)
        if entry is None:
            return
        key = (qual, held)
        if key in self._memo:
            return
        self._memo.add(key)
        env = self._env_for(entry)
        self._stmts(entry.node.body, env, held, entry, depth)

    def _thread_spawn(self, call: ast.Call, env, entry) -> bool:
        func = call.func
        chain = _attr_chain(func) or []
        target = None
        if chain and chain[-1] == "Thread" and (
                len(chain) == 1 or chain[0] == "threading"):
            for kw in call.keywords:
                if kw.arg == "target":
                    target = kw.value
        elif chain and chain[-1] == "submit" and call.args:
            target = call.args[0]
        if target is None:
            return False
        for q in _callable_targets(self.p, target, env.cls):
            self._enqueue(q, frozenset())
        if isinstance(target, ast.Name):
            b = env.locals.get(target.id)
            if b and b[0] == "method":
                self._enqueue(b[1], frozenset())
        return chain[-1] == "Thread"

    def _blocking(self, call, name, env, held, entry) -> None:
        func = call.func
        descr = None
        if isinstance(func, ast.Attribute):
            if name in BLOCKING_ATTRS:
                recv = _attr_chain(func.value)
                descr = f"{recv[-1] if recv else '?'}.{name}"
            elif name == "wait":
                key = self._lock_of(func.value, env)
                if key is not None and held == frozenset({key}):
                    return      # waiting on the ONLY held lock's condition
                recv = _attr_chain(func.value)
                descr = f"{recv[-1] if recv else '?'}.wait"
            elif name == "join":
                if isinstance(func.value, (ast.Constant, ast.JoinedStr,
                                           ast.BinOp)):
                    return      # str/bytes join
                recv = _attr_chain(func.value)
                if recv and recv[0] in ("os", "posixpath", "ntpath"):
                    return
                descr = f"{recv[-1] if recv else '?'}.join"
            elif name == "publish" and self._is_bus(func.value, env):
                descr = "bus.publish"
        elif isinstance(func, ast.Name) and name in BLOCKING_NAMES:
            descr = name
        if descr is not None:
            self.p.blocks.append(BlockFact(env.qual, descr,
                                           tuple(sorted(held)),
                                           entry.file, call.lineno))

    def _is_bus(self, recv: ast.AST, env) -> bool:
        t = self._type_of(recv, env)
        if t == "EventBus":
            return True
        chain = _attr_chain(recv)
        return bool(chain) and chain[-1] in ("bus", "_bus")

    # -- resolution -------------------------------------------------------
    def _type_of(self, node: ast.AST, env) -> Optional[str]:
        p = self.p
        if isinstance(node, ast.Name):
            b = env.locals.get(node.id)
            if b and b[0] == "type":
                return b[1]
            return p.class_hint(node.id)
        if isinstance(node, ast.Attribute):
            base = self._type_of(node.value, env)
            if base and base in p.classes:
                t = p.classes[base].attr_types.get(node.attr)
                if t:
                    return t
            return p.class_hint(node.attr)
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in p.classes:
                return f.id
            if isinstance(f, ast.Attribute):
                base = self._type_of(f.value, env)
                if base:
                    hint = RETURN_HINTS.get((base, f.attr))
                    if hint:
                        return hint
        return None

    def _lock_of(self, node: ast.AST, env) -> Optional[str]:
        """Resolve an expression to a lock key (conditions → underlying)."""
        p = self.p
        if isinstance(node, ast.Name):
            b = env.locals.get(node.id)
            if b and b[0] in ("lock", "cond"):
                return b[1]
            entry = self.p.funcs.get(env.qual)
            mod = entry.module if entry else ""
            decl = p.module_locks.get((mod, node.id))
            return decl.key if decl else None
        if isinstance(node, ast.Attribute):
            base = self._type_of(node.value, env)
            if base and base in p.classes:
                cm = p.classes[base]
                attr = node.attr
                if attr in cm.cond_alias:
                    attr = cm.cond_alias[attr]
                if attr in cm.locks:
                    return cm.locks[attr].key
        return None

    def _binding(self, val: ast.AST, env, entry,
                 varname: Optional[str] = None) -> Optional[tuple]:
        p = self.p
        if isinstance(val, ast.Call):
            kind = _is_threading_call(val, set(_LOCK_KINDS))
            if kind:
                key = f"{env.qual}:{varname or 'local'}"
                p.add_decl(LockDecl(key, _LOCK_KINDS[kind], entry.file,
                                    val.lineno))
                return ("lock", key)
            if _is_threading_call(val, {"Condition"}):
                if val.args:
                    under = self._lock_of(val.args[0], env)
                    if under:
                        return ("cond", under)
                key = f"{env.qual}:{varname or 'localcond'}"
                p.add_decl(LockDecl(key, "cond", entry.file, val.lineno))
                return ("cond", key)
            t = self._type_of(val, env)
            if t:
                return ("type", t)
            targets = _callable_targets(p, val, env.cls)
            if targets:
                return ("method", targets[0])
            return None
        key = self._lock_of(val, env)
        if key:
            return ("lock", key)
        if isinstance(val, ast.Attribute) and isinstance(val.value, ast.Name)\
                and val.value.id == "self" and env.cls:
            # callback-attr alias: cb = self.on_residency
            if (env.cls, val.attr) in p.bindings \
                    or ("*", val.attr) in p.bindings:
                return ("callback", env.cls, val.attr)
            targets = _callable_targets(p, val, env.cls)
            if targets:
                return ("method", targets[0])
        t = self._type_of(val, env)
        if t:
            return ("type", t)
        return None

    def _callees(self, call: ast.Call, env) -> List[str]:
        p = self.p
        func = call.func
        out: List[str] = []
        if isinstance(func, ast.Name):
            b = env.locals.get(func.id)
            if b and b[0] == "method":
                out.append(b[1])
            elif func.id in p.classes:
                init = f"{func.id}.__init__"
                if init in p.funcs:
                    out.append(init)
            elif func.id in p.funcs_by_name:
                out.append(p.funcs_by_name[func.id])
        elif isinstance(func, ast.Attribute):
            recv = self._type_of(func.value, env)
            if recv and recv in p.classes \
                    and func.attr in p.classes[recv].methods:
                out.append(f"{recv}.{func.attr}")
        return out

    def _callback_targets(self, func: ast.AST, env) -> List[str]:
        p = self.p
        owner = attr = None
        if isinstance(func, ast.Attribute):
            owner = self._type_of(func.value, env)
            attr = func.attr
        elif isinstance(func, ast.Name):
            b = env.locals.get(func.id)
            if b and b[0] == "callback":
                owner, attr = b[1], b[2]
        if attr is None:
            return []
        out: Set[str] = set()
        if owner:
            out |= p.bindings.get((owner, attr), set())
        out |= p.bindings.get(("*", attr), set())
        return sorted(out)


# --------------------------------------------------------------- top level

def analyze_paths(paths: List[str]) -> Program:
    """Parse every ``.py`` under ``paths`` and run the full walk."""
    prog = Program()
    files: List[Tuple[str, str]] = []
    seen: Set[str] = set()     # overlapping roots must not double-collect

    def _add(mod: str, path: str) -> None:
        real = os.path.realpath(path)
        if real not in seen:
            seen.add(real)
            files.append((mod, path))

    for root in paths:
        if os.path.isfile(root):
            _add(os.path.splitext(os.path.basename(root))[0], root)
            continue
        for dirpath, _dirs, names in os.walk(root):
            for fn in sorted(names):
                if fn.endswith(".py"):
                    mod = os.path.splitext(fn)[0]
                    _add(mod, os.path.join(dirpath, fn))
    trees = []
    for mod, path in files:
        with open(path, "r", encoding="utf-8") as fh:
            tree = ast.parse(fh.read(), filename=path)
        trees.append((mod, path, tree))
        _collect_module(prog, mod, path, tree)
    _infer_attrs(prog)
    _collect_wiring(prog)
    Walker(prog).run()
    return prog
