"""Rule evaluation over the extracted lock graph + the suppression baseline.

Rules
-----
R1  lock-order cycles: two lock identities acquired in both orders on some
    pair of paths (plus self-acquisition of a non-reentrant lock). Each
    strongly connected component of the acquisition graph is one finding.
R2  blocking calls under a lock: ``Condition.wait`` (unless waiting on the
    only held lock's own condition), ``Channel.stream/transfer``,
    ``clock/time.sleep``, ``Future.result``, ``wait_for``, thread joins,
    and ``bus.publish`` reached — possibly interprocedurally — while any
    lock is held.
R3  unlocked shared writes: in a class that owns a lock, a ``self``
    attribute that IS written under the class lock somewhere (i.e. it is
    lock-guarded by convention) written on another path with no class
    lock held. Constructors are exempt.
R4  ``*_locked``-suffix methods (the repo's "caller must hold the lock"
    convention) called without a lock of the receiver's class held.
R5  silent broad excepts: ``except Exception/BaseException:`` (or bare)
    whose body neither raises, calls anything (logging/accounting), nor
    references the caught exception — errors vanish without a trace.

Fingerprints (``Violation.ident``) are built from qualnames + lock keys,
never line numbers, so the committed baseline survives unrelated edits.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.lockgraph import Program

RULE_TITLES = {
    "R1": "lock-order cycle",
    "R2": "blocking call while holding a lock",
    "R3": "unlocked write to a lock-guarded attribute",
    "R4": "_locked method called without the owning lock",
    "R5": "silent broad except",
}


@dataclass
class Violation:
    rule: str
    ident: str          # stable fingerprint (baseline key; no line numbers)
    message: str
    file: str
    line: int
    held: Tuple[str, ...] = ()

    def format(self) -> str:
        where = f"{self.file}:{self.line}"
        return f"[{self.rule}] {where}: {self.message}"


# ----------------------------------------------------------------- R1

def _cycles(prog: Program) -> List[Violation]:
    graph: Dict[str, Set[str]] = {}
    witness: Dict[Tuple[str, str], Tuple[str, str, int]] = {}
    for a in prog.acqs:
        if a.src is None:
            continue
        if a.src == a.dst:
            # re-acquiring a held lock: fine for an RLock, deadlock else
            if prog.kind_of(a.dst) == "rlock":
                continue
        graph.setdefault(a.src, set()).add(a.dst)
        witness.setdefault((a.src, a.dst), (a.context, a.file, a.line))

    out: List[Violation] = []
    seen_idents: Set[str] = set()
    # self-loops first (non-reentrant re-acquisition)
    for src, dsts in graph.items():
        if src in dsts:
            ctx, f, ln = witness[(src, src)]
            ident = f"R1|self|{src}"
            out.append(Violation("R1", ident,
                                 f"non-reentrant lock {src} re-acquired "
                                 f"while held (in {ctx})", f, ln))
            seen_idents.add(ident)
    # SCCs (iterative Tarjan) over the rest
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on: Set[str] = set()
    stack: List[str] = []
    counter = [0]
    sccs: List[List[str]] = []

    def strongconnect(v: str) -> None:
        work = [(v, iter(sorted(graph.get(v, ()))))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on.add(w)
                    work.append((w, iter(sorted(graph.get(w, ())))))
                    advanced = True
                    break
                if w in on:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                comp = []
                while True:
                    w = stack.pop()
                    on.discard(w)
                    comp.append(w)
                    if w == node:
                        break
                if len(comp) > 1:
                    sccs.append(sorted(comp))

    for v in sorted(graph):
        if v not in index:
            strongconnect(v)
    for comp in sccs:
        ident = "R1|cycle|" + "+".join(comp)
        if ident in seen_idents:
            continue
        edges = [(s, d) for (s, d) in witness
                 if s in comp and d in comp and s != d]
        ctx, f, ln = witness[edges[0]] if edges else ("?", "?", 0)
        detail = ", ".join(
            f"{s}->{d} (in {witness[(s, d)][0]})" for s, d in sorted(edges))
        out.append(Violation("R1", ident,
                             f"lock-order cycle over {{{', '.join(comp)}}}: "
                             f"{detail}", f, ln))
    return out


# ----------------------------------------------------------------- R2-R5

def _blocking(prog: Program) -> List[Violation]:
    out = []
    for b in prog.blocks:
        ident = f"R2|{b.context}|{b.call}|{'+'.join(b.held)}"
        out.append(Violation(
            "R2", ident,
            f"{b.context} calls blocking {b.call}() while holding "
            f"{', '.join(b.held)}", b.file, b.line, b.held))
    return out


def _unlocked_writes(prog: Program) -> List[Violation]:
    out = []
    guarded: Dict[Tuple[str, str], bool] = {}
    for w in prog.writes:
        cm = prog.classes.get(w.cls)
        if cm is None or not cm.locks:
            continue
        own = cm.lock_keys()
        if own & set(w.held):
            guarded[(w.cls, w.attr)] = True
    for w in prog.writes:
        cm = prog.classes.get(w.cls)
        if cm is None or not cm.locks:
            continue
        if w.method in ("__init__", "__post_init__"):
            continue
        if not guarded.get((w.cls, w.attr)):
            continue            # never lock-guarded: a config/hook slot
        if cm.lock_keys() & set(w.held):
            continue
        ident = f"R3|{w.cls}.{w.method}|{w.attr}"
        out.append(Violation(
            "R3", ident,
            f"{w.cls}.{w.method} writes self.{w.attr} (elsewhere guarded by "
            f"{'/'.join(sorted(cm.lock_keys()))}) without the lock",
            w.file, w.line, w.held))
    return out


def _locked_suffix(prog: Program) -> List[Violation]:
    out = []
    for c in prog.locked_calls:
        if c.recv_cls and c.recv_cls in prog.classes:
            own = prog.classes[c.recv_cls].lock_keys()
            ok = bool(own & set(c.held)) if own else bool(c.held)
        else:
            ok = bool(c.held)
        if ok:
            continue
        ident = f"R4|{c.context}|{c.callee}"
        out.append(Violation(
            "R4", ident,
            f"{c.context} calls {c.callee}() without holding "
            f"{(c.recv_cls or 'the owner') + chr(39) + 's'} lock "
            f"(held: {', '.join(c.held) or 'nothing'})",
            c.file, c.line, c.held))
    return out


def _silent_excepts(prog: Program) -> List[Violation]:
    out = []
    counts: Dict[str, int] = {}
    for e in prog.excepts:
        n = counts.get(e.context, 0)
        counts[e.context] = n + 1
        suffix = f"#{n}" if n else ""
        ident = f"R5|{e.context}|{e.exc}{suffix}"
        out.append(Violation(
            "R5", ident,
            f"{e.context}: `except {e.exc}` swallows the error with no "
            f"raise/log/record", e.file, e.line))
    return out


# ------------------------------------------------------------- evaluation

def evaluate(prog: Program) -> List[Violation]:
    out: List[Violation] = []
    out += _cycles(prog)
    out += _blocking(prog)
    out += _unlocked_writes(prog)
    out += _locked_suffix(prog)
    out += _silent_excepts(prog)
    # one finding per fingerprint (interprocedural walks can reach the
    # same site through several contexts)
    uniq: Dict[str, Violation] = {}
    for v in out:
        uniq.setdefault(v.ident, v)
    return sorted(uniq.values(), key=lambda v: (v.rule, v.ident))


# --------------------------------------------------------------- baseline

def load_baseline(path: str) -> Dict[str, str]:
    """``{ident: rationale}`` from a baseline file (missing → empty)."""
    try:
        with open(path, "r", encoding="utf-8") as fh:
            data = json.load(fh)
    except FileNotFoundError:
        return {}
    return {s["ident"]: s.get("rationale", "")
            for s in data.get("suppressions", [])}


def save_baseline(path: str, violations: List[Violation],
                  existing: Optional[Dict[str, str]] = None) -> None:
    """Write the current findings as the baseline, keeping rationales
    already recorded for surviving idents."""
    existing = existing or {}
    sup = [{"ident": v.ident,
            "rule": v.rule,
            "rationale": existing.get(v.ident,
                                      "TODO: justify or fix"),
            }
           for v in violations]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"version": 1, "suppressions": sup}, fh, indent=2)
        fh.write("\n")


def split_baselined(violations: List[Violation], baseline: Dict[str, str]
                    ) -> Tuple[List[Violation], List[Violation]]:
    """(new, suppressed) partition of ``violations`` against the baseline."""
    new, old = [], []
    for v in violations:
        (old if v.ident in baseline else new).append(v)
    return new, old
