"""``python -m repro.analysis`` — run the static concurrency auditor.

Exits nonzero when any violation is NOT covered by the committed
suppression baseline (``src/repro/analysis/baseline.json``), so CI can
gate on it. Typical runs::

    python -m repro.analysis                      # audit core/ + runtime/
    python -m repro.analysis path/to/tree         # audit another tree
    python -m repro.analysis --json               # machine-readable report
    python -m repro.analysis --write-baseline     # accept current findings

Amending the baseline: run ``--write-baseline``, then edit the generated
entries' ``rationale`` fields — a suppression without a real rationale
should not survive review.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.lockgraph import analyze_paths
from repro.analysis.rules import (RULE_TITLES, evaluate, load_baseline,
                                  save_baseline, split_baselined)

_PKG = os.path.dirname(os.path.abspath(__file__))        # src/repro/analysis
_REPRO = os.path.dirname(_PKG)                           # src/repro
DEFAULT_PATHS = [os.path.join(_REPRO, "core"),
                 os.path.join(_REPRO, "runtime"),
                 # explicit: the fleet subpackage stays audited even if the
                 # runtime root is ever narrowed (analyze_paths dedups files
                 # reached through both roots)
                 os.path.join(_REPRO, "runtime", "fleet")]
DEFAULT_BASELINE = os.path.join(_PKG, "baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static lock-discipline auditor for the Truffle "
                    "data plane (rules R1-R5).")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to audit "
                         "(default: src/repro/{core,runtime})")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="suppression baseline JSON (default: %(default)s)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignore the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept current findings into the baseline "
                         "(existing rationales are kept)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the report as JSON")
    ap.add_argument("--graph", action="store_true",
                    help="also print the lock acquisition graph")
    args = ap.parse_args(argv)

    paths = args.paths or DEFAULT_PATHS
    prog = analyze_paths(paths)
    violations = evaluate(prog)
    baseline = {} if args.no_baseline else load_baseline(args.baseline)

    if args.write_baseline:
        save_baseline(args.baseline, violations, baseline)
        print(f"baseline: wrote {len(violations)} suppression(s) to "
              f"{args.baseline}")
        return 0

    new, suppressed = split_baselined(violations, baseline)

    if args.as_json:
        print(json.dumps({
            "paths": paths,
            "locks": sorted(prog.decls),
            "new": [vars(v) for v in new],
            "suppressed": [vars(v) for v in suppressed],
        }, indent=2, default=list))
        return 1 if new else 0

    print(f"concurrency audit: {len(prog.decls)} lock identities, "
          f"{len(prog.acqs)} acquisition facts, "
          f"{len(prog.funcs)} functions walked")
    if args.graph:
        edges = sorted({(a.src, a.dst) for a in prog.acqs
                        if a.src is not None})
        for src, dst in edges:
            print(f"  {src} -> {dst}")
    for v in suppressed:
        print(f"  baselined {v.format()}")
        print(f"            rationale: {baseline.get(v.ident, '')}")
    if not new:
        print("OK: no non-baselined violations "
              f"({len(suppressed)} baselined)")
        return 0
    print(f"FAIL: {len(new)} non-baselined violation(s):")
    for v in new:
        print(f"  {v.format()}")
        print(f"    rule: {RULE_TITLES[v.rule]}   ident: {v.ident}")
    print("fix the finding, or (with a written rationale) accept it via "
          "--write-baseline and edit baseline.json")
    return 1


if __name__ == "__main__":
    sys.exit(main())
