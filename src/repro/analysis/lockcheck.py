"""Dynamic lock-discipline checker (the runtime half of the auditor).

Opt-in via ``TRUFFLE_LOCKCHECK=1``: :func:`install` replaces
``threading.Lock`` / ``threading.RLock`` with thin instrumented wrappers
that record, per thread, the order in which lock *sites* are acquired.
A lock site is the source location that created the lock
(``buffer.py:41``), so every ``Buffer`` instance maps to one node in the
order graph — exactly the identity the static layer reasons about.

What it detects:

* **Order inversions** — site A acquired while holding B somewhere, and
  B acquired while holding A somewhere else.  Each direction keeps the
  stack of the acquisition that created the edge, so a report is a
  ready-made deadlock witness even if the schedules never actually
  interleaved into a deadlock during the run.
* **Long holds** — a lock held longer than ``TRUFFLE_LOCKCHECK_HOLD_S``
  wall seconds (default 5.0).  Reported as warnings, not failures: the
  suites run simulated sleeps that legitimately stretch wall time.

The checker never blocks the locks it watches: its own bookkeeping is
guarded by a raw ``_thread`` lock that no wrapper ever wraps, and the
per-thread held stack lives in a ``threading.local``.

Wiring: ``tests/conftest.py`` calls :func:`install` when
``TRUFFLE_LOCKCHECK=1`` and fails the session from ``pytest_sessionfinish``
if :func:`inversions` is non-empty.  ``TRUFFLE_LOCKCHECK_DUMP=<path>``
writes the full edge set + witnesses as JSON at interpreter exit.
"""
from __future__ import annotations

import atexit
import json
import os
import sys
import threading
import time
import traceback
import _thread
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

_RealLock = threading.Lock          # originals, captured at import time
_RealRLock = threading.RLock        # (nothing has patched threading yet)
_installed = False

_registry_guard = _thread.allocate_lock()   # raw: never instrumented
_edges: Dict[Tuple[str, str], dict] = {}    # (held_site, acq_site) -> witness
_long_holds: List[dict] = []
_tls = threading.local()

HOLD_S = float(os.environ.get("TRUFFLE_LOCKCHECK_HOLD_S", "5.0"))
_MAX_LONG_HOLDS = 50


def _site() -> str:
    """file:line of the frame that created the lock, skipping infra frames."""
    f = sys._getframe(2)
    while f is not None:
        fn = f.f_code.co_filename
        if not (fn.endswith("threading.py") or fn.endswith("dataclasses.py")
                or "lockcheck" in fn):
            return "%s:%d" % (os.path.basename(fn), f.f_lineno)
        f = f.f_back
    return "<unknown>"


def _held_stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _note_acquire(site: str, t_acq: float) -> None:
    stack = _held_stack()
    # Re-entrant depth on the SAME site (RLock) adds no ordering info.
    fresh = all(s != site for s, _ in stack)
    if fresh:
        for held_site, _ in stack:
            if held_site == site:
                continue
            key = (held_site, site)
            if key not in _edges:
                wit = {
                    "held": held_site, "acquired": site,
                    "thread": threading.current_thread().name,
                    "stack": "".join(traceback.format_stack(limit=12)[:-2]),
                }
                with _registry_guard:
                    _edges.setdefault(key, wit)
    stack.append((site, t_acq))


def _note_release(site: str) -> None:
    stack = _held_stack()
    # release() may come from a different nesting than acquire (Condition
    # juggling), so pop the LAST matching entry rather than assuming LIFO.
    for i in range(len(stack) - 1, -1, -1):
        if stack[i][0] == site:
            t_acq = stack[i][1]
            del stack[i]
            held = time.monotonic() - t_acq
            if held > HOLD_S:
                with _registry_guard:
                    if len(_long_holds) < _MAX_LONG_HOLDS:
                        _long_holds.append({
                            "site": site, "held_s": round(held, 3),
                            "thread": threading.current_thread().name,
                        })
            return


class _CheckedLock:
    """Instrumented stand-in for threading.Lock."""

    _reentrant = False

    def __init__(self):
        self._inner = _RealLock()
        self._lc_site = _site()

    def acquire(self, blocking=True, timeout=-1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            _note_acquire(self._lc_site, time.monotonic())
        return got

    def release(self):
        _note_release(self._lc_site)
        self._inner.release()

    def locked(self):
        return self._inner.locked()

    def _at_fork_reinit(self):
        # stdlib registers this via os.register_at_fork (futures, logging)
        self._inner._at_fork_reinit()
        _tls.__dict__.pop("stack", None)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()

    def __repr__(self):
        return "<CheckedLock %s %r>" % (self._lc_site, self._inner)


class _CheckedRLock(_CheckedLock):
    """Instrumented stand-in for threading.RLock.

    Implements the private Condition protocol (`_release_save` /
    `_acquire_restore` / `_is_owned`) by delegating to the real RLock so
    ``threading.Condition(rlock)`` keeps working; the save/restore pair
    updates our held stack like a full release/reacquire.
    """

    _reentrant = True

    def __init__(self):
        self._inner = _RealRLock()
        self._lc_site = _site()

    def _release_save(self):
        _note_release(self._lc_site)
        return self._inner._release_save()

    def _acquire_restore(self, state):
        self._inner._acquire_restore(state)
        _note_acquire(self._lc_site, time.monotonic())

    def _is_owned(self):
        return self._inner._is_owned()


def install() -> None:
    """Swap threading.Lock/RLock for the checked wrappers (idempotent).

    Locks created BEFORE install() stay raw — call it as early as
    possible (conftest does, before any repro import).
    """
    global _installed
    if _installed:
        return
    threading.Lock = _CheckedLock          # type: ignore[misc]
    threading.RLock = _CheckedRLock        # type: ignore[misc]
    _installed = True
    dump = os.environ.get("TRUFFLE_LOCKCHECK_DUMP")
    if dump:
        atexit.register(lambda: dump_report(dump))


def uninstall() -> None:
    global _installed
    if _installed:
        threading.Lock = _RealLock         # type: ignore[misc]
        threading.RLock = _RealRLock       # type: ignore[misc]
        _installed = False


def reset() -> None:
    """Drop all recorded edges/holds (tests use this between scenarios)."""
    with _registry_guard:
        _edges.clear()
        del _long_holds[:]


@contextmanager
def isolated():
    """Snapshot + restore the recorded state so a unit test can create a
    deliberate inversion without poisoning a TRUFFLE_LOCKCHECK=1 session."""
    with _registry_guard:
        edges, holds = dict(_edges), list(_long_holds)
        _edges.clear()
        del _long_holds[:]
    try:
        yield
    finally:
        with _registry_guard:
            _edges.clear()
            _edges.update(edges)
            _long_holds[:] = holds


def inversions() -> List[dict]:
    """Unordered site pairs observed in BOTH orders, with both witnesses."""
    with _registry_guard:
        edges = dict(_edges)
    out, seen = [], set()
    for (a, b) in edges:
        if (b, a) in edges and frozenset((a, b)) not in seen:
            seen.add(frozenset((a, b)))
            out.append({"pair": sorted((a, b)),
                        "witness_ab": edges[(a, b)],
                        "witness_ba": edges[(b, a)]})
    return out


def long_holds() -> List[dict]:
    with _registry_guard:
        return list(_long_holds)


def report() -> dict:
    with _registry_guard:
        n_edges = len(_edges)
    return {"installed": _installed, "order_edges": n_edges,
            "inversions": inversions(), "long_holds": long_holds()}


def dump_report(path: str) -> None:
    rep = report()
    with _registry_guard:
        rep["edges"] = [{"held": a, "acquired": b} for (a, b) in _edges]
    with open(path, "w") as fh:
        json.dump(rep, fh, indent=1)


def format_inversions(invs: Optional[List[dict]] = None) -> str:
    invs = inversions() if invs is None else invs
    lines = []
    for inv in invs:
        a, b = inv["pair"]
        lines.append("LOCK ORDER INVERSION: %s <-> %s" % (a, b))
        for tag in ("witness_ab", "witness_ba"):
            w = inv[tag]
            lines.append("  %s -> %s  [thread %s]"
                         % (w["held"], w["acquired"], w["thread"]))
            lines.append("    " + w["stack"].strip().replace("\n", "\n    "))
    return "\n".join(lines)
