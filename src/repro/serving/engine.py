"""Batched serving engine: request queue -> padded prefill -> greedy decode.

Truffle integration: the engine's first-batch cold start (real XLA compiles
of prefill_step + serve_step) is overlapped with SDP prefetch of request
payloads from storage — the serving twin of launch/train.py."""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclass
class GenRequest:
    uid: str
    prompt: List[int]
    max_new_tokens: int = 8
    result: Optional[List[int]] = None


@dataclass
class EngineStats:
    compile_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    time_to_first_batch: float = 0.0
    tokens_out: int = 0


class ServeEngine:
    """Static batcher: pad a batch of prompts, prefill once, decode greedily."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 128):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self._queue: List[GenRequest] = []
        self._lock = threading.Lock()
        self.stats = EngineStats()
        self._compiled = False

    # ------------------------------------------------------------- lifecycle
    def warmup(self, prompt_len: int) -> None:
        """Cold start: trace+compile prefill and decode (call under Truffle's
        overlap window)."""
        t0 = time.monotonic()
        cfg = self.cfg
        B, L = self.max_batch, prompt_len
        self._prefill = jax.jit(
            lambda p, b: api.prefill(cfg, p, b)).lower(
                jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             self.params),
                {"tokens": jax.ShapeDtypeStruct((B, L), jnp.int32)}).compile()
        cache_sds = api.cache_sds(cfg, B, self.max_len)
        self._decode = jax.jit(
            lambda p, c, t, q: api.decode_step(cfg, p, c, t, q)).lower(
                jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             self.params),
                cache_sds,
                jax.ShapeDtypeStruct((B, 1), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32)).compile()
        self.stats.compile_s = time.monotonic() - t0
        self._compiled = True

    # --------------------------------------------------------------- serving
    def submit(self, req: GenRequest) -> None:
        with self._lock:
            self._queue.append(req)

    def step_batch(self) -> List[GenRequest]:
        """Serve one batch from the queue; returns completed requests."""
        with self._lock:
            batch = self._queue[:self.max_batch]
            self._queue = self._queue[self.max_batch:]
        if not batch:
            return []
        B = self.max_batch
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, plen - len(r.prompt):] = r.prompt        # left-pad
        if not self._compiled:
            self.warmup(plen)

        t0 = time.monotonic()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        cache = self._grow_cache(cache, plen)
        self.stats.prefill_s += time.monotonic() - t0

        t0 = time.monotonic()
        out = np.asarray(jnp.argmax(logits[:, -1], -1)).reshape(B, 1)
        results = [out[:, 0].tolist()]
        max_new = max(r.max_new_tokens for r in batch)
        pos = plen
        token = jnp.asarray(out, jnp.int32)
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, token,
                                         jnp.asarray(pos, jnp.int32))
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            token = nxt[:, None]
            results.append(np.asarray(nxt).tolist())
            pos += 1
        self.stats.decode_s += time.monotonic() - t0

        gen = np.asarray(results).T                           # [B, max_new]
        for i, r in enumerate(batch):
            r.result = gen[i, :r.max_new_tokens].tolist()
            self.stats.tokens_out += len(r.result)
        return batch

    def _grow_cache(self, cache, plen: int):
        """Pad prefill cache out to max_len decode slots."""
        extra = self.max_len - plen
        if extra <= 0:
            return cache

        def pad(path, a):
            name = str(getattr(path[-1], "key", ""))
            if name in ("k", "v") and a.ndim == 5:
                return jnp.pad(a, ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
            if name in ("ckv", "kpe") and a.ndim == 4:
                return jnp.pad(a, ((0, 0), (0, 0), (0, extra), (0, 0)))
            return a

        return jax.tree_util.tree_map_with_path(pad, cache)
