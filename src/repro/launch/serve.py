"""Serving driver: batched generation with the Truffle-overlapped cold start.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 8 \
      [--no-truffle] [--prompt-len 16] [--max-new 8]

The engine cold start (real XLA compiles of prefill + serve_step) overlaps
with SDP prefetch of request payloads from the KVS (see
examples/serve_batch.py for the scripted walkthrough)."""
from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from repro.configs.registry import get_config, list_archs
from repro.core.buffer import Buffer
from repro.models import api
from repro.runtime.clock import Clock
from repro.runtime.netsim import GBPS
from repro.serving.engine import GenRequest, ServeEngine
from repro.storage.base import StorageService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--truffle", action="store_true", default=True)
    ap.add_argument("--no-truffle", dest="truffle", action="store_false")
    ap.add_argument("--kvs-gbps", type=float, default=0.002)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = api.init(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=args.batch,
                         max_len=args.prompt_len + args.max_new)

    clock = Clock(1.0)
    kvs = StorageService("kvs", put_bandwidth=1 * GBPS,
                         get_bandwidth=args.kvs_gbps * GBPS, latency=0.002,
                         clock=clock)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        p = rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
        kvs.put(f"req-{i}", p.tobytes())

    buffer = Buffer(name="serve-buffer")
    t0 = time.monotonic()

    def prefetch():
        for i in range(args.requests):
            data, _ = kvs.get(f"req-{i}")
            buffer.set(f"req-{i}", data)

    if args.truffle:
        th = threading.Thread(target=prefetch, daemon=True)
        th.start()
        engine.warmup(args.prompt_len)
        th.join()
    else:
        engine.warmup(args.prompt_len)
        prefetch()

    for i in range(args.requests):
        raw = buffer.wait_for(f"req-{i}", timeout=120)
        engine.submit(GenRequest(f"req-{i}",
                                 np.frombuffer(raw, np.int32).tolist(),
                                 args.max_new))
    served = 0
    while True:
        batch = engine.step_batch()
        if not batch:
            break
        served += len(batch)
    total = time.monotonic() - t0
    print(f"mode={'truffle' if args.truffle else 'baseline'} served={served} "
          f"tokens={engine.stats.tokens_out} total={total:.2f}s "
          f"compile={engine.stats.compile_s:.2f}s")
    return total


if __name__ == "__main__":
    main()
