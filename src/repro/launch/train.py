"""End-to-end training driver with Truffle cold-start overlap.

The training job is treated exactly like a paper-§IV function: its cold start
β = (worker provisioning ν, simulated) + (XLA compile η, REAL), and Truffle
overlaps that window with (a) SDP prefetch of the first data batches from the
object store and (b) streaming the checkpoint bytes for restore. Baseline
mode runs the same phases sequentially (state-of-the-art lifecycle, Fig. 2).

Fault tolerance: ``--inject-failure K`` raises at step K; the outer loop
restarts the job (new incarnation -> new cold start, again overlapped) and
resumes from the latest complete checkpoint. ``--elastic`` restarts onto a
different microbatch split to emulate losing part of the DP group.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-every 5 --inject-failure 12
"""
from __future__ import annotations

import argparse
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager, deserialize, serialize
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.core.buffer import Buffer
from repro.data.pipeline import TokenDataset, TruffleDataLoader
from repro.distributed.sharding import rules_for_shape
from repro.launch.mesh import host_device_mesh, set_mesh
from repro.launch.steps import build_train_step, concrete_train_state
from repro.optim.adamw import OptConfig
from repro.runtime.clock import Clock
from repro.storage.base import make_object_store


class SimulatedFailure(RuntimeError):
    pass


def run_incarnation(args, incarnation: int, clock: Clock) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.vision is not None or cfg.encoder is not None:
        raise SystemExit("train driver targets LM archs; use examples/ for others")
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = host_device_mesh(1, 1)
    microbatch = args.microbatch * (2 if (args.elastic and incarnation > 0) else 1)

    storage = make_object_store(clock)
    dataset = TokenDataset(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    loader = TruffleDataLoader(dataset, storage, prefetch_depth=2)
    ckpt = CheckpointManager(args.ckpt_dir)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)

    train_step, (state_sds, batch_sds) = build_train_step(
        cfg, mesh, shape, opt_cfg=opt_cfg, microbatch=microbatch)

    t0 = time.monotonic()
    compiled_box, ckpt_box = {}, {}
    ckpt_buffer = Buffer(name="ckpt-buffer")

    def cold_start():  # η: the real XLA compile
        clock.sleep(args.provision_s)  # ν: worker provisioning (simulated)
        with set_mesh(mesh):
            compiled_box["exe"] = jax.jit(train_step).lower(
                state_sds, batch_sds).compile()

    def fetch_ckpt():  # CSP-style: stream restore bytes during cold start
        step = ckpt.latest_step()
        if step is not None:
            ckpt_box["bytes"] = None  # manifest path restore (local disk here)
            ckpt_box["step"] = step

    if args.truffle:
        threads = [threading.Thread(target=cold_start),
                   threading.Thread(target=fetch_ckpt)]
        for th in threads:
            th.start()
        loader.start_prefetch()               # SDP: batches flow during compile
        for th in threads:
            th.join()
    else:  # sequential lifecycle
        cold_start()
        fetch_ckpt()
        loader.start_prefetch()

    exe = compiled_box["exe"]
    with set_mesh(mesh):
        state = concrete_train_state(cfg, mesh, rules_for_shape("train"),
                                     jax.random.PRNGKey(args.seed))
        start_step = 0
        if "step" in ckpt_box:
            state, start_step = ckpt.restore(state, ckpt_box["step"])
            state = jax.tree.map(jnp.asarray, state)
            print(f"[inc {incarnation}] resumed from step {start_step}")

    losses, t_first = [], None
    for step in range(start_step, args.steps):
        batch = loader.get(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = exe(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if t_first is None:
            t_first = time.monotonic() - t0
        if args.inject_failure == step and incarnation == 0:
            loader.stop()
            raise SimulatedFailure(f"injected node failure at step {step}")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, state)
        if step % args.log_every == 0:
            print(f"[inc {incarnation}] step {step} loss {loss:.4f}")
    ckpt.wait()
    loader.stop()
    assert all(np.isfinite(losses)), "NaN/inf loss"
    return {"time_to_first_step": t_first, "losses": losses,
            "final_step": args.steps, "incarnation": incarnation}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--truffle", action="store_true", default=True)
    ap.add_argument("--no-truffle", dest="truffle", action="store_false")
    ap.add_argument("--provision-s", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--inject-failure", type=int, default=-1)
    ap.add_argument("--elastic", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--time-scale", type=float, default=1.0)
    args = ap.parse_args(argv)

    clock = Clock(args.time_scale)
    incarnation = 0
    while True:
        try:
            out = run_incarnation(args, incarnation, clock)
            break
        except SimulatedFailure as e:
            print(f"!! {e} — restarting (checkpoint/restart path)")
            incarnation += 1
            if incarnation > 3:
                raise
    print(f"done: time_to_first_step={out['time_to_first_step']:.2f}s "
          f"final_loss={out['losses'][-1]:.4f} "
          f"loss_drop={out['losses'][0] - out['losses'][-1]:.4f}")
    return out


if __name__ == "__main__":
    main()
