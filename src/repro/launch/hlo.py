"""Post-SPMD HLO analysis: collective inventory for the roofline's third term.

``compiled.as_text()`` shapes are per-device (post-partitioning). For each
collective op we take its *result* byte size as the per-device traffic proxy
(all-reduce is counted twice: ring RS+AG moves ~2x). EXPERIMENTS.md §Roofline
documents this convention.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "all-reduce-scatter")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast)(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes} (per-device result sizes;
    all-reduce counted at 2x for ring RS+AG)."""
    out: Dict[str, Dict[str, float]] = defaultdict(lambda: {"count": 0, "bytes": 0.0})
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # async pairs appear as -start/-done; count -start only
        prefix = hlo_text[max(0, m.start() - 120):m.end()]
        if f"{kind}-done(" in m.group(0):
            continue
        b = _shape_bytes(shape_str)
        factor = 2.0 if kind == "all-reduce" else 1.0
        out[kind]["count"] += 1
        out[kind]["bytes"] += b * factor
    return dict(out)


def total_collective_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(v["bytes"] for v in stats.values())


def remat_duplication(hlo_text: str) -> Dict[str, int]:
    """Count fusion/dot ops as a coarse redundancy signal."""
    return {
        "dots": len(re.findall(r"\bdot\(", hlo_text)),
        "fusions": len(re.findall(r"= \S+ fusion\(", hlo_text)),
        "while_ops": len(re.findall(r"\bwhile\(", hlo_text)),
    }
