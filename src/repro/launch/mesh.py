"""Production meshes. A function (not a module constant) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def host_device_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = n_data * n_model
    assert n <= len(jax.devices()), (n, len(jax.devices()))
    return make_mesh((n_data, n_model), ("data", "model"))
