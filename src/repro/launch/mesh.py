"""Production meshes. A function (not a module constant) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def set_mesh(mesh):
    """Context manager activating ``mesh``: jax.set_mesh on jax >= 0.6;
    on 0.4.x the Mesh object is itself the context manager."""
    impl = getattr(jax, "set_mesh", None)
    return impl(mesh) if impl is not None else mesh


def make_mesh(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)  # jax >= 0.6 only
    kw = {"axis_types": (axis_type.Auto,) * len(axes)} if axis_type else {}
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)


def host_device_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = n_data * n_model
    assert n <= len(jax.devices()), (n, len(jax.devices()))
    return make_mesh((n_data, n_model), ("data", "model"))
