"""Analytic MODEL_FLOPS per (arch x shape): the "useful work" reference the
roofline compares compiled HLO FLOPs against (6ND-style accounting + explicit
attention/SSM terms; no remat, no dispatch overhead)."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig


def _matmul_params(cfg: ModelConfig) -> int:
    """Active params that participate in matmuls (embedding gather excluded,
    unembedding projection included)."""
    n = cfg.param_count(active_only=True)
    n -= cfg.vocab_size * cfg.d_model          # embedding table (gather)
    if cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model      # ...but the tied head is a matmul
    return n


def _attn_layers(cfg: ModelConfig) -> int:
    per = sum(1 for m, _ in cfg.block_pattern if m == "attn")
    return per * cfg.num_periods


def _mixer_layers(cfg: ModelConfig, kind: str) -> int:
    per = sum(1 for m, _ in cfg.block_pattern if m == kind)
    return per * cfg.num_periods


def _attn_fwd_flops(cfg: ModelConfig, B: int, Sq: int, Skv: int,
                    causal: bool) -> float:
    """QK^T + AV matmul flops for ONE layer, forward."""
    H = cfg.num_heads
    if cfg.attention_type == "mla":
        qk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        vd = cfg.mla.v_head_dim
    else:
        qk = vd = cfg.resolved_head_dim
    f = 2.0 * B * Sq * Skv * H * (qk + vd)
    return f * (0.5 if causal and Sq == Skv else 1.0)


def _ssm_fwd_flops(cfg: ModelConfig, B: int, S: int) -> float:
    """Mamba selective-scan elementwise work for ONE layer, forward."""
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    return 9.0 * B * S * d_in * mc.d_state


def _mlstm_fwd_flops(cfg: ModelConfig, B: int, S: int) -> float:
    """Chunkwise mLSTM: intra-chunk quadratic + inter-chunk state einsums."""
    x = cfg.xlstm
    d_in = int(x.mlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    dk = d_in // H
    c = min(x.chunk, S)
    intra = 2.0 * B * S * c * H * (2 * dk + dk) * 0.5      # qk + av, causal
    inter = 4.0 * B * S * H * dk * dk + 4.0 * B * S * H * dk  # q@C + kv^T accum
    return intra + inter


def _slstm_fwd_flops(cfg: ModelConfig, B: int, S: int) -> float:
    H = cfg.num_heads
    dh = cfg.d_model // H
    return 8.0 * B * S * H * dh * dh  # 4 recurrent gate einsums


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens, mult = B * S, 6.0
    elif shape.kind == "prefill":
        tokens, mult = B * S, 2.0
    else:  # decode: per step, one token each
        tokens, mult = B, 2.0

    mm_params = _matmul_params(cfg)
    if cfg.encoder is not None and shape.kind == "decode":
        # decode never runs the encoder; cross K/V projections are cached
        d, hd, nkv = cfg.d_model, cfg.resolved_head_dim, cfg.num_kv_heads
        enc_attn = d * cfg.num_heads * hd * 2 + 2 * d * nkv * hd
        enc_mlp = (3 if cfg.act == "silu" else 2) * d * cfg.d_ff
        mm_params -= cfg.encoder.num_layers * (enc_attn + enc_mlp)
        mm_params -= cfg.num_layers * 2 * d * nkv * hd
    total = mult * mm_params * tokens
    fwd_share = mult / 2.0  # fwd(+bwd): train 3x fwd, inference 1x

    if shape.kind == "decode":
        attn = _attn_fwd_flops(cfg, B, 1, S, causal=False)
        ssm = _ssm_fwd_flops(cfg, B, 1) if cfg.mamba else 0.0
        mls = _mlstm_fwd_flops(cfg, B, 1) if cfg.xlstm else 0.0
        sls = _slstm_fwd_flops(cfg, B, 1) if cfg.xlstm else 0.0
    else:
        attn = _attn_fwd_flops(cfg, B, S, S, causal=True) * fwd_share
        ssm = (_ssm_fwd_flops(cfg, B, S) if cfg.mamba else 0.0) * fwd_share
        mls = (_mlstm_fwd_flops(cfg, B, S) if cfg.xlstm else 0.0) * fwd_share
        sls = (_slstm_fwd_flops(cfg, B, S) if cfg.xlstm else 0.0) * fwd_share

    total += attn * _attn_layers(cfg)
    total += ssm * _mixer_layers(cfg, "mamba")
    total += mls * _mixer_layers(cfg, "mlstm")
    total += sls * _mixer_layers(cfg, "slstm")

    if cfg.encoder is not None:  # whisper: encoder + cross-attention
        Se = cfg.encoder.num_frames
        enc_attn = _attn_fwd_flops(cfg, B, Se, Se, causal=False) * fwd_share
        total += enc_attn * cfg.encoder.num_layers
        if shape.kind == "decode":
            total += _attn_fwd_flops(cfg, B, 1, Se, causal=False) * cfg.num_layers
        else:
            total += _attn_fwd_flops(cfg, B, S, Se, causal=False) * fwd_share * cfg.num_layers
    return float(total)
