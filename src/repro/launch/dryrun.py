import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: device count locks at first jax init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step / prefill_step /
serve_step) against abstract ShapeDtypeStruct inputs carrying the production
NamedShardings, compiles it, and records memory_analysis / cost_analysis /
collective inventory to JSON — the roofline table (§Roofline) is built from
these artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import get_config, list_archs
from repro.launch import hlo
from repro.launch.flops import model_flops
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch.steps import build_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _compile_cell(cfg, shape, mesh, multi_pod, step_kw, jit_kw=None):
    fn, abstract_args = build_step(cfg, mesh, shape, multi_pod=multi_pod, **step_kw)
    t0 = time.time()
    with set_mesh(mesh):
        lowered = jax.jit(fn, **(jit_kw or {})).lower(*abstract_args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
    coll = hlo.collective_stats(txt)
    return {
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "collectives": coll,
        "collective_bytes": hlo.total_collective_bytes(coll),
        "hlo_stats": hlo.remat_duplication(txt),
    }


def _probe_cfg(cfg, n_periods: int):
    """Unrolled small-depth config for exact cost_analysis (no while undercount)."""
    c = cfg.replace(num_layers=n_periods * cfg.period, scan_layers=False,
                    unroll_scans=True)
    if cfg.encoder is not None:
        import dataclasses
        c = c.replace(encoder=dataclasses.replace(
            cfg.encoder,
            num_layers=max(1, cfg.encoder.num_layers * n_periods // cfg.num_periods)))
    return c


def _extrapolate(m1: dict, m2: dict, n_periods: int, enc_note: str = "") -> dict:
    """True per-program cost from two unrolled probes: est(T) = m1 + (m2-m1)(T-1)."""
    out = {}
    for k in ("flops", "bytes_accessed", "collective_bytes"):
        per = m2[k] - m1[k]
        out[k + "_est"] = m1[k] + per * (n_periods - 1)
        out[k + "_per_layer"] = per
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             overrides: dict | None = None, tag: str = "",
             skip_probes: bool = False) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**{k: v for k, v in overrides.items() if hasattr(cfg, k)})
    shape = SHAPES[shape_name]
    multi_pod = mesh_kind == "multi"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
           "kind": shape.kind, "ok": False}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec.update(skipped=True, reason=why, ok=True)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    step_kw = {}
    if shape.kind == "train" and overrides:
        if "microbatch" in overrides:
            step_kw["microbatch"] = overrides["microbatch"]
        if "zero1" in overrides:
            step_kw["zero1"] = overrides["zero1"]
    if overrides and overrides.get("rules") == "ep_only":
        # §Perf lever for small-active MoE archs: use the 'model' axis for
        # expert parallelism only; attention/shared-MLP compute replicates
        # (their TP all-reduces were the residual collective term).
        from repro.distributed.sharding import rules_for_shape
        rules = rules_for_shape(shape.kind, multi_pod=multi_pod,
                                global_batch=shape.global_batch,
                                seq_len=shape.seq_len)
        rules.update(heads=None, kv_heads=None, ff=None,
                     act_heads=None, act_ff=None, vocab="model")
        step_kw["rules"] = rules

    jit_kw = {}
    if overrides and overrides.get("donate_cache") and shape.kind == "decode":
        # §Perf (serving): alias the KV cache in/out — removes the full
        # cache copy from every decode step (standard serving practice).
        jit_kw["donate_argnums"] = (1,)

    # 1) The deliverable compile: full depth, production scan/remat config.
    print(f"    [{arch}/{shape_name}/{mesh_kind}] main compile...", flush=True)
    main = _compile_cell(cfg, shape, mesh, multi_pod, step_kw, jit_kw)
    rec.update(ok=True, num_devices=mesh.devices.size, **main)

    # 2) Cost probes: XLA cost_analysis counts `while` bodies once, so the
    #    scanned-stack FLOPs are undercounted; two unrolled shallow compiles
    #    give the exact per-layer cost to extrapolate from.
    #    SSM-family train/prefill probes would unroll the inner chunk scans
    #    into enormous HLO (hour-long CPU compiles) — those cells report
    #    analytic model_flops instead (roofline marks them 'analytic').
    if (cfg.mamba or cfg.xlstm) and shape.kind != "decode":
        skip_probes = True
        rec["probe_note"] = "ssm inner scans: analytic flops (probe unroll too costly)"
    if not skip_probes:
        try:
            print(f"    [{arch}/{shape_name}/{mesh_kind}] probe compiles...",
                  flush=True)
            m1 = _compile_cell(_probe_cfg(cfg, 1), shape, mesh, multi_pod,
                               step_kw, jit_kw)
            m2 = _compile_cell(_probe_cfg(cfg, 2), shape, mesh, multi_pod,
                               step_kw, jit_kw)
            rec.update(_extrapolate(m1, m2, cfg.num_periods))
        except Exception as e:  # noqa: BLE001 — probes are best-effort:
            # the error (any compile failure) is RECORDED on the cell, not
            # swallowed — the roofline table shows the probe hole
            rec["probe_error"] = str(e)[:500]
            rec["probe_trace"] = traceback.format_exc()[-2000:]

    rec["model_flops"] = model_flops(cfg, shape)
    if rec.get("flops_est"):
        rec["useful_flops_ratio"] = rec["model_flops"] / (
            rec["flops_est"] * mesh.devices.size)
    return rec


def cell_path(arch, shape_name, mesh_kind, tag="") -> Path:
    sfx = f"--{tag}" if tag else ""
    return OUT_DIR / f"{arch}--{shape_name}--{mesh_kind}{sfx}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="variant tag (perf hillclimb)")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (e.g. remat=full, microbatch=4)")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    archs = list_archs() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                path = cell_path(arch, shape_name, mesh_kind, args.tag)
                if path.exists() and not args.force:
                    print(f"cached  {path.name}")
                    n_ok += 1
                    continue
                t0 = time.time()
                try:
                    # probes (exact-FLOPs extrapolation) feed the single-pod
                    # roofline table; the multi-pod pass only proves sharding.
                    rec = run_cell(arch, shape_name, mesh_kind,
                                   overrides=overrides, tag=args.tag,
                                   skip_probes=(mesh_kind == "multi"
                                                or args.no_probes))
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                           "tag": args.tag, "ok": False, "error": str(e),
                           "trace": traceback.format_exc()[-2000:]}
                path.write_text(json.dumps(rec, indent=1))
                jax.clear_caches()  # keep one-process sweep memory bounded
                status = ("SKIP" if rec.get("skipped")
                          else "ok" if rec["ok"] else "FAIL")
                if rec.get("skipped"):
                    n_skip += 1
                elif rec["ok"]:
                    n_ok += 1
                else:
                    n_fail += 1
                print(f"{status:5s} {arch:18s} {shape_name:12s} {mesh_kind:6s} "
                      f"{time.time() - t0:7.1f}s "
                      f"flops={rec.get('flops', 0):.3g} "
                      f"coll={rec.get('collective_bytes', 0):.3g}B"
                      + (f"  ERR: {rec.get('error', '')[:120]}" if not rec["ok"] else ""))
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
