"""Step builders: train / prefill / decode, with full sharding trees.

Every builder returns ``(fn, abstract_args)`` where abstract_args is a tree
of ShapeDtypeStructs *carrying NamedShardings* — ready both for AOT
``jax.jit(fn).lower(*abstract_args)`` (dry-run) and for real execution with
concrete arrays laid out the same way.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (ShardCtx, default_rules, dp_axes,
                                        rules_for_shape, spec_for_axes,
                                        specs_for, shardings_for)
from repro.distributed.zero import zero1_specs
from repro.models import api
from repro.models.params import abstract_params
from repro.optim.adamw import OptConfig, apply_updates, init_state

PyTree = Any


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _with_shardings(sds_tree: PyTree, axes_tree: PyTree, mesh: Mesh, rules) -> PyTree:
    def f(s: jax.ShapeDtypeStruct, ax):
        spec = spec_for_axes(mesh, rules, s.shape, ax)
        return _sds(s.shape, s.dtype, mesh, spec)
    return jax.tree.map(f, sds_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _batch_axes(batch_sds: Dict[str, Any]) -> Dict[str, Any]:
    ax = {}
    for k, v in batch_sds.items():
        if k in ("tokens", "labels", "token"):
            ax[k] = ("batch", None)
        elif k == "mrope_positions":
            ax[k] = ("batch", None, None)
        else:  # frames / vision_embeds
            ax[k] = ("batch", None, None)
    return ax


# ---------------------------------------------------------------------------
# Params / state
# ---------------------------------------------------------------------------

def abstract_param_tree(cfg: ModelConfig, mesh: Mesh, rules) -> PyTree:
    defs = api.model_defs(cfg)
    sds = abstract_params(defs, cfg.param_dtype)
    shardings = shardings_for(defs, mesh, rules)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds, shardings,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def abstract_train_state(cfg: ModelConfig, mesh: Mesh, rules,
                         zero1: bool = True) -> PyTree:
    defs = api.model_defs(cfg)
    p = abstract_param_tree(cfg, mesh, rules)
    if zero1:
        zspecs = zero1_specs(defs, mesh, rules)
    else:
        zspecs = specs_for(defs, mesh, rules)
    moment = jax.tree.map(
        lambda s, sp: _sds(s.shape, jnp.float32, mesh, sp), p, zspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return {"params": p,
            "opt": {"m": moment, "v": jax.tree.map(lambda x: x, moment),
                    "step": _sds((), jnp.int32, mesh, P())}}


def concrete_train_state(cfg: ModelConfig, mesh: Optional[Mesh], rules, key) -> PyTree:
    params = api.init(cfg, key)
    opt = init_state(params)
    state = {"params": params, "opt": opt}
    if mesh is not None:
        abstract = abstract_train_state(cfg, mesh, rules)
        state = jax.tree.map(lambda x, s: jax.device_put(x, s.sharding),
                             state, abstract)
    return state


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                     opt_cfg: Optional[OptConfig] = None, microbatch: int = 1,
                     multi_pod: bool = False, zero1: bool = True,
                     rules: Optional[Dict[str, Any]] = None):
    rules = rules or rules_for_shape("train", multi_pod=multi_pod)
    ctx = ShardCtx(mesh, rules)
    opt_cfg = opt_cfg or OptConfig()

    def train_step(state, batch):
        params = state["params"]

        def lf(p, b):
            return api.loss_fn(cfg, p, b, ctx)

        if microbatch > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(lf, has_aux=True)(params, mb)
                g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            dpn = 1
            for a in dp_axes(rules):
                if a in mesh.shape:
                    dpn *= mesh.shape[a]

            def split(x):
                # Shard-aligned microbatching: split WITHIN each DP shard
                # ([B] -> [dp, mb, B/(dp*mb)] -> [mb, B/mb]) so every
                # microbatch keeps the original batch sharding. The naive
                # contiguous split makes GSPMD resort to involuntary full
                # rematerialization (§Perf log, jamba iteration 2).
                nb = x.shape[0] // microbatch
                if x.shape[0] % (dpn * microbatch) == 0:
                    x = x.reshape(dpn, microbatch, nb // dpn, *x.shape[1:])
                    x = jnp.moveaxis(x, 1, 0)
                    return x.reshape(microbatch, nb, *x.shape[3:])
                return x.reshape(microbatch, nb, *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = loss / microbatch
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params, batch)

        new_p, new_opt, om = apply_updates(opt_cfg, params, grads, state["opt"])
        metrics = dict(metrics)
        metrics.update(om)
        return {"params": new_p, "opt": new_opt}, metrics

    state_sds = abstract_train_state(cfg, mesh, rules, zero1=zero1)
    batch_raw = api.input_specs(cfg, shape)["batch"]
    batch_sds = _with_shardings(batch_raw, _batch_axes(batch_raw), mesh, rules)
    return train_step, (state_sds, batch_sds)


# ---------------------------------------------------------------------------
# Prefill / decode steps
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                       multi_pod: bool = False,
                       rules: Optional[Dict[str, Any]] = None):
    rules = rules or rules_for_shape("prefill", multi_pod=multi_pod)
    ctx = ShardCtx(mesh, rules)

    def prefill_step(params, batch):
        return api.prefill(cfg, params, batch, ctx)

    p_sds = abstract_param_tree(cfg, mesh, rules)
    batch_raw = api.input_specs(cfg, shape)["batch"]
    batch_sds = _with_shardings(batch_raw, _batch_axes(batch_raw), mesh, rules)
    return prefill_step, (p_sds, batch_sds)


def build_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                      multi_pod: bool = False,
                      rules: Optional[Dict[str, Any]] = None):
    rules = rules or rules_for_shape("decode", multi_pod=multi_pod,
                                     global_batch=shape.global_batch,
                                     seq_len=shape.seq_len)
    ctx = ShardCtx(mesh, rules)

    def serve_step(params, cache, token, pos):
        return api.decode_step(cfg, params, cache, token, pos, ctx)

    p_sds = abstract_param_tree(cfg, mesh, rules)
    specs = api.input_specs(cfg, shape)
    cache_sds = _with_shardings(specs["cache"],
                                api.cache_axes(cfg), mesh, rules)
    token_sds = _sds((shape.global_batch, 1), jnp.int32, mesh,
                     spec_for_axes(mesh, rules, (shape.global_batch, 1),
                                   ("batch", None)))
    pos_sds = _sds((), jnp.int32, mesh, P())
    return serve_step, (p_sds, cache_sds, token_sds, pos_sds)


def build_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, **kw):
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, **kw)
    return build_decode_step(cfg, mesh, shape, **kw)
