"""AdamW + cosine schedule + global-norm clipping, ZeRO-1 ready.

Optimizer state (m, v) mirrors the param tree; under ZeRO-1 the state is
*additionally* sharded over the DP axes on each tensor's largest
still-unsharded dimension (see ``distributed.zero``), cutting optimizer
memory by |DP| on replicated params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)  # decay to 10%


def init_state(params: PyTree) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    import copy
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(cfg: OptConfig, params: PyTree, grads: PyTree,
                  opt: Dict[str, Any]) -> Tuple[PyTree, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
