"""Shared data-plane helpers for SDP/CSP (one implementation of the
ship-payload decision tree and the stall-guarded thread join, so the two
paths cannot diverge).

Knobs: ``stream`` relays at chunk granularity (``chunk_bytes``, default
1 MiB) into an in-flight buffer entry; ``dedup`` aliases the target's
content-addressed index on a hit instead of shipping bytes.

Relay batching (ROADMAP "one relay stream"): concurrent passes of the SAME
content to the SAME node — a fan-out stage placed locality-aware lands all
its sinks on one node — share a single relay via the cluster's
:class:`RelayTable`. The first pass ships; followers wait on its completion
and alias the landed bytes (``record.relay_shared``), instead of each
re-shipping the payload over the fabric."""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

from repro.core.errors import TransferStallError
from repro.runtime.executor import EXECUTOR
from repro.runtime.function import LifecycleRecord
from repro.runtime.netsim import DEFAULT_CHUNK_BYTES

#: wall-seconds a follower waits for the leader's relay before giving up
#: and shipping on its own (matches the SDP/CSP join budget order)
RELAY_WAIT_S = 120.0


class RelayTable:
    """In-flight relay registry: (digest, target node) → completion event.

    ``lead_or_follow`` elects exactly one shipper per (content, node) pair;
    everyone else blocks on the leader's event and then aliases. Entries are
    removed on completion (success or failure), so a failed leader's
    followers fall back to shipping themselves."""

    def __init__(self):
        self._lock = threading.Lock()
        self._inflight: Dict[Tuple[str, str], threading.Event] = {}
        self.stats = {"leads": 0, "follows": 0}

    def lead_or_follow(self, digest: str,
                       node_name: str) -> Tuple[bool, threading.Event]:
        key = (digest, node_name)
        with self._lock:
            ev = self._inflight.get(key)
            if ev is not None:
                self.stats["follows"] += 1
                return False, ev
            ev = threading.Event()
            self._inflight[key] = ev
            self.stats["leads"] += 1
            return True, ev

    def finish(self, digest: str, node_name: str) -> None:
        with self._lock:
            ev = self._inflight.pop((digest, node_name), None)
        if ev is not None:
            ev.set()


def relay_lead_or_alias(cluster, digest: Optional[str], buffer,
                        node_name: str, key: str,
                        record: Optional[LifecycleRecord] = None,
                        wait_s: float = RELAY_WAIT_S) -> Tuple[bool, bool]:
    """The ONE relay rendezvous both the CSP/SDP ship and the Data Engine's
    storage fetch use (the two paths must not diverge). ``wait_s`` bounds
    the follower's wait on an in-flight leader — a speculative backup
    passes a tighter budget (the backup exists because something is
    already stuck; parking behind a possibly-wedged relay for the full
    default would defeat it). Returns ``(lead, aliased)``:

      * ``(True, False)`` — caller is the elected leader: move the bytes,
        then call ``cluster.relays.finish(digest, node_name)`` (in a
        ``finally``) to release followers.
      * ``(False, True)`` — an in-flight relay of this content landed and
        was aliased under ``key`` (``record.relay_shared``); nothing to
        move.
      * ``(False, False)`` — no relay table / no digest, or the leader
        failed before we could alias: move the bytes yourself, without
        holding (or finishing) a lead."""
    relays = getattr(cluster, "relays", None)
    if digest is None or relays is None:
        return False, False
    lead, ev = relays.lead_or_follow(digest, node_name)
    if lead:
        return True, False
    ev.wait(wait_s)
    if buffer.alias(key, digest):
        if record is not None:
            record.dedup_hit = True
            record.relay_shared = True
        return False, True
    return False, False


def pin_of(cluster, fn: str) -> Optional[str]:
    """The node name ``fn`` is affinity-pinned to, if any."""
    spec = cluster.platform._specs.get(fn)
    return spec.affinity if spec is not None else None


def resolve_codec(name: Optional[str]):
    """``DataPolicy.compression`` -> chunk codec (lazy: the codec module
    pulls in the ML stack, which pure data-plane paths shouldn't pay for
    unless an edge actually enables compression)."""
    if name in (None, "none"):
        return None
    from repro.distributed.compression import chunk_codec
    return chunk_codec(name)


def publish_content(node, data: bytes, digest: str) -> None:
    """Make ``data`` resident on ``node`` under its content address
    (``cas/<digest>``) so the digest registry — and therefore the
    locality-aware scheduler — can see it. Alias-first avoids registry
    churn when the bytes are already there."""
    cas_key = f"cas/{digest}"
    if not node.buffer.alias(cas_key, digest):
        node.buffer.set(cas_key, data, digest=digest)


def seed_content(cluster, node, fn: str, data: bytes, digest: str) -> None:
    """Seed dedup'd content into ``node``'s buffer under ``cas/<digest>``
    BEFORE the trigger fires, so the digest registry sees the bytes and the
    locality-aware scheduler can place ``fn`` on them (the pass then
    degenerates to a local alias). One implementation for CSP and SDP — the
    seeding gate must not diverge between the two paths. alias-first avoids
    registry churn on repeat passes; a target pinned to another node can
    never use the seed, so the copy is skipped."""
    pin = pin_of(cluster, fn)
    if pin is not None and pin != node.name:
        return
    publish_content(node, data, digest)


def ship_payload(cluster, src_node, target, buf_key: str, data: bytes, *,
                 stream: bool, digest: Optional[str],
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 codec=None,
                 record: Optional[LifecycleRecord] = None,
                 relay_wait_s: float = RELAY_WAIT_S) -> None:
    """Move an inline payload into ``target``'s buffer: dedup alias if the
    content is already resident, piggyback on an in-flight relay of the same
    content, else chunk-streamed or whole-blob over the fabric (local
    placement skips the network entirely). ``codec`` (a
    :class:`~repro.distributed.compression.ChunkCodec`) compresses the
    wire bytes on remote hops — the per-edge policy enables it on WAN
    tiers where the link, not the codec, is the bottleneck.
    ``relay_wait_s`` bounds a follower's wait on an in-flight relay of the
    same content (speculative backups pass a tighter budget — see
    :func:`relay_lead_or_alias`)."""
    if digest is not None and target.buffer.alias(buf_key, digest):
        if record is not None:
            record.dedup_hit = True           # content already resident
        return

    lead, aliased = relay_lead_or_alias(cluster, digest, target.buffer,
                                        target.name, buf_key, record,
                                        wait_s=relay_wait_s)
    if aliased:
        return          # piggybacked on an in-flight relay of these bytes
    if lead:
        try:
            _ship_direct(cluster, src_node, target, buf_key, data,
                         stream=stream, digest=digest,
                         chunk_bytes=chunk_bytes, codec=codec,
                         record=record)
        finally:
            cluster.relays.finish(digest, target.name)
        return

    # no relay table, or the leader failed / its entry was evicted before
    # we could alias: ship ourselves
    _ship_direct(cluster, src_node, target, buf_key, data, stream=stream,
                 digest=digest, chunk_bytes=chunk_bytes, codec=codec,
                 record=record)


def _ship_direct(cluster, src_node, target, buf_key: str, data: bytes, *,
                 stream: bool, digest: Optional[str], chunk_bytes: int,
                 codec=None, record: Optional[LifecycleRecord] = None) -> None:
    if target.name != src_node.name:
        wire_ratio = 1.0
        pace_bps = None
        if codec is not None:
            wire_ratio = codec.ratio(data)
            # pipelined codec model: compression overlaps the wire, so the
            # stream's effective rate is min(wire rate, codec throughput) —
            # the channel paces codec-bound transfers (``pace_bps``) and
            # only the first chunk's compression is on the critical path
            pace_bps = codec.compress_bps
            cluster.clock.sleep(codec.compress_s(min(len(data), chunk_bytes)))
            if record is not None:
                record.compress_ratio = wire_ratio
            telemetry = getattr(cluster, "telemetry", None)
            if telemetry is not None:
                telemetry.observe_codec(codec.name, wire_ratio)
        if stream:
            target.buffer.ingest(
                buf_key, cluster.stream(src_node, target, data, chunk_bytes,
                                        wire_ratio=wire_ratio,
                                        pace_bps=pace_bps),
                digest=digest)
        else:
            cluster.transfer(src_node, target, data,    # during cold start
                             wire_ratio=wire_ratio, pace_bps=pace_bps)
            target.buffer.set(buf_key, data, digest=digest)
    else:
        src_node.buffer.set(buf_key, data, digest=digest)


class Prefetcher:
    """Registry-driven prefetch (per-edge ``DataPolicy.prefetch``).

    When the scheduler must place a function OFF its input's bytes (load
    skew beat the locality credit), it calls :meth:`kick` at the placement
    DECISION — before the ``scheduling.placed`` event even publishes —
    instead of leaving the relay to start when the data path reacts to the
    trigger. The relay leads the cluster :class:`RelayTable`, so the
    CSP/SDP ship that follows the trigger becomes its follower and the
    bytes cross the fabric exactly once; and it pulls from the *best*
    holder the :class:`~repro.runtime.registry.DigestRegistry` knows
    (fastest channel into the target), not necessarily the original
    source — a WAN source with an edge-local replica never re-ships over
    the WAN."""

    def __init__(self, cluster):
        self.cluster = cluster
        self._lock = threading.Lock()
        self.stats = {"kicks": 0, "relays": 0, "skipped": 0, "failed": 0}

    def _bump(self, key: str) -> None:
        with self._lock:
            self.stats[key] += 1

    def kick(self, digest: Optional[str], target_name: str,
             compression: str = "none") -> bool:
        """Start relaying ``digest``'s bytes toward ``target_name`` if they
        resolve somewhere else and no relay is already in flight. The
        relay-table lead is taken synchronously (so a racing CSP/SDP ship
        follows instead of double-shipping); the bytes move on a daemon
        thread. ``compression`` is the EDGE's wire codec — the prefetch
        relay replaces the CSP/SDP ship, so it must honor the same policy
        (a WAN edge's compression must not be lost because the scheduler
        moved the bytes first). Returns True iff a relay was started."""
        cluster = self.cluster
        registry = getattr(cluster, "digests", None)
        relays = getattr(cluster, "relays", None)
        if digest is None or registry is None or relays is None:
            return False
        target = cluster.node(target_name)
        if target.buffer.find_digest(digest):
            self._bump("skipped")             # already resident
            return False
        # drop_node clears the registry on death, but a racing crash can
        # still leave a phantom holder in this snapshot — never relay from
        # a dead node
        holders = [n for n in registry.nodes_for(digest)
                   if n != target_name
                   and getattr(cluster.nodes.get(n), "alive", True)]
        if not holders:
            self._bump("skipped")             # nothing to relay from
            return False
        src = max((cluster.node(n) for n in holders),
                  key=lambda n: cluster.network.channel(n, target).bandwidth)
        lead, _ev = relays.lead_or_follow(digest, target_name)
        if not lead:
            self._bump("skipped")             # a relay is already in flight
            return False
        self._bump("kicks")
        EXECUTOR.submit(self._relay, args=(digest, src, target, compression),
                        name=f"prefetch-{digest[:8]}")
        return True

    def _relay(self, digest: str, src, target, compression: str) -> None:
        try:
            key = src.buffer.find_digest(digest)
            data = src.buffer.get(key) if key is not None else None
            if data is None:                  # holder evicted under us
                self._bump("failed")
                return
            _ship_direct(self.cluster, src, target, f"cas/{digest}", data,
                         stream=True, digest=digest,
                         chunk_bytes=DEFAULT_CHUNK_BYTES,
                         codec=resolve_codec(compression))
            self._bump("relays")
        except BaseException:  # noqa: BLE001 — prefetch is best-effort
            self._bump("failed")
        finally:
            # success or failure, release followers: they alias the landed
            # bytes or fall through and ship themselves
            self.cluster.relays.finish(digest, target.name)


def join_or_stall(th, record: LifecycleRecord,
                  timeout_s: float, what: str) -> None:
    """Join the data-path task (a pool :class:`~repro.runtime.executor.Task`
    or a bare Thread); one outliving its budget is recorded on the
    lifecycle record and raised instead of silently leaked."""
    th.join(timeout=timeout_s)
    if th.is_alive():
        record.transfer_stalled = True
        raise TransferStallError(
            f"{what} still running after {timeout_s}s join budget",
            record=record)
