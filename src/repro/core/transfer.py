"""Shared data-plane helpers for SDP/CSP (one implementation of the
ship-payload decision tree and the stall-guarded thread join, so the two
paths cannot diverge).

Knobs: ``stream`` relays at chunk granularity (``chunk_bytes``, default
1 MiB) into an in-flight buffer entry; ``dedup`` aliases the target's
content-addressed index on a hit instead of shipping bytes.

Relay batching (ROADMAP "one relay stream"): concurrent passes of the SAME
content to the SAME node — a fan-out stage placed locality-aware lands all
its sinks on one node — share a single relay via the cluster's
:class:`RelayTable`. The first pass ships; followers wait on its completion
and alias the landed bytes (``record.relay_shared``), instead of each
re-shipping the payload over the fabric."""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

from repro.core.errors import TransferStallError
from repro.runtime.function import LifecycleRecord
from repro.runtime.netsim import DEFAULT_CHUNK_BYTES

#: wall-seconds a follower waits for the leader's relay before giving up
#: and shipping on its own (matches the SDP/CSP join budget order)
RELAY_WAIT_S = 120.0


class RelayTable:
    """In-flight relay registry: (digest, target node) → completion event.

    ``lead_or_follow`` elects exactly one shipper per (content, node) pair;
    everyone else blocks on the leader's event and then aliases. Entries are
    removed on completion (success or failure), so a failed leader's
    followers fall back to shipping themselves."""

    def __init__(self):
        self._lock = threading.Lock()
        self._inflight: Dict[Tuple[str, str], threading.Event] = {}
        self.stats = {"leads": 0, "follows": 0}

    def lead_or_follow(self, digest: str,
                       node_name: str) -> Tuple[bool, threading.Event]:
        key = (digest, node_name)
        with self._lock:
            ev = self._inflight.get(key)
            if ev is not None:
                self.stats["follows"] += 1
                return False, ev
            ev = threading.Event()
            self._inflight[key] = ev
            self.stats["leads"] += 1
            return True, ev

    def finish(self, digest: str, node_name: str) -> None:
        with self._lock:
            ev = self._inflight.pop((digest, node_name), None)
        if ev is not None:
            ev.set()


def pin_of(cluster, fn: str) -> Optional[str]:
    """The node name ``fn`` is affinity-pinned to, if any."""
    spec = cluster.platform._specs.get(fn)
    return spec.affinity if spec is not None else None


def seed_content(cluster, node, fn: str, data: bytes, digest: str) -> None:
    """Seed dedup'd content into ``node``'s buffer under ``cas/<digest>``
    BEFORE the trigger fires, so the digest registry sees the bytes and the
    locality-aware scheduler can place ``fn`` on them (the pass then
    degenerates to a local alias). One implementation for CSP and SDP — the
    seeding gate must not diverge between the two paths. alias-first avoids
    registry churn on repeat passes; a target pinned to another node can
    never use the seed, so the copy is skipped."""
    pin = pin_of(cluster, fn)
    if pin is not None and pin != node.name:
        return
    cas_key = f"cas/{digest}"
    if not node.buffer.alias(cas_key, digest):
        node.buffer.set(cas_key, data, digest=digest)


def ship_payload(cluster, src_node, target, buf_key: str, data: bytes, *,
                 stream: bool, digest: Optional[str],
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 record: Optional[LifecycleRecord] = None) -> None:
    """Move an inline payload into ``target``'s buffer: dedup alias if the
    content is already resident, piggyback on an in-flight relay of the same
    content, else chunk-streamed or whole-blob over the fabric (local
    placement skips the network entirely)."""
    if digest is not None and target.buffer.alias(buf_key, digest):
        if record is not None:
            record.dedup_hit = True           # content already resident
        return

    relays = getattr(cluster, "relays", None)
    if digest is not None and relays is not None:
        lead, ev = relays.lead_or_follow(digest, target.name)
        if lead:
            try:
                _ship_direct(cluster, src_node, target, buf_key, data,
                             stream=stream, digest=digest,
                             chunk_bytes=chunk_bytes)
            finally:
                relays.finish(digest, target.name)
            return
        # follower: one relay of these bytes is already in flight to this
        # node — wait for it, then alias instead of re-shipping
        ev.wait(RELAY_WAIT_S)
        if target.buffer.alias(buf_key, digest):
            if record is not None:
                record.dedup_hit = True
                record.relay_shared = True
            return
        # leader failed or its entry was evicted before we aliased:
        # fall through and ship ourselves

    _ship_direct(cluster, src_node, target, buf_key, data, stream=stream,
                 digest=digest, chunk_bytes=chunk_bytes)


def _ship_direct(cluster, src_node, target, buf_key: str, data: bytes, *,
                 stream: bool, digest: Optional[str],
                 chunk_bytes: int) -> None:
    if target.name != src_node.name:
        if stream:
            target.buffer.ingest(
                buf_key, cluster.stream(src_node, target, data, chunk_bytes),
                digest=digest)
        else:
            cluster.transfer(src_node, target, data)   # during cold start
            target.buffer.set(buf_key, data, digest=digest)
    else:
        src_node.buffer.set(buf_key, data, digest=digest)


def join_or_stall(th: threading.Thread, record: LifecycleRecord,
                  timeout_s: float, what: str) -> None:
    """Join the data-path thread; a thread outliving its budget is recorded
    on the lifecycle record and raised instead of silently leaked."""
    th.join(timeout=timeout_s)
    if th.is_alive():
        record.transfer_stalled = True
        raise TransferStallError(
            f"{what} still running after {timeout_s}s join budget",
            record=record)
