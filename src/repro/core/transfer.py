"""Shared data-plane helpers for SDP/CSP (one implementation of the
ship-payload decision tree and the stall-guarded thread join, so the two
paths cannot diverge).

Knobs: ``stream`` relays at chunk granularity (``chunk_bytes``, default
1 MiB) into an in-flight buffer entry; ``dedup`` aliases the target's
content-addressed index on a hit instead of shipping bytes."""
from __future__ import annotations

import threading
from typing import Optional

from repro.core.errors import TransferStallError
from repro.runtime.function import LifecycleRecord
from repro.runtime.netsim import DEFAULT_CHUNK_BYTES


def ship_payload(cluster, src_node, target, buf_key: str, data: bytes, *,
                 stream: bool, digest: Optional[str],
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 record: Optional[LifecycleRecord] = None) -> None:
    """Move an inline payload into ``target``'s buffer: dedup alias if the
    content is already resident, else chunk-streamed or whole-blob over the
    fabric (local placement skips the network entirely)."""
    if digest is not None and target.buffer.alias(buf_key, digest):
        if record is not None:
            record.dedup_hit = True           # content already resident
    elif target.name != src_node.name:
        if stream:
            target.buffer.ingest(
                buf_key, cluster.stream(src_node, target, data, chunk_bytes),
                digest=digest)
        else:
            cluster.transfer(src_node, target, data)   # during cold start
            target.buffer.set(buf_key, data, digest=digest)
    else:
        src_node.buffer.set(buf_key, data, digest=digest)


def join_or_stall(th: threading.Thread, record: LifecycleRecord,
                  timeout_s: float, what: str) -> None:
    """Join the data-path thread; a thread outliving its budget is recorded
    on the lifecycle record and raised instead of silently leaked."""
    th.join(timeout=timeout_s)
    if th.is_alive():
        record.transfer_stalled = True
        raise TransferStallError(
            f"{what} still running after {timeout_s}s join budget",
            record=record)
