"""Truffle data-plane errors."""
from __future__ import annotations

from typing import Optional


class WorkflowCycleError(ValueError):
    """The workflow DAG contains a dependency cycle. Raised by
    ``Workflow.topo_order`` / ``WorkflowBuilder.build`` / ``Planner.compile``
    instead of recursing forever; names the offending cycle so the author
    can see exactly which ``after(...)`` edge closed it."""

    def __init__(self, cycle):
        self.cycle = list(cycle)
        super().__init__("workflow dependency cycle: "
                         + " -> ".join(self.cycle))


class PlanError(ValueError):
    """A workflow + policy combination cannot be compiled into a coherent
    ExecutionPlan (e.g. two in-edges of one stage declare different
    ``strategy`` values, so the stage's input has no single home)."""


class TransferStallError(RuntimeError):
    """A data-path transfer thread outlived its join budget: the function
    already returned but its transfer never finished (wedged channel,
    stuck storage client). Carries the lifecycle record — the stall is
    recorded there (``transfer_stalled``) before raising, so callers and
    post-mortems see it instead of a silently-leaked daemon thread."""

    def __init__(self, message: str, record: Optional[object] = None):
        super().__init__(message)
        self.record = record
