"""Truffle data-plane errors."""
from __future__ import annotations

from typing import Optional


class TransferStallError(RuntimeError):
    """A data-path transfer thread outlived its join budget: the function
    already returned but its transfer never finished (wedged channel,
    stuck storage client). Carries the lifecycle record — the stall is
    recorded there (``transfer_stalled``) before raising, so callers and
    post-mortems see it instead of a silently-leaked daemon thread."""

    def __init__(self, message: str, record: Optional[object] = None):
        super().__init__(message)
        self.record = record
