"""Truffle data-plane errors."""
from __future__ import annotations

from typing import Optional


class WorkflowCycleError(ValueError):
    """The workflow DAG contains a dependency cycle. Raised by
    ``Workflow.topo_order`` / ``WorkflowBuilder.build`` / ``Planner.compile``
    instead of recursing forever; names the offending cycle so the author
    can see exactly which ``after(...)`` edge closed it."""

    def __init__(self, cycle):
        self.cycle = list(cycle)
        super().__init__("workflow dependency cycle: "
                         + " -> ".join(self.cycle))


class PlanError(ValueError):
    """A workflow + policy combination cannot be compiled into a coherent
    ExecutionPlan (e.g. two in-edges of one stage declare different
    ``strategy`` values, so the stage's input has no single home)."""


class TransferStallError(RuntimeError):
    """A data-path transfer thread outlived its join budget: the function
    already returned but its transfer never finished (wedged channel,
    stuck storage client). Carries the lifecycle record — the stall is
    recorded there (``transfer_stalled``) before raising, so callers and
    post-mortems see it instead of a silently-leaked daemon thread."""

    def __init__(self, message: str, record: Optional[object] = None):
        super().__init__(message)
        self.record = record


class NodeCrashError(RuntimeError):
    """An operation touched a node that is not alive: provisioning or
    executing on it, passing data from it, or an affinity pin naming it.
    ``node`` is the dead node's name (None when no live node exists at
    all). Classified as an infrastructure fault by the retry layer — the
    next attempt is steered to a different, health-scored node."""

    def __init__(self, node: Optional[str], message: Optional[str] = None):
        self.node = node
        super().__init__(message or f"node {node!r} is not alive")


class LinkDownError(RuntimeError):
    """A fabric transfer hit a channel whose endpoint node went dark
    (``NetworkFabric.set_node_down``). Raised at transfer start and
    per-chunk mid-stream, so in-flight streams fail fast instead of
    pricing bytes against a dead endpoint."""


class BufferOfflineError(IOError):
    """The node-local Truffle buffer is offline (its node crashed and the
    CAS contents were wiped). All reads/writes fail fast; waiters parked
    in ``wait_for``/``BufferReader`` are woken and raised out."""


#: What a dead or partitioned peer can throw at a best-effort data-plane
#: operation (poisoning a remote buffer, evacuating CAS content, a relay
#: hop): the node died (NodeCrashError / KeyError for a deregistered
#: node), the link went dark (LinkDownError), the buffer was wiped
#: (BufferOfflineError and other IOErrors), or the operation timed out.
#: Best-effort callers catch THIS tuple — a typed contract — instead of
#: a blanket ``except Exception`` that would also swallow programming
#: errors (AttributeError, TypeError) silently.
DATA_PLANE_FAULTS = (NodeCrashError, LinkDownError, TransferStallError,
                     IOError, KeyError, TimeoutError)


class StageExecutionError(RuntimeError):
    """A workflow stage exhausted its retry budget (or had none). Carries
    the failure context the raw errbox propagation used to drop: which
    stage, on which node, after how many attempts, caused by what — plus
    the last attempt's ``LifecycleRecord`` when one was produced. The
    original exception is both ``cause`` and ``__cause__``."""

    def __init__(self, stage: str, node: Optional[str] = None,
                 attempt: int = 1, cause: Optional[BaseException] = None,
                 record: Optional[object] = None):
        self.stage = stage
        self.node = node
        self.attempt = attempt
        self.cause = cause
        self.record = record
        super().__init__(
            f"stage {stage!r} failed on node {node!r} "
            f"after {attempt} attempt(s): {cause!r}")
        if cause is not None:
            self.__cause__ = cause
