"""Truffle Buffer: per-node content store holding input data until the
target function is fully provisioned (paper §III-B.1e).

Local by design (high-speed in-memory access next to the function); capacity
bounded with LRU eviction of unpinned entries; ``wait_for`` lets a starting
function block until its input lands (the CSP/SDP rendezvous point).

Streaming entries (chunked data plane): ``open_stream`` creates an in-flight
entry, ``append_chunk`` lands chunks as they arrive off the wire, and
``close_stream`` seals it. ``open_reader`` returns a :class:`BufferReader`
that blocks *per chunk*, so a cold-starting function begins consuming its
input at first-chunk arrival instead of last-byte. In-flight streams are
never evicted; a whole-blob ``set`` is just a one-chunk stream.

Content addressing: complete entries may carry a digest
(:func:`content_digest`, BLAKE2b-128) registered in a per-buffer index.
``alias`` lets fan-out workflows and repeated inputs reuse the stored chunks
under a new invocation key with zero copy and zero transfer (dedup hit).

Knobs: ``capacity_bytes`` bounds resident bytes (LRU over complete unpinned
entries, O(1) amortized eviction); chunk size is chosen by the writer.
With a ``replica_oracle`` wired (Cluster does, from the DigestRegistry),
eviction is residency-aware: replicas that still resolve on another node
go first, and the cluster's last copy of a digest survives LRU pressure
while any other victim remains.

Residency reporting: assigning ``on_residency`` (a callable
``(digest, size, resident: bool) -> None``) makes the buffer report every
digest that becomes resolvable (set/close/ingest/alias) or stops resolving
(evict/displace) — the hook the cluster-wide
:class:`~repro.runtime.registry.DigestRegistry` hangs off for
locality-aware placement. Callbacks fire *after* the buffer lock is
released (queued under the lock, flushed outside), so listeners may safely
call back into the buffer or take their own locks.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.errors import BufferOfflineError


def content_digest(data) -> str:
    """Content address of a payload (BLAKE2b-128: fast, ample for dedup).

    Hashes the buffer protocol directly — bytes, bytearray, and memoryview
    inputs are digested with ZERO copies (the old ``bytes(data)`` duplicated
    a 128 MB payload just to hash it)."""
    return hashlib.blake2b(data, digest_size=16).hexdigest()


class IncrementalDigest:
    """Streaming content address: fold chunks as they land. BLAKE2b is
    sequential, so ``hexdigest()`` after N ``update`` calls equals
    :func:`content_digest` of the joined blob — streaming entries get
    content-addressed without ever joining (or re-reading) their chunks.
    ``seed`` prefixes namespace salt bytes (tenant-isolated CAS)."""

    __slots__ = ("_h", "n_bytes")

    def __init__(self, seed: bytes = b"") -> None:
        self._h = hashlib.blake2b(digest_size=16)
        self.n_bytes = 0
        if seed:
            self._h.update(seed)

    def update(self, chunk) -> None:
        self._h.update(chunk)
        self.n_bytes += len(chunk)

    def hexdigest(self) -> str:
        return self._h.hexdigest()


@dataclass
class BufferEntry:
    key: str
    created: float
    pinned: bool = False
    digest: Optional[str] = None
    chunks: List[bytes] = field(default_factory=list)
    complete: bool = True
    aborted: bool = False
    size: int = 0
    #: backpressure high-water mark: with a bound set, ``append_chunk``
    #: blocks while unconsumed in-flight bytes (size - consumed) would
    #: exceed it. None = unbounded (the pre-pipelining behavior).
    highwater: Optional[int] = None
    #: bytes consumed by the furthest reader (releases backpressure)
    consumed: int = 0
    #: incremental per-chunk hash (``open_stream(track_digest=True)``):
    #: folded on every append so close never re-hashes the joined blob
    hasher: Optional[IncrementalDigest] = None
    _joined: Optional[bytes] = None     # cached join of chunks

    @property
    def data(self) -> bytes:
        if self._joined is None:
            if len(self.chunks) == 1 and isinstance(self.chunks[0], bytes):
                self._joined = self.chunks[0]
            else:                       # joins bytes and memoryview chunks
                self._joined = b"".join(self.chunks)
        return self._joined


class BufferReader:
    """Chunk iterator over a (possibly in-flight) entry.

    ``__next__`` blocks until the next chunk lands or the stream completes;
    holding a reference to the entry keeps its chunks alive across eviction.
    """

    def __init__(self, buffer: "Buffer", key: str,
                 timeout: Optional[float] = None):
        self._buffer = buffer
        self._key = key
        self._timeout = timeout
        self._entry: Optional[BufferEntry] = None
        self._idx = 0
        self._consumed = 0          # bytes this reader has taken

    def __iter__(self) -> "BufferReader":
        return self

    def __next__(self) -> bytes:
        buf = self._buffer
        deadline = (None if self._timeout is None
                    else time.monotonic() + self._timeout)
        with buf._cond:
            while True:
                buf._check_online_locked()
                if self._entry is None:
                    self._entry = buf._entries.get(self._key)
                e = self._entry
                if e is not None:
                    if e.aborted:          # writer failed mid-stream
                        raise IOError(
                            f"{buf.name}: stream {self._key!r} aborted")
                    if self._idx < len(e.chunks):
                        chunk = e.chunks[self._idx]
                        self._idx += 1
                        self._consumed += len(chunk)
                        if self._consumed > e.consumed:
                            # furthest reader advanced: release backpressure
                            e.consumed = self._consumed
                            if e.highwater is not None:
                                buf._cond.notify_all()
                        return chunk
                    if e.complete:
                        raise StopIteration
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"{buf.name}: chunk {self._idx} of {self._key!r} "
                        f"never arrived")
                buf._cond.wait(remaining)


class Buffer:
    def __init__(self, capacity_bytes: int = 2 << 30, name: str = "buffer"):
        self.name = name
        self.capacity = capacity_bytes
        self._entries: "OrderedDict[str, BufferEntry]" = OrderedDict()
        # Evictable keys (complete + unpinned) in LRU order; front = oldest.
        self._lru: "OrderedDict[str, None]" = OrderedDict()
        self._digests: Dict[str, str] = {}       # digest -> key
        self._size = 0
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self.stats = {"puts": 0, "gets": 0, "waits": 0, "evictions": 0,
                      "dedup_hits": 0, "streams": 0, "bp_waits": 0,
                      "alias_promotions": 0}
        #: residency listener: (digest, size, resident) — see module docstring
        self.on_residency: Optional[Callable[[str, int, bool], None]] = None
        #: residency-aware eviction oracle: ``digest -> True`` when the
        #: content still resolves on some OTHER node (wired by Cluster from
        #: the DigestRegistry). With an oracle set, eviction sheds replicas
        #: that exist elsewhere first and keeps the cluster's LAST copy of
        #: a digest alive as long as any other victim is available.
        self.replica_oracle: Optional[Callable[[str], bool]] = None
        self._pending_residency: List[tuple] = []    # queued under the lock
        # serializes flushes so a preempted flusher cannot deliver a stale
        # "resident" AFTER another thread delivered the matching "evicted"
        # (RLock: a listener may mutate the buffer and re-enter the flush)
        self._flush_lock = threading.RLock()
        # node crashed: all IO fails fast until revive() (see clear())
        self._offline = False

    # -------------------------------------------------- crash/offline state
    def _check_online_locked(self) -> None:
        if self._offline:
            raise BufferOfflineError(
                f"{self.name}: buffer offline (node crashed)")

    def clear(self, offline: bool = False) -> int:
        """Wipe every entry — the CAS loss of a node crash. Residency
        withdrawals fire for each digest (the DigestRegistry forgets these
        replicas), in-flight streams abort, and blocked waiters/readers
        wake. ``offline=True`` additionally fails all subsequent IO with
        :class:`BufferOfflineError` until :meth:`revive`. Returns the
        number of entries dropped."""
        with self._cond:
            keys = list(self._entries)
            for key in keys:
                self._drop_locked(key)
            if offline:
                self._offline = True
            self._cond.notify_all()
        self._flush_residency()
        return len(keys)

    def revive(self) -> None:
        """Restart: the buffer comes back empty but serving IO again."""
        with self._cond:
            self._offline = False
            self._cond.notify_all()

    def poison(self, key: str, reason: str = "transfer failed") -> bool:
        """Mark ``key`` as failed-for-good: the data path that was going
        to land it died (source crashed mid-ship, link went dark). A
        waiter parked in :meth:`wait_for` — or a chunk reader — wakes
        immediately and raises instead of burning its full timeout.
        Content that landed completely before the poison wins the race
        (returns False, nothing marked). The waiter that observes the
        poison consumes it (entry popped), so a later retry may reuse
        the key."""
        with self._cond:
            e = self._entries.get(key)
            if e is not None and e.complete:
                return False
            if e is None:
                # sentinel: incomplete + aborted, size 0, not in the LRU
                e = BufferEntry(key, time.monotonic(), False,
                                chunks=[], complete=False, size=0)
                self._entries[key] = e
            e.aborted = True
            self._cond.notify_all()
        return True

    # ------------------------------------------------- residency reporting
    def _queue_residency_locked(self, digest: str, size: int,
                                resident: bool) -> None:
        if self.on_residency is not None and digest is not None:
            self._pending_residency.append((digest, size, resident))

    def _flush_residency(self) -> None:
        """Deliver queued residency events outside the buffer lock. The
        flush lock keeps deliveries in queue order across threads."""
        cb = self.on_residency
        if cb is None:
            return
        # unlocked peek: get/wait_for on the data-plane hot path almost
        # never queue events; skip both locks then. (Benign race: whoever
        # queued an event flushes it after releasing the buffer lock.)
        if not self._pending_residency:
            return
        with self._flush_lock:
            with self._lock:
                events, self._pending_residency = self._pending_residency, []
            for digest, size, resident in events:
                cb(digest, size, resident)

    # ------------------------------------------------------------ whole blob
    def set(self, key: str, data: bytes, pinned: bool = False,
            digest: Optional[str] = None) -> None:
        with self._cond:
            self._check_online_locked()
            self._drop_locked(key)
            e = BufferEntry(key, time.monotonic(), pinned, digest,
                            chunks=[data], complete=True, size=len(data))
            self._insert_locked(e)
            self.stats["puts"] += 1
            self._evict_locked(exempt=key)
            self._cond.notify_all()
        self._flush_residency()

    def get(self, key: str, pop: bool = False) -> Optional[bytes]:
        with self._lock:
            self._check_online_locked()
            e = self._entries.get(key)
            if e is None or not e.complete:
                return None
            self.stats["gets"] += 1
            if pop:
                self._drop_locked(key)
            else:
                self._touch_locked(e)
            data = e.data
        self._flush_residency()
        return data

    def wait_for(self, key: str, timeout: Optional[float] = None,
                 pop: bool = False) -> Optional[bytes]:
        """Block until ``key`` is present AND complete (streams included).

        The entry's data is returned under the same lock hold that observed
        completion: re-acquiring the lock for a trailing ``get`` would let a
        racing eviction (or same-key displacement) turn a successful wait
        into a spurious ``None``."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            self.stats["waits"] += 1
            while True:
                self._check_online_locked()
                e = self._entries.get(key)
                if e is not None and e.aborted:
                    self._drop_locked(key)       # consume the poison
                    raise IOError(f"{self.name}: input {key!r} aborted "
                                  f"(its data path failed)")
                if e is not None and e.complete:
                    self.stats["gets"] += 1
                    if pop:
                        self._drop_locked(key)
                    else:
                        self._touch_locked(e)
                    data = e.data
                    break
                if e is not None and e.highwater is not None:
                    # a whole-blob waiter cannot drain mid-stream: lift the
                    # backpressure bound or the writer and this waiter
                    # deadlock (writer blocked at highwater, us at complete)
                    e.highwater = None
                    self._cond.notify_all()
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return None
                self._cond.wait(remaining)
        self._flush_residency()
        return data

    def drop(self, key: str) -> bool:
        """Administratively drop a COMPLETE entry (fleet quota pressure,
        tenant eviction). In-flight streams are left to their writers —
        aborting them is ``abort_stream``'s job. Fires the residency
        withdrawal like any eviction, so the registry (and any ledgers on
        it) see the bytes leave. Returns whether an entry was dropped."""
        with self._cond:
            e = self._entries.get(key)
            if e is None or not e.complete:
                return False
            self._drop_locked(key)
            self._cond.notify_all()
        self._flush_residency()
        return True

    # ------------------------------------------------------------- streaming
    def open_stream(self, key: str, pinned: bool = False,
                    highwater: Optional[int] = None,
                    track_digest: bool = False) -> None:
        """Create an in-flight entry; chunks land via ``append_chunk``.
        Incomplete streams are invisible to get/wait_for and never evicted.
        With ``highwater`` set, appends block once unconsumed in-flight
        bytes reach the mark until a reader drains (pipelined edges bound
        their buffering this way). ``track_digest`` folds an incremental
        BLAKE2b over the chunks as they land, so ``close_stream`` can seal
        the entry content-addressed without re-hashing the joined blob
        (``stream_digest`` reads the running value)."""
        with self._cond:
            self._check_online_locked()
            self._drop_locked(key)
            e = BufferEntry(key, time.monotonic(), pinned,
                            chunks=[], complete=False, size=0,
                            highwater=highwater,
                            hasher=IncrementalDigest() if track_digest
                            else None)
            self._insert_locked(e)
            self.stats["streams"] += 1
            self._cond.notify_all()
        self._flush_residency()

    def append_chunk(self, key: str, chunk: bytes) -> None:
        with self._cond:
            e = self._entries.get(key)
            if e is None or e.complete:
                raise KeyError(f"{self.name}: no open stream {key!r}")
            self._append_entry_locked(e, chunk)
            self._cond.notify_all()

    def _append_entry_locked(self, e: BufferEntry, chunk: bytes) -> None:
        while True:
            self._check_online_locked()
            if e.aborted or e.complete:
                raise IOError(f"{self.name}: stream {e.key!r} no longer open")
            if self._entries.get(e.key) is not e:
                # displaced by a same-key open/set: fail the zombie writer
                # NOW instead of letting it grow e.size uncharged until close
                e.aborted = True
                raise IOError(f"{self.name}: stream {e.key!r} displaced")
            if (e.highwater is None or not e.chunks
                    or e.size - e.consumed < e.highwater):
                break                     # room (first chunk always admitted)
            self.stats["bp_waits"] += 1
            self._cond.wait()             # reader drain / abort / offline wake
        e.chunks.append(chunk)
        if e.hasher is not None:
            e.hasher.update(chunk)
        e.size += len(chunk)
        self._size += len(chunk)

    def abort_stream(self, key: str) -> None:
        """Drop an in-flight entry (writer failed mid-stream). Without this
        the incomplete entry — invisible to get/wait_for and exempt from
        eviction — would leak its appended chunks forever. Blocked readers
        wake with an IOError rather than seeing a truncated input."""
        with self._cond:
            e = self._entries.get(key)
            if e is not None and not e.complete:
                self._drop_locked(key)
            self._cond.notify_all()
        self._flush_residency()

    def close_stream(self, key: str, digest: Optional[str] = None) -> None:
        with self._cond:
            e = self._entries.get(key)
            if e is None or e.complete:
                raise KeyError(f"{self.name}: no open stream {key!r}")
            if digest is None and e.hasher is not None:
                # tracked stream: seal content-addressed from the running
                # per-chunk hash — the joined blob is never re-hashed
                digest = e.hasher.hexdigest()
            e.complete = True
            e.digest = digest
            if digest is not None:
                self._digests.setdefault(digest, key)
                self._queue_residency_locked(digest, e.size, True)
            if not e.pinned:
                self._lru[key] = None           # becomes evictable now
            self.stats["puts"] += 1
            self._evict_locked(exempt=key)
            self._cond.notify_all()
        self._flush_residency()

    def ingest(self, key: str, chunks, digest: Optional[str] = None,
               highwater: Optional[int] = None) -> int:
        """Stream an iterable of chunks into a new entry: open → append as
        each chunk arrives → close. Writer-safe under same-key races: this
        writer holds its own entry, so if another open/set displaces it the
        writer fails (IOError) immediately instead of interleaving chunks
        into the successor. With ``highwater`` set, appends block while
        unconsumed in-flight bytes exceed the mark (backpressure against
        the producer). On any failure the entry is aborted (readers wake
        with IOError) and the error re-raised. Returns the bytes ingested."""
        with self._cond:
            self._check_online_locked()
            self._drop_locked(key)
            e = BufferEntry(key, time.monotonic(), False,
                            chunks=[], complete=False, size=0,
                            highwater=highwater)
            self._insert_locked(e)
            self.stats["streams"] += 1
            self._cond.notify_all()
        self._flush_residency()
        n = 0
        try:
            for chunk in chunks:
                with self._cond:
                    self._append_entry_locked(e, chunk)
                    self._cond.notify_all()
                n += len(chunk)
            with self._cond:
                if e.aborted:
                    raise IOError(f"{self.name}: stream {key!r} displaced")
                e.complete = True
                e.digest = digest
                if digest is not None:
                    self._digests.setdefault(digest, key)
                    self._queue_residency_locked(digest, e.size, True)
                if not e.pinned:
                    self._lru[key] = None
                self.stats["puts"] += 1
                self._evict_locked(exempt=key)
                self._cond.notify_all()
            self._flush_residency()
        except BaseException:
            with self._cond:
                if self._entries.get(key) is e:
                    self._drop_locked(key)
                else:
                    e.aborted = True          # wake readers bound to us
                self._cond.notify_all()
            self._flush_residency()
            raise
        return n

    def open_reader(self, key: str,
                    timeout: Optional[float] = None) -> BufferReader:
        """Chunk-granular reader; works on in-flight streams and complete
        entries alike (a ``set`` blob reads as one chunk)."""
        return BufferReader(self, key, timeout)

    def stream_digest(self, key: str) -> Optional[str]:
        """Running (or final) incremental digest of a ``track_digest``
        stream — the content address of every chunk landed so far. None
        for untracked or unknown keys."""
        with self._lock:
            e = self._entries.get(key)
            if e is None or e.hasher is None:
                return None
            return e.hasher.hexdigest()

    # ------------------------------------------------- content addressing
    def find_digest(self, digest: Optional[str]) -> Optional[str]:
        """Key currently holding this content, if any."""
        if digest is None:
            return None
        with self._lock:
            key = self._digests.get(digest)
            if key is None:
                return None
            e = self._entries.get(key)
            return key if e is not None and e.complete else None

    def alias(self, new_key: str, digest: Optional[str],
              pinned: bool = False) -> bool:
        """Dedup hit: expose existing content under ``new_key`` without
        copying or re-shipping bytes. Returns True if the digest was found.

        Aliases share the source's chunk list, so they are charged size 0
        against capacity (the bytes are counted once, on the owning entry).
        If the owner is evicted or dropped while aliases survive, one alias
        is PROMOTED to owner — it inherits the byte charge and the digest
        mapping — so shared chunks are never resident-but-uncharged."""
        if digest is None:
            return False
        with self._cond:
            self._check_online_locked()
            src_key = self._digests.get(digest)
            src = self._entries.get(src_key) if src_key else None
            if src is None or not src.complete:
                return False
            if src_key == new_key:            # content already under this key
                self.stats["dedup_hits"] += 1
                # refresh residency (paper: alias confirms the bytes are live)
                self._queue_residency_locked(digest, src.size, True)
            else:
                self._drop_locked(new_key)
                e = BufferEntry(new_key, time.monotonic(), pinned, digest,
                                chunks=src.chunks, complete=True, size=0)
                e._joined = src._joined
                self._insert_locked(e)
                self.stats["dedup_hits"] += 1
                self._queue_residency_locked(digest, src.size, True)
            self._cond.notify_all()
        self._flush_residency()
        return True

    # -------------------------------------------------------------- internal
    def _insert_locked(self, e: BufferEntry) -> None:
        self._entries[e.key] = e
        self._size += e.size
        if e.complete:
            if e.digest is not None:
                # don't repoint an existing mapping (e.g. an alias's digest
                # keeps resolving to the charged source entry)
                self._digests.setdefault(e.digest, e.key)
                # alias entries (charged size 0) are reported by alias()
                # with the source entry's real size instead
                if e.size > 0:
                    self._queue_residency_locked(e.digest, e.size, True)
            if not e.pinned:
                self._lru[e.key] = None
        # in-flight / pinned entries stay out of the LRU

    def _drop_locked(self, key: str) -> None:
        e = self._entries.pop(key, None)
        if e is None:
            return
        if not e.complete:
            # an in-flight stream displaced (abort, same-key re-open, or
            # replacement): its writer and any bound readers must fail fast,
            # not interleave into / hang on the successor entry
            e.aborted = True
        self._size -= e.size
        self._lru.pop(key, None)
        self._retire_owner_locked(e)

    def _retire_owner_locked(self, e: BufferEntry) -> None:
        """``e`` (already uncharged and popped) is leaving. If it owned its
        digest's bytes and an alias sharing its chunk list survives, promote
        that alias to owner: re-charge the real size against capacity and
        repoint the digest mapping, so shared chunks are never resident but
        uncharged. Otherwise withdraw the digest (residency goodbye)."""
        if e.digest is None or self._digests.get(e.digest) != e.key:
            return
        heir = None
        if e.size > 0:                     # only byte owners need an heir
            for other in self._entries.values():
                if (other is not e and other.complete and not other.aborted
                        and other.chunks is e.chunks):
                    heir = other
                    break
        if heir is None:
            del self._digests[e.digest]
            self._queue_residency_locked(e.digest, e.size, False)
        else:
            self._digests[e.digest] = heir.key
            self._size += e.size - heir.size
            heir.size = e.size
            self.stats["alias_promotions"] += 1
            # bytes stay resident under the heir: no residency withdrawal

    def _touch_locked(self, e: BufferEntry) -> None:
        self._entries.move_to_end(e.key)
        if e.key in self._lru:
            self._lru.move_to_end(e.key)

    def _evict_locked(self, exempt: Optional[str] = None) -> None:
        """Pop evictable keys until under capacity; pinned and in-flight
        entries are never in ``_lru``, so no scanning past them. ``exempt``
        protects the entry just inserted: evicting it would strand the
        function that is about to wait_for it (it is the newest entry, so
        it surfaces only once everything else evictable is gone).

        Without a ``replica_oracle`` this is the O(1)-amortized plain LRU
        pop. With one, each eviction prefers the LRU victim whose bytes
        are NOT the cluster's last copy — a digest resolving on another
        node (or an entry with no digest at all) goes first, and a sole
        replica is only shed once no other victim remains (an O(n) scan,
        paid only under capacity pressure on registry-wired buffers)."""
        while self._size > self.capacity and self._lru:
            key = self._pick_victim_locked(exempt)
            if key is None:
                return                        # only the new entry is left
            del self._lru[key]
            e = self._entries.pop(key)
            self._size -= e.size
            self._retire_owner_locked(e)
            self.stats["evictions"] += 1

    def _pick_victim_locked(self, exempt: Optional[str]) -> Optional[str]:
        """LRU order, sole-replica entries deferred (see _evict_locked)."""
        oracle = self.replica_oracle
        fallback = None
        for key in self._lru:
            if key == exempt:
                continue
            if oracle is None:
                return key                    # plain LRU: front wins
            digest = self._entries[key].digest
            if digest is None or oracle(digest):
                return key                    # replicated (or anonymous)
            if fallback is None:
                fallback = key                # oldest sole replica
        return fallback

    @property
    def size(self) -> int:
        with self._lock:
            return self._size

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries
