"""Truffle Buffer: per-node content store holding input data until the
target function is fully provisioned (paper §III-B.1e).

Local by design (high-speed in-memory access next to the function); capacity
bounded with LRU eviction of unpinned entries; ``wait_for`` lets a starting
function block until its input lands (the CSP/SDP rendezvous point)."""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class BufferEntry:
    key: str
    data: bytes
    created: float
    pinned: bool = False


class Buffer:
    def __init__(self, capacity_bytes: int = 2 << 30, name: str = "buffer"):
        self.name = name
        self.capacity = capacity_bytes
        self._entries: "OrderedDict[str, BufferEntry]" = OrderedDict()
        self._size = 0
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self.stats = {"puts": 0, "gets": 0, "waits": 0, "evictions": 0}

    def set(self, key: str, data: bytes, pinned: bool = False) -> None:
        with self._cond:
            if key in self._entries:
                self._size -= len(self._entries[key].data)
            self._entries[key] = BufferEntry(key, data, time.monotonic(), pinned)
            self._entries.move_to_end(key)
            self._size += len(data)
            self.stats["puts"] += 1
            self._evict_locked()
            self._cond.notify_all()

    def get(self, key: str, pop: bool = False) -> Optional[bytes]:
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                return None
            self.stats["gets"] += 1
            if pop:
                del self._entries[key]
                self._size -= len(e.data)
            else:
                self._entries.move_to_end(key)
            return e.data

    def wait_for(self, key: str, timeout: Optional[float] = None,
                 pop: bool = False) -> Optional[bytes]:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            self.stats["waits"] += 1
            while key not in self._entries:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return None
                self._cond.wait(remaining)
        return self.get(key, pop=pop)

    def _evict_locked(self) -> None:
        while self._size > self.capacity:
            for k, e in self._entries.items():
                if not e.pinned:
                    del self._entries[k]
                    self._size -= len(e.data)
                    self.stats["evictions"] += 1
                    break
            else:
                return  # everything pinned

    @property
    def size(self) -> int:
        with self._lock:
            return self._size

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries
