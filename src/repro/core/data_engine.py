"""Data Engine (paper §III-B.1c + Algorithm 1): identifies the storage type
of incoming function data via an adapter registry, retrieves it, and stores
it in the node-local Buffer. Extensible: ``register_adapter`` adds storage
types / providers without touching callers."""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional, Tuple

from repro.runtime.function import ContentRef


class StorageAdapter:
    """Adapter facade over a storage service (aws-sdk / go-redis analogue)."""

    def __init__(self, type_name: str, service):
        self.type_name = type_name
        self.service = service

    def get(self, key: str) -> Tuple[bytes, float]:
        return self.service.get(key)

    def put(self, key: str, data: bytes) -> float:
        return self.service.put(key, data)


class DataEngine:
    def __init__(self, node, cluster):
        self.node = node
        self.cluster = cluster
        self._adapters: Dict[str, StorageAdapter] = {}
        for name, svc in cluster.storage.items():
            self.register_adapter(StorageAdapter(name, svc))

    def register_adapter(self, adapter: StorageAdapter) -> None:
        self._adapters[adapter.type_name] = adapter

    def adapter_for(self, ref: ContentRef) -> StorageAdapter:
        """Algorithm 1 lines 8-12: resolve the storage client by type."""
        if ref.storage_type not in self._adapters:
            raise KeyError(f"no storage adapter for {ref.storage_type!r} "
                           f"(have: {list(self._adapters)})")
        return self._adapters[ref.storage_type]

    def fetch(self, ref: ContentRef, buffer_key: Optional[str] = None) -> bytes:
        """Algorithm 1: resolve adapter → get(content_ref) → buffer.set."""
        sc = self.adapter_for(ref)
        data, _ = sc.get(ref.key)                 # line 13: C <- SC.get(C_R)
        self.node.buffer.set(buffer_key or ref.key, data)   # line 14: B.set(C)
        return data
