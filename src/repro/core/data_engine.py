"""Data Engine (paper §III-B.1c + Algorithm 1): identifies the storage type
of incoming function data via an adapter registry, retrieves it, and stores
it in the node-local Buffer. Extensible: ``register_adapter`` adds storage
types / providers without touching callers.

Chunked streaming (``fetch(..., stream=True)``): the storage read is
pipelined chunk-by-chunk into an in-flight buffer entry (``chunk_bytes``
knob, default 1 MiB), so a cold-starting function can begin consuming at
first-chunk arrival; adapters without ``get_stream`` fall back to whole-blob.

Content-addressed dedup (``dedup=True``): the engine resolves the input's
digest (from the ContentRef, or the service's digest index) and checks the
node's buffer first — fan-out workflows and repeated inputs alias the
already-resident chunks and skip the fetch entirely (``stats["dedup_hits"]``).

Relay following: a dedup'd fetch consults the cluster
:class:`~repro.core.transfer.RelayTable` before touching storage. If a
relay of the same content toward this node is already in flight — a
registry-driven prefetch kicked at placement time — the engine waits for
it and aliases the landed bytes instead of issuing a second (storage)
read; otherwise it takes the relay lead itself, so a racing prefetch
becomes *its* follower. Either way the bytes move exactly once
(``stats["relay_follows"]``), which is what lets storage-strategy
(kvs/s3) edges use ``DataPolicy.prefetch``.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Iterator, Optional, Tuple

from repro.runtime.function import ContentRef
from repro.runtime.netsim import DEFAULT_CHUNK_BYTES


class StorageAdapter:
    """Adapter facade over a storage service (aws-sdk / go-redis analogue)."""

    def __init__(self, type_name: str, service):
        self.type_name = type_name
        self.service = service

    def get(self, key: str) -> Tuple[bytes, float]:
        return self.service.get(key)

    def put(self, key: str, data: bytes) -> float:
        return self.service.put(key, data)

    def get_stream(self, key: str,
                   chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> Iterator[bytes]:
        """Chunked read; providers without native streaming degrade to a
        single whole-blob chunk (same bytes, no pipelining)."""
        impl = getattr(self.service, "get_stream", None)
        if impl is not None:
            return impl(key, chunk_bytes)
        data, _ = self.service.get(key)
        return iter((data,))

    def digest(self, key: str) -> Optional[str]:
        impl = getattr(self.service, "digest", None)
        return impl(key) if impl is not None else None


class DataEngine:
    def __init__(self, node, cluster):
        self.node = node
        self.cluster = cluster
        self._adapters: Dict[str, StorageAdapter] = {}
        self.stats = {"fetches": 0, "dedup_hits": 0, "bytes_fetched": 0,
                      "relay_follows": 0}
        for name, svc in cluster.storage.items():
            self.register_adapter(StorageAdapter(name, svc))

    def register_adapter(self, adapter: StorageAdapter) -> None:
        self._adapters[adapter.type_name] = adapter

    def adapter_for(self, ref: ContentRef) -> StorageAdapter:
        """Algorithm 1 lines 8-12: resolve the storage client by type."""
        if ref.storage_type not in self._adapters:
            raise KeyError(f"no storage adapter for {ref.storage_type!r} "
                           f"(have: {list(self._adapters)})")
        return self._adapters[ref.storage_type]

    def fetch(self, ref: ContentRef, buffer_key: Optional[str] = None, *,
              stream: bool = False, chunk_bytes: int = DEFAULT_CHUNK_BYTES,
              dedup: bool = False, record=None,
              policy=None) -> Optional[bytes]:
        """Algorithm 1: resolve adapter → get(content_ref) → buffer.set.

        ``policy`` (a per-edge :class:`~repro.runtime.policy.DataPolicy`)
        is the compiled-plan spelling of the knobs below; when given it
        overrides ``stream``/``dedup``. (Edge ``compression`` does not
        apply here: storage reads are priced by the service adapter, not
        the node fabric.)
        ``stream`` pipelines the read into the buffer chunk-by-chunk and
        returns None — the consumer reads per-chunk via ``open_reader``
        (joining the blob here would add a full extra copy on the hot path).
        ``dedup`` consults the content-addressed index before any I/O (a hit
        is flagged on ``record.dedup_hit`` when a LifecycleRecord is given),
        then the in-flight RelayTable: an already-kicked prefetch relay of
        this content is waited for and aliased instead of double-moving the
        bytes through a storage read.
        """
        if policy is not None:
            stream, dedup = policy.stream, policy.dedup
            chunk_bytes = policy.chunk_bytes or chunk_bytes
        key = buffer_key or ref.key
        sc = self.adapter_for(ref)
        buf = self.node.buffer

        digest = ref.digest
        if dedup:
            if digest is None:
                digest = sc.digest(ref.key)
            if buf.alias(key, digest):            # content already local
                self.stats["dedup_hits"] += 1
                if record is not None:
                    record.dedup_hit = True
                return None if stream else buf.get(key)

        lead = False
        if dedup:
            # a relay of these bytes toward this node may be in flight
            # (registry-driven prefetch): wait and alias — the storage read
            # would move the same bytes a second time. Otherwise take the
            # lead so a racing prefetch becomes OUR follower.
            from repro.core.transfer import relay_lead_or_alias
            lead, aliased = relay_lead_or_alias(self.cluster, digest, buf,
                                                self.node.name, key, record)
            if aliased:
                self.stats["dedup_hits"] += 1
                self.stats["relay_follows"] += 1
                return None if stream else buf.get(key)
        try:
            self.stats["fetches"] += 1
            if stream:
                # pipelined: chunks land in the buffer as they arrive; aborts
                # (and re-raises) on a mid-stream failure instead of leaking
                n = buf.ingest(key, sc.get_stream(ref.key, chunk_bytes),
                               digest=digest)
                self.stats["bytes_fetched"] += n
                return None
            data, _ = sc.get(ref.key)             # line 13: C <- SC.get(C_R)
            self.stats["bytes_fetched"] += len(data)
            buf.set(key, data, digest=digest)     # line 14: B.set(C)
            return data
        finally:
            if lead:
                self.cluster.relays.finish(digest, self.node.name)
