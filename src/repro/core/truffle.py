"""TruffleInstance: the per-node daemon (paper §V: DaemonSet) wiring
Listener → Ingress → {SDP, CSP} over the shared Buffer / Data Engine /
Watcher components. The public surface mirrors the paper's architecture:

  handle_request(request)      — SDP: client/event ingress with prefetch
  pass_data(target_fn, data)   — CSP: inter-function cold-start pass
  proxy(request)               — hot-function transparent pass-through
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core import model as tmodel
from repro.core.csp import CSP
from repro.core.data_engine import DataEngine
from repro.core.sdp import SDP
from repro.core.transfer import pin_of
from repro.core.watcher import Watcher
from repro.runtime.function import LifecycleRecord, Request


class TruffleInstance:
    def __init__(self, node, cluster):
        self.node = node
        self.cluster = cluster
        self.engine = DataEngine(node, cluster)
        self.watcher = Watcher(cluster)
        self.sdp = SDP(self)
        self.csp = CSP(self)

    # ------------------------------------------------------------------ SDP
    def handle_request(self, request: Request, policy=None,
                       **data_plane) -> Tuple[bytes, LifecycleRecord]:
        """Ingress entry (Listener → Ingress). Hot functions take the proxy
        path (paper §III-B: Truffle only passes the data through).
        ``policy`` (a per-edge :class:`~repro.runtime.policy.DataPolicy`,
        usually resolved from the workflow's ExecutionPlan) selects the
        data plane; the legacy ``stream``/``dedup``/``chunk_bytes`` kwargs
        build a uniform one. Defaults keep whole-blob behavior."""
        if self.cluster.platform.warm_instances(request.fn):
            return self.proxy(request)
        return self.sdp.handle(request, policy=policy, **data_plane)

    # ------------------------------------------------------------------ CSP
    def pass_data(self, target_fn: str, data: bytes, policy=None,
                  input_hints=None, avoid=None, digest=None, pipes=None,
                  **data_plane) -> Tuple[bytes, LifecycleRecord]:
        if self.cluster.platform.warm_instances(target_fn):
            # warm target: no cold start to overlap, but its pipelined
            # consumers' pipes still ride the request meta so put_stream
            # reaches them mid-execution
            meta = {"pipes": list(pipes)} if pipes else {}
            return self.proxy(Request(fn=target_fn, payload=data,
                                      source_node=self.node.name, meta=meta))
        return self.csp.pass_data(target_fn, data, policy=policy,
                                  input_hints=input_hints, avoid=avoid,
                                  digest=digest, pipes=pipes, **data_plane)

    # ---------------------------------------------------------------- proxy
    def proxy(self, request: Request) -> Tuple[bytes, LifecycleRecord]:
        """Transparent pass-through for warm targets: no overlap to exploit,
        so forward unmodified (payload travels with the request)."""
        if request.source_node is None:
            request.source_node = self.node.name
        out, rec = self.cluster.platform.invoke(request)
        rec.mode = "truffle-proxy"
        return out, rec

    # ------------------------------------------------------------- planning
    def plan(self, estimate: tmodel.PhaseEstimate, fn: str,
             digest: str = None) -> bool:
        """Eq. 4 planner: engage only when predicted Δ > 0 and fn is cold.

        ``digest`` folds the locality term in: if placement can land on a
        node already holding the input's bytes (some holder exists and the
        function is either unpinned or pinned to a holder), the effective
        transfer shrinks toward 0 and the lightweight trigger alone beats
        the payload-carrying ingress — engage. A pin to a non-holder gets
        no locality benefit and falls through to the plain Eq. 4 gate."""
        warm = bool(self.cluster.platform.warm_instances(fn))
        if warm:
            return False
        registry = getattr(self.cluster, "digests", None)
        if digest is not None and registry is not None:
            holders = registry.nodes_for(digest)
            pin = pin_of(self.cluster, fn)
            if holders and (pin is None or pin in holders):
                return True
        return tmodel.should_engage(estimate, warm)
