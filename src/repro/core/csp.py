"""Cold Start Pass (paper §IV-B, Fig. 6 + Algorithm 2).

Inter-function data passing: the source function hands its output to the
local Truffle, which (1) triggers the target function with a reference key,
(2a) listens for the target's host assignment, and (6a) ships the payload
source-node → target-node the moment placement is known — i.e. during the
target's cold start. The target handler reads from its local buffer.

With ``dedup=True`` the payload is also *seeded* into the source node's
buffer under its content address before the trigger fires, so the digest
registry sees the bytes and the locality-aware scheduler can place the
target right on them — the pass then degenerates to a zero-transfer local
alias. Concurrent fan-out passes of the same content to one node share a
single relay stream (``RelayTable``).

Knobs (``pass_data`` kwargs): ``stream`` relays the payload chunk-by-chunk
(``chunk_bytes``, default 1 MiB) into an in-flight buffer entry, so the
target starts consuming at first-chunk arrival and per-chunk compute
overlaps the remaining transfer; ``dedup`` content-addresses the payload
(BLAKE2b) and, when the target buffer already holds identical bytes
(fan-out, retries), aliases them — near-zero transfer. Defaults keep the
whole-blob behavior. ``join_timeout_s`` bounds the post-return wait on the
transfer thread; a stall is recorded and raised as TransferStallError."""
from __future__ import annotations

import threading
import uuid
from typing import Optional, Tuple

from repro.core.buffer import content_digest
from repro.core.transfer import join_or_stall, seed_content, ship_payload
from repro.runtime.function import ContentRef, LifecycleRecord, Request
from repro.runtime.netsim import DEFAULT_CHUNK_BYTES


class CSP:
    def __init__(self, truffle, join_timeout_s: float = 60.0):
        self.truffle = truffle
        self.join_timeout_s = join_timeout_s

    def pass_data(self, target_fn: str, data: bytes,
                  exec_after: Optional[float] = None, *,
                  stream: bool = False, dedup: bool = False,
                  chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                  ) -> Tuple[bytes, LifecycleRecord]:
        """Algorithm 2 from the source node's Truffle. Returns the target's
        result + lifecycle record."""
        t = self.truffle
        cluster = t.cluster
        clock = cluster.clock
        inv_id = uuid.uuid4().hex
        buf_key = f"truffle/{target_fn}/{inv_id[:8]}"
        digest = content_digest(data) if dedup else None
        if digest is not None:
            seed_content(cluster, t.node, target_fn, data, digest)

        fwd = Request(fn=target_fn,
                      content_ref=ContentRef("truffle", buf_key, size=len(data),
                                             digest=digest),
                      source_node=t.node.name, meta={"invocation": inv_id})
        rec = LifecycleRecord(fn=target_fn, mode="truffle")
        rec.streamed = stream
        rec.t_request = clock.now()

        # (2) reference-key trigger to the platform ...
        fut, rec = cluster.platform.invoke_async(fwd, lightweight_trigger=True,
                                                 record=rec)
        errbox = []

        # (2a) ... while listening for the target host; (6a) early transfer.
        def transfer_path():
            try:
                rec.t_transfer_start = clock.now()
                placed = t.watcher.resolve_placement(target_fn, inv_id)
                ship_payload(cluster, t.node, cluster.node(placed["node"]),
                             buf_key, data, stream=stream, digest=digest,
                             chunk_bytes=chunk_bytes, record=rec)
                rec.t_transfer_end = clock.now()
            except BaseException as e:  # noqa: BLE001
                errbox.append(e)

        th = threading.Thread(target=transfer_path, daemon=True,
                              name=f"csp-{target_fn}-{inv_id[:6]}")
        th.start()
        result = fut.result()
        join_or_stall(th, rec, self.join_timeout_s,
                      f"CSP transfer for {target_fn} ({inv_id[:8]})")
        if errbox:
            raise errbox[0]
        return result, rec
