"""Cold Start Pass (paper §IV-B, Fig. 6 + Algorithm 2).

Inter-function data passing: the source function hands its output to the
local Truffle, which (1) triggers the target function with a reference key,
(2a) listens for the target's host assignment, and (6a) ships the payload
source-node → target-node the moment placement is known — i.e. during the
target's cold start. The target handler reads from its local buffer.

The edge's :class:`~repro.runtime.policy.DataPolicy` (``policy=``, compiled
from the workflow's ExecutionPlan; legacy ``stream=``/``dedup=`` kwargs
build a uniform one) selects the data plane:

``dedup`` content-addresses the payload (BLAKE2b) and *seeds* it into the
source node's buffer before the trigger fires, so the digest registry sees
the bytes and the locality-aware scheduler can place the target right on
them — the pass then degenerates to a zero-transfer local alias. Fan-in
passes carry ``input_hints`` — one (digest, size) per upstream dep — so
the scheduler scores the SUM of resident inputs instead of a joined-blob
hash. Concurrent fan-out passes of the same content to one node share a
single relay stream (``RelayTable``). ``stream`` relays the payload
chunk-by-chunk (``chunk_bytes``, default 1 MiB) into an in-flight buffer
entry, so the target starts consuming at first-chunk arrival. ``compression``
ships compressed chunks on remote hops (WAN edges). ``prefetch``/
``locality_weight`` ride the PlacementHint; ``avoid`` steers a speculative
backup off the straggler's node. Defaults keep the whole-blob behavior.
``join_timeout_s`` bounds the post-return wait on the transfer thread; a
stall is recorded and raised as TransferStallError."""
from __future__ import annotations

import threading
import uuid
from typing import Optional, Tuple

from repro.core.buffer import content_digest
from repro.core.errors import DATA_PLANE_FAULTS, NodeCrashError
from repro.core.transfer import (RELAY_WAIT_S, join_or_stall, resolve_codec,
                                 seed_content, ship_payload)
from repro.runtime.function import ContentRef, LifecycleRecord, Request
from repro.runtime.netsim import DEFAULT_CHUNK_BYTES
from repro.runtime.policy import DataPolicy
from repro.runtime.scheduler import PlacementHint


class CSP:
    def __init__(self, truffle, join_timeout_s: float = 60.0):
        self.truffle = truffle
        self.join_timeout_s = join_timeout_s

    def pass_data(self, target_fn: str, data: bytes,
                  exec_after: Optional[float] = None, *,
                  policy: Optional[DataPolicy] = None,
                  input_hints=None,
                  avoid: Optional[str] = None,
                  digest: Optional[str] = None,
                  stream: bool = False, dedup: bool = False,
                  chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                  ) -> Tuple[bytes, LifecycleRecord]:
        """Algorithm 2 from the source node's Truffle. Returns the target's
        result + lifecycle record. ``digest``, when the caller already knows
        the payload's content address (the runner seeds stage outputs),
        skips the re-hash on the dispatch path."""
        if policy is None:     # legacy kwargs -> uniform policy (shim)
            policy = DataPolicy(stream=stream, dedup=dedup)
        stream, dedup = policy.stream, policy.dedup
        chunk_bytes = policy.chunk_bytes or chunk_bytes   # per-edge grant size
        codec = resolve_codec(policy.compression)
        t = self.truffle
        cluster = t.cluster
        clock = cluster.clock
        if not getattr(t.node, "alive", True):
            # fail fast: a dead source can neither seed nor ship — the
            # caller's retry machinery must re-fetch from a replica instead
            raise NodeCrashError(t.node.name,
                                 f"CSP source node {t.node.name} crashed")
        inv_id = uuid.uuid4().hex
        buf_key = f"truffle/{target_fn}/{inv_id[:8]}"
        if dedup and digest is None:
            digest = content_digest(data)
        elif not dedup:
            digest = None
        if digest is not None:
            seed_content(cluster, t.node, target_fn, data, digest)

        inputs = tuple(input_hints) if input_hints else None
        fwd = Request(fn=target_fn,
                      content_ref=ContentRef("truffle", buf_key,
                                             size=len(data), digest=digest,
                                             inputs=inputs),
                      source_node=t.node.name, meta={"invocation": inv_id})
        hint = PlacementHint.from_policy(policy, digest, len(data),
                                         inputs, avoid)
        rec = LifecycleRecord(fn=target_fn, mode="truffle")
        rec.streamed = stream
        rec.t_request = clock.now()

        # (2) reference-key trigger to the platform ...
        fut, rec = cluster.platform.invoke_async(fwd, lightweight_trigger=True,
                                                 record=rec, hint=hint)
        errbox = []

        # a speculative backup (avoid set) exists because the original
        # attempt is already stuck: bound its wait on any in-flight relay
        # of the same content by the join budget instead of the full
        # follower default — better to re-ship than to park behind a
        # possibly-wedged leader
        relay_wait_s = (min(RELAY_WAIT_S, self.join_timeout_s)
                        if avoid is not None else RELAY_WAIT_S)

        # (2a) ... while listening for the target host; (6a) early transfer.
        # ``cancel`` lets a failed trigger abandon the placement wait early
        # (no placement will ever publish); a failed ship poisons the target
        # buffer key so the handler's input wait fails NOW, not at timeout.
        cancel = threading.Event()

        def transfer_path():
            placed = None
            try:
                rec.t_transfer_start = clock.now()
                placed = t.watcher.resolve_placement_cancellable(
                    target_fn, inv_id, cancel)
                if placed is None:
                    return              # trigger already failed — nothing to ship
                ship_payload(cluster, t.node, cluster.node(placed["node"]),
                             buf_key, data, stream=stream, digest=digest,
                             chunk_bytes=chunk_bytes, codec=codec, record=rec,
                             relay_wait_s=relay_wait_s)
                rec.t_transfer_end = clock.now()
            except BaseException as e:  # noqa: BLE001
                errbox.append(e)
                if placed is not None:
                    try:
                        cluster.node(placed["node"]).buffer.poison(buf_key)
                    except DATA_PLANE_FAULTS:
                        pass            # target may be dead too — the
                        #                 original error in errbox wins

        th = threading.Thread(target=transfer_path, daemon=True,
                              name=f"csp-{target_fn}-{inv_id[:6]}")
        th.start()
        try:
            result = fut.result()
        except BaseException:
            cancel.set()                # release the placement wait
            th.join(timeout=2.0)
            if errbox:                  # data path saw the root cause
                raise errbox[0]
            raise
        join_or_stall(th, rec, self.join_timeout_s,
                      f"CSP transfer for {target_fn} ({inv_id[:8]})")
        if errbox:
            raise errbox[0]
        return result, rec

