"""Cold Start Pass (paper §IV-B, Fig. 6 + Algorithm 2).

Inter-function data passing: the source function hands its output to the
local Truffle, which (1) triggers the target function with a reference key,
(2a) listens for the target's host assignment, and (6a) ships the payload
source-node → target-node the moment placement is known — i.e. during the
target's cold start. The target handler reads from its local buffer.

The edge's :class:`~repro.runtime.policy.DataPolicy` (``policy=``, compiled
from the workflow's ExecutionPlan; legacy ``stream=``/``dedup=`` kwargs
build a uniform one) selects the data plane:

``dedup`` content-addresses the payload (BLAKE2b) and *seeds* it into the
source node's buffer before the trigger fires, so the digest registry sees
the bytes and the locality-aware scheduler can place the target right on
them — the pass then degenerates to a zero-transfer local alias. Fan-in
passes carry ``input_hints`` — one (digest, size) per upstream dep — so
the scheduler scores the SUM of resident inputs instead of a joined-blob
hash. Concurrent fan-out passes of the same content to one node share a
single relay stream (``RelayTable``). ``stream`` relays the payload
chunk-by-chunk (``chunk_bytes``, default 1 MiB) into an in-flight buffer
entry, so the target starts consuming at first-chunk arrival. ``compression``
ships compressed chunks on remote hops (WAN edges). ``prefetch``/
``locality_weight`` ride the PlacementHint; ``avoid`` steers a speculative
backup off the straggler's node. Defaults keep the whole-blob behavior.
``join_timeout_s`` bounds the post-return wait on the transfer thread; a
stall is recorded and raised as TransferStallError."""
from __future__ import annotations

import threading
import uuid
from typing import Optional, Tuple

from repro.core.buffer import content_digest
from repro.core.errors import DATA_PLANE_FAULTS, NodeCrashError
from repro.core.transfer import (RELAY_WAIT_S, join_or_stall, resolve_codec,
                                 seed_content, ship_payload)
from repro.runtime.executor import EXECUTOR
from repro.runtime.function import ContentRef, LifecycleRecord, Request
from repro.runtime.netsim import DEFAULT_CHUNK_BYTES
from repro.runtime.policy import DataPolicy
from repro.runtime.scheduler import PlacementHint


class CSP:
    def __init__(self, truffle, join_timeout_s: float = 60.0):
        self.truffle = truffle
        self.join_timeout_s = join_timeout_s

    def open_pipe(self, target_fn: str, *,
                  policy: Optional[DataPolicy] = None,
                  size_hint: int = 0,
                  avoid: Optional[str] = None,
                  pipes=None) -> "Pipe":
        """Open a pipelined producer→consumer edge (fires the consumer's
        lightweight trigger NOW — before the producer has even started
        executing). ``pipes`` are the consumer's OWN downstream pipes,
        riding its request meta so a whole chain cascades from one
        dispatch. See :class:`Pipe`."""
        return Pipe(self, target_fn, policy=policy, size_hint=size_hint,
                    avoid=avoid, pipes=pipes)

    def pass_data(self, target_fn: str, data: bytes,
                  exec_after: Optional[float] = None, *,
                  policy: Optional[DataPolicy] = None,
                  input_hints=None,
                  avoid: Optional[str] = None,
                  digest: Optional[str] = None,
                  stream: bool = False, dedup: bool = False,
                  chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                  pipes=None,
                  ) -> Tuple[bytes, LifecycleRecord]:
        """Algorithm 2 from the source node's Truffle. Returns the target's
        result + lifecycle record. ``digest``, when the caller already knows
        the payload's content address (the runner seeds stage outputs),
        skips the re-hash on the dispatch path. ``pipes`` (open
        :class:`Pipe` handles for the target's pipelined consumers) ride
        the request meta so the target's ``Invocation.put_stream`` can
        write into them mid-execution."""
        if policy is None:     # legacy kwargs -> uniform policy (shim)
            policy = DataPolicy(stream=stream, dedup=dedup)
        stream, dedup = policy.stream, policy.dedup
        chunk_bytes = policy.chunk_bytes or chunk_bytes   # per-edge grant size
        codec = resolve_codec(policy.compression)
        t = self.truffle
        cluster = t.cluster
        clock = cluster.clock
        if not getattr(t.node, "alive", True):
            # fail fast: a dead source can neither seed nor ship — the
            # caller's retry machinery must re-fetch from a replica instead
            raise NodeCrashError(t.node.name,
                                 f"CSP source node {t.node.name} crashed")
        inv_id = uuid.uuid4().hex
        buf_key = f"truffle/{target_fn}/{inv_id[:8]}"
        if dedup and digest is None:
            digest = content_digest(data)
        elif not dedup:
            digest = None
        if digest is not None:
            seed_content(cluster, t.node, target_fn, data, digest)

        inputs = tuple(input_hints) if input_hints else None
        fwd = Request(fn=target_fn,
                      content_ref=ContentRef("truffle", buf_key,
                                             size=len(data), digest=digest,
                                             inputs=inputs),
                      source_node=t.node.name,
                      meta={"invocation": inv_id,
                            "pipes": list(pipes) if pipes else []})
        hint = PlacementHint.from_policy(policy, digest, len(data),
                                         inputs, avoid)
        rec = LifecycleRecord(fn=target_fn, mode="truffle")
        rec.streamed = stream
        rec.t_request = clock.now()

        # (2) reference-key trigger to the platform ...
        fut, rec = cluster.platform.invoke_async(fwd, lightweight_trigger=True,
                                                 record=rec, hint=hint)
        errbox = []

        # a speculative backup (avoid set) exists because the original
        # attempt is already stuck: bound its wait on any in-flight relay
        # of the same content by the join budget instead of the full
        # follower default — better to re-ship than to park behind a
        # possibly-wedged leader
        relay_wait_s = (min(RELAY_WAIT_S, self.join_timeout_s)
                        if avoid is not None else RELAY_WAIT_S)

        # (2a) ... while listening for the target host; (6a) early transfer.
        # ``cancel`` lets a failed trigger abandon the placement wait early
        # (no placement will ever publish); a failed ship poisons the target
        # buffer key so the handler's input wait fails NOW, not at timeout.
        cancel = threading.Event()

        def transfer_path():
            placed = None
            try:
                rec.t_transfer_start = clock.now()
                placed = t.watcher.resolve_placement_cancellable(
                    target_fn, inv_id, cancel)
                if placed is None:
                    return              # trigger already failed — nothing to ship
                ship_payload(cluster, t.node, cluster.node(placed["node"]),
                             buf_key, data, stream=stream, digest=digest,
                             chunk_bytes=chunk_bytes, codec=codec, record=rec,
                             relay_wait_s=relay_wait_s)
                rec.t_transfer_end = clock.now()
            except BaseException as e:  # noqa: BLE001
                errbox.append(e)
                if placed is not None:
                    try:
                        cluster.node(placed["node"]).buffer.poison(buf_key)
                    except DATA_PLANE_FAULTS:
                        pass            # target may be dead too — the
                        #                 original error in errbox wins

        th = EXECUTOR.submit(transfer_path,
                             name=f"csp-{target_fn}-{inv_id[:6]}")
        try:
            result = fut.result()
        except BaseException:
            cancel.set()                # release the placement wait
            th.join(timeout=2.0)
            if errbox:                  # data path saw the root cause
                raise errbox[0]
            raise
        join_or_stall(th, rec, self.join_timeout_s,
                      f"CSP transfer for {target_fn} ({inv_id[:8]})")
        if errbox:
            raise errbox[0]
        return result, rec


class Pipe:
    """One pipelined producer→consumer edge, opened at PRODUCER dispatch
    (function-to-function direct streaming — the CSP taken to its limit).

    Construction fires the consumer's lightweight trigger immediately, so
    its cold start (α+ν+η) overlaps the producer's ENTIRE execution — not
    just the output transfer — and starts resolving its placement on a
    background thread. The producer's ``Invocation.put_stream`` then
    writes output chunks here while it is still executing: the first
    write opens an in-flight entry in the consumer node's buffer, bounded
    by the edge's high-water mark (``DataPolicy.pipeline_highwater``,
    default 4× the edge chunk size) — a consumer that falls behind blocks
    the producer's writes instead of growing the entry unboundedly — and
    each chunk pays its fabric grant (chained deadlines, same channel
    model as every other transfer) before landing.

    ``close`` seals the consumer's entry; ``abort`` poisons it so a
    blocked consumer wakes with the error NOW (composing with the
    runner's retry machinery: the consumer falls back to the whole-blob
    dispatch path against the producer's retried output); ``flush`` is
    the whole-output fallback for producers that never streamed (handler
    without ``streaming_output``, or a retry attempt that ran without the
    pipe) — the consumer still gets its input through the normal
    relay/dedup ship, just without mid-execution overlap. ``result``
    joins the consumer's invocation."""

    def __init__(self, csp: CSP, target_fn: str, *,
                 policy: Optional[DataPolicy] = None,
                 size_hint: int = 0,
                 avoid: Optional[str] = None,
                 pipes=None):
        self.csp = csp
        t = csp.truffle
        self.cluster = t.cluster
        clock = self.cluster.clock
        self.policy = policy if policy is not None else DataPolicy()
        self.chunk_bytes = self.policy.chunk_bytes or DEFAULT_CHUNK_BYTES
        self.highwater = self.policy.pipeline_highwater or 4 * self.chunk_bytes
        self.target_fn = target_fn
        self.inv_id = uuid.uuid4().hex
        self.buf_key = f"truffle/{target_fn}/{self.inv_id[:8]}"
        self._lock = threading.Lock()
        self._placed = threading.Event()
        self._cancel = threading.Event()
        self._errbox = []
        self._target = None             # consumer Node once placement resolves
        self._src = None                # producer Node once bound
        self._channel = None
        self._deadline = None           # chained per-chunk grant deadline
        self._closed = False
        self._aborted: Optional[BaseException] = None
        self.used = False               # producer streamed ≥ 1 chunk

        fwd = Request(fn=target_fn,
                      content_ref=ContentRef("truffle", self.buf_key,
                                             size=size_hint),
                      source_node=t.node.name,
                      meta={"invocation": self.inv_id,
                            "pipes": list(pipes) if pipes else []})
        hint = PlacementHint.from_policy(self.policy, None, size_hint,
                                         None, avoid)
        rec = LifecycleRecord(fn=target_fn, mode="truffle")
        rec.streamed = True
        rec.pipelined = True
        rec.t_request = clock.now()
        # (2) reference-key trigger NOW — at producer dispatch
        self.future, self.record = self.cluster.platform.invoke_async(
            fwd, lightweight_trigger=True, record=rec, hint=hint)
        # a trigger that fails before placement would otherwise leave the
        # producer's first write parked on _await_target — cancel the
        # placement wait so writes fail over to the whole-blob path NOW
        self.future.add_done_callback(
            lambda f: self._cancel.set() if f.exception() is not None
            else None)
        # (2a) listen for the consumer's host on the side, so the first
        # produced chunk ships the moment both ends are known
        EXECUTOR.submit(self._resolve,
                        name=f"pipe-{target_fn}-{self.inv_id[:6]}")

    # ------------------------------------------------------------ placement
    def _resolve(self) -> None:
        t = self.csp.truffle
        try:
            placed = t.watcher.resolve_placement_cancellable(
                self.target_fn, self.inv_id, self._cancel)
            if placed is not None:
                self._target = self.cluster.node(placed["node"])
        except BaseException as e:  # noqa: BLE001 — surfaced via _await_target
            self._errbox.append(e)
        finally:
            self._placed.set()

    def _await_target(self, timeout: float = 120.0):
        if not self._placed.wait(timeout):
            raise TimeoutError(f"pipe to {self.target_fn}: placement never "
                               f"resolved within {timeout}s")
        if self._target is None:
            if self._errbox:
                raise self._errbox[0]
            raise IOError(f"pipe to {self.target_fn}: trigger failed before "
                          f"placement")
        return self._target

    # ----------------------------------------------------------- write path
    def bind_source(self, node) -> None:
        """Stamp the producer's node (known only once IT is placed)."""
        self._src = node

    def write(self, chunk: bytes) -> None:
        """Ship one producer output chunk into the consumer's in-flight
        buffer entry. Blocks while the entry sits at its high-water mark
        (backpressure propagates to the producer). A DELIVERY failure —
        consumer node crashed, link dark, entry poisoned/displaced — never
        fails the producer (its output is still valid; the consumer's own
        retry machinery recovers): the pipe self-aborts, poisons the
        consumer's input so it wakes NOW, and every later write no-ops."""
        with self._lock:
            if self._aborted is not None or self._closed:
                return                  # dead pipe: producer carries on
        try:
            if self._src is None:
                raise IOError(f"pipe to {self.target_fn}: source node "
                              f"not bound")
            target = self._await_target()
            if not self.used:
                self.record.t_transfer_start = self.cluster.clock.now()
                target.buffer.open_stream(self.buf_key,
                                          highwater=self.highwater)
                self._channel = self.cluster.network.channel(self._src,
                                                             target)
                self.used = True
            self._deadline = self._channel.transfer_chunk(
                len(chunk), pay_latency=self._deadline is None,
                after=self._deadline)
            target.buffer.append_chunk(self.buf_key, chunk)
        except Exception as e:  # noqa: BLE001 — delivery fault, not ours
            self.abort(e)

    def close(self, digest: Optional[str] = None) -> None:
        """Seal the consumer's entry (its reader drains and completes). A
        pipe that never streamed stays open for the runner's whole-output
        ``flush`` fallback; a seal failure (consumer died after the last
        chunk) aborts the pipe instead of failing the producer."""
        if not self.used:
            return
        with self._lock:
            if self._closed or self._aborted is not None:
                return
            self._closed = True
        try:
            self._target.buffer.close_stream(self.buf_key, digest=digest)
        except Exception as e:  # noqa: BLE001
            with self._lock:
                self._closed = False    # reopen so abort() can poison
            self.abort(e)
            return
        self.record.t_transfer_end = self.cluster.clock.now()

    def flush(self, src_node, data: bytes,
              digest: Optional[str] = None) -> None:
        """Whole-output fallback: producer finished without streaming
        (non-``streaming_output`` handler, or the streaming attempt failed
        and a retry produced the output whole). Ships through the normal
        relay/dedup machinery — the pipe still bought the early trigger."""
        with self._lock:
            if self._closed or self._aborted is not None or self.used:
                return
            self._closed = True
        target = self._await_target()
        rec = self.record
        rec.t_transfer_start = self.cluster.clock.now()
        ship_payload(self.cluster, src_node, target, self.buf_key, data,
                     stream=self.policy.stream, digest=digest,
                     chunk_bytes=self.chunk_bytes,
                     codec=resolve_codec(self.policy.compression),
                     record=rec)
        rec.t_transfer_end = self.cluster.clock.now()

    def abort(self, exc: BaseException) -> None:
        """Producer died mid-stream (or its attempt failed before binding):
        poison the consumer's input so its blocked reader wakes with the
        error immediately — the consumer-side waiter then falls back to
        the whole-blob path against the producer's retried output."""
        with self._lock:
            if self._closed or self._aborted is not None:
                return
            self._aborted = exc
        self._cancel.set()              # release the placement wait, if any
        target = self._target
        if target is not None:
            try:
                target.buffer.poison(self.buf_key,
                                     reason=f"pipe aborted: {exc}")
            except DATA_PLANE_FAULTS:
                pass                    # consumer node may be dead too

    # ---------------------------------------------------------- result path
    def result(self, timeout: Optional[float] = None) -> bytes:
        """Join the consumer's invocation (its trigger future)."""
        return self.future.result(timeout)

