"""Cold Start Pass (paper §IV-B, Fig. 6 + Algorithm 2).

Inter-function data passing: the source function hands its output to the
local Truffle, which (1) triggers the target function with a reference key,
(2a) listens for the target's host assignment, and (6a) ships the payload
source-node → target-node the moment placement is known — i.e. during the
target's cold start. The target handler reads from its local buffer."""
from __future__ import annotations

import threading
import uuid
from typing import Optional, Tuple

from repro.runtime.function import ContentRef, LifecycleRecord, Request


class CSP:
    def __init__(self, truffle):
        self.truffle = truffle

    def pass_data(self, target_fn: str, data: bytes,
                  exec_after: Optional[float] = None,
                  ) -> Tuple[bytes, LifecycleRecord]:
        """Algorithm 2 from the source node's Truffle. Returns the target's
        result + lifecycle record."""
        t = self.truffle
        cluster = t.cluster
        clock = cluster.clock
        inv_id = uuid.uuid4().hex
        buf_key = f"truffle/{target_fn}/{inv_id[:8]}"

        fwd = Request(fn=target_fn,
                      content_ref=ContentRef("truffle", buf_key, size=len(data)),
                      source_node=t.node.name, meta={"invocation": inv_id})
        rec = LifecycleRecord(fn=target_fn, mode="truffle")
        rec.t_request = clock.now()

        # (2) reference-key trigger to the platform ...
        fut, rec = cluster.platform.invoke_async(fwd, lightweight_trigger=True,
                                                 record=rec)
        errbox = []

        # (2a) ... while listening for the target host; (6a) early transfer.
        def transfer_path():
            try:
                rec.t_transfer_start = clock.now()
                target_name = t.watcher.resolve_host(target_fn, inv_id)
                if target_name != t.node.name:
                    target = cluster.node(target_name)
                    cluster.transfer(t.node, target, data)   # during cold start
                    target.buffer.set(buf_key, data)
                else:
                    t.node.buffer.set(buf_key, data)
                rec.t_transfer_end = clock.now()
            except BaseException as e:  # noqa: BLE001
                errbox.append(e)

        th = threading.Thread(target=transfer_path, daemon=True,
                              name=f"csp-{target_fn}-{inv_id[:6]}")
        th.start()
        result = fut.result()
        th.join(timeout=60)
        if errbox:
            raise errbox[0]
        return result, rec
