"""Watcher (paper §III-B.1d + Algorithm 2): subscribes to the orchestrator's
live scheduling events and resolves the target host for a function the
moment placement happens — i.e. *before* the sandbox exists. Hot functions
(already placed) resolve immediately from the warm pool."""
from __future__ import annotations

from typing import Optional


class Watcher:
    def __init__(self, cluster):
        self.cluster = cluster

    def resolve_host(self, function: str, invocation: Optional[str] = None,
                     timeout: float = 120.0) -> str:
        """Algorithm 2: scan current placements / wait for the event; returns
        the node name. ``invocation`` pins a specific scale-up."""
        # Hot path: function already has an assigned worker.
        if invocation is None:
            warm = self.cluster.platform.warm_instances(function)
            if warm:
                return warm[0].node.name

        def match(e: dict) -> bool:
            return (e["function"] == function
                    and (invocation is None or e["invocation"] == invocation))

        ev = self.cluster.bus.wait_for("scheduling.placed", match,
                                       timeout=timeout)
        if ev is None:
            raise TimeoutError(f"watcher: no placement for {function!r} "
                               f"within {timeout}s")
        return ev["node"]
