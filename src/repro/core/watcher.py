"""Watcher (paper §III-B.1d + Algorithm 2): subscribes to the orchestrator's
live scheduling events and resolves the target host for a function the
moment placement happens — i.e. *before* the sandbox exists. Hot functions
(already placed) resolve immediately from the warm pool.

``scheduling.placed`` events now carry the scheduler's locality decision
(``locality_hit``, ``resident_bytes``); ``resolve_placement`` exposes the
whole event so the data plane can see not just WHERE the function landed
but whether its input is already there (in which case CSP/SDP degenerate to
a local alias)."""
from __future__ import annotations

from typing import Optional


class Watcher:
    def __init__(self, cluster):
        self.cluster = cluster

    def resolve_placement(self, function: str,
                          invocation: Optional[str] = None,
                          timeout: float = 120.0) -> dict:
        """Algorithm 2: scan current placements / wait for the event; returns
        the full placement event (``node``, ``locality_hit``, …).
        ``invocation`` pins a specific scale-up."""
        # Hot path: function already has an assigned worker.
        if invocation is None:
            warm = self.cluster.platform.warm_instances(function)
            if warm:
                # same keys as a cold scheduling.placed event (scheduler.py)
                return {"function": function, "node": warm[0].node.name,
                        "warm": True, "locality_hit": False,
                        "resident_bytes": 0}

        def match(e: dict) -> bool:
            return (e["function"] == function
                    and (invocation is None or e["invocation"] == invocation))

        ev = self.cluster.bus.wait_for("scheduling.placed", match,
                                       timeout=timeout)
        if ev is None:
            raise TimeoutError(f"watcher: no placement for {function!r} "
                               f"within {timeout}s")
        return ev

    def resolve_placement_cancellable(self, function: str,
                                      invocation: Optional[str] = None,
                                      cancel=None, timeout: float = 120.0,
                                      poll_s: float = 0.25) -> Optional[dict]:
        """:meth:`resolve_placement`, but abandoned early (returns None)
        once ``cancel`` (a ``threading.Event``) is set — the data-path
        thread must not sit out the full placement timeout after the
        trigger it was shipping for has already failed (e.g. the scheduler
        raised on a crashed affinity node, so no placement will EVER be
        published)."""
        if cancel is None:
            return self.resolve_placement(function, invocation, timeout)
        waited = 0.0
        while True:
            try:
                return self.resolve_placement(function, invocation,
                                              timeout=poll_s)
            except TimeoutError:
                if cancel.is_set():
                    return None
                waited += poll_s
                if waited >= timeout:
                    raise

    def resolve_host(self, function: str, invocation: Optional[str] = None,
                     timeout: float = 120.0) -> str:
        """Node name only (the original Algorithm 2 surface)."""
        return self.resolve_placement(function, invocation, timeout)["node"]
