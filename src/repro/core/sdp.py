"""Smart Data Prefetch (paper §IV-A, Fig. 5).

On request ingress, two paths run concurrently:
  (a) the platform activation path (scale-up → scheduling → cold start),
  (b) the data path: Data Engine fetch from the input's storage into the
      local buffer, then (once the Watcher reports placement) relay to the
      target node's buffer.
The function, once started, reads its input from its node-local Truffle
buffer via the reference key — ideally without waiting.

The edge's :class:`~repro.runtime.policy.DataPolicy` (``policy=``, compiled
into the workflow's ExecutionPlan; the legacy ``stream=``/``dedup=`` kwargs
build a uniform one) selects the data plane:

``dedup`` resolves the input's digest BEFORE the trigger fires (from the
ContentRef, the storage service's digest index, or — for inline payloads —
by hashing and seeding the bytes into the local buffer), so the forwarded
reference carries a placement hint: the locality-aware scheduler can put
the function on whichever node already holds those bytes and the data path
degenerates to a local alias. Fan-in inputs hint one digest PER DEP
(``ContentRef.inputs``), scored as a sum. ``stream`` pipelines the data
path at chunk granularity (``chunk_bytes``, default 1 MiB) so the function
consumes at first-chunk arrival. ``compression`` ships compressed chunks on
the inline-relay hop (WAN edges). ``prefetch``/``locality_weight`` ride the
:class:`~repro.runtime.scheduler.PlacementHint` to the scheduler; ``avoid``
steers a speculative backup off the straggler's node. Defaults keep the
whole-blob behavior. ``join_timeout_s`` bounds how long we wait for the
data-path thread after the function returns — a thread still alive then is
recorded on the LifecycleRecord and raised as TransferStallError instead of
silently leaking."""
from __future__ import annotations

import threading
import uuid
from typing import Optional, Tuple

from repro.core.buffer import content_digest
from repro.core.errors import DATA_PLANE_FAULTS, NodeCrashError
from repro.core.transfer import (RELAY_WAIT_S, join_or_stall, resolve_codec,
                                 seed_content, ship_payload)
from repro.runtime.executor import EXECUTOR
from repro.runtime.function import ContentRef, LifecycleRecord, Request
from repro.runtime.netsim import DEFAULT_CHUNK_BYTES
from repro.runtime.policy import DataPolicy
from repro.runtime.scheduler import PlacementHint


class SDP:
    def __init__(self, truffle, join_timeout_s: float = 60.0):
        self.truffle = truffle
        self.join_timeout_s = join_timeout_s

    def handle(self, request: Request, *,
               policy: Optional[DataPolicy] = None,
               avoid: Optional[str] = None,
               stream: bool = False, dedup: bool = False,
               chunk_bytes: int = DEFAULT_CHUNK_BYTES,
               ) -> Tuple[bytes, LifecycleRecord]:
        """Fig. 5 steps 1-7. Returns (result, lifecycle record)."""
        if policy is None:     # legacy kwargs -> uniform policy (shim)
            policy = DataPolicy(stream=stream, dedup=dedup)
        stream, dedup = policy.stream, policy.dedup
        chunk_bytes = policy.chunk_bytes or chunk_bytes   # per-edge grant size
        codec = resolve_codec(policy.compression)
        t = self.truffle
        cluster = t.cluster
        clock = cluster.clock
        if not getattr(t.node, "alive", True):
            # fail fast: a dead ingress node can neither seed nor relay —
            # callers must re-route through a live node
            raise NodeCrashError(t.node.name,
                                 f"SDP ingress node {t.node.name} crashed")
        ref = request.content_ref
        inv_id = uuid.uuid4().hex
        buf_key = f"truffle/{request.fn}/{inv_id[:8]}"

        # resolve the content address BEFORE the trigger so the scheduler can
        # score placement by residency (digest-aware locality): storage refs
        # consult the service's digest index; inline payloads (including the
        # non-adapter-ref fallback, which ships the inline body) are hashed
        # and seeded into the local buffer. The hint must always describe
        # the bytes the data path will actually land — a non-adapter ref's
        # own digest describes content we do NOT have.
        fetchable = ref is not None and ref.storage_type in t.engine._adapters
        digest = ref.digest if fetchable else None
        if dedup:
            if fetchable:
                if digest is None:
                    digest = t.engine.adapter_for(ref).digest(ref.key)
            else:
                data = request.payload or b""
                digest = content_digest(data)
                seed_content(cluster, t.node, request.fn, data, digest)

        size = ref.size if ref else len(request.payload or b"")
        inputs = ref.inputs if (fetchable and ref.inputs) else None
        fwd = Request(fn=request.fn,
                      content_ref=ContentRef("truffle", buf_key, size=size,
                                             digest=digest, inputs=inputs),
                      source_node=t.node.name,
                      # pipelined downstream edges ride through: the target's
                      # put_stream writes into its consumers' pipes
                      meta={"invocation": inv_id,
                            "pipes": (request.meta or {}).get("pipes") or []})
        # storage-backed inputs fetch via the Data Engine too — it follows
        # the cluster RelayTable, so a prefetch relay kicked at placement
        # time makes the engine's storage read a follower (single transfer)
        hint = PlacementHint.from_policy(policy, digest, size,
                                         inputs, avoid)

        rec = LifecycleRecord(fn=request.fn, mode="truffle")
        rec.streamed = stream
        rec.t_request = clock.now()

        # (2) fire the platform trigger (reference key only) ...
        fut, rec = cluster.platform.invoke_async(fwd, lightweight_trigger=True,
                                                 record=rec, hint=hint)
        errbox = []

        # (2a/3) ... and, simultaneously, the data path. Storage refs are
        # fetched by the *target* node's Data Engine (every node runs a
        # Truffle DaemonSet instance — fetch lands next to the function, one
        # storage read, no ingress-node relay). Inline payloads hop
        # source -> target once (CSP-style).
        # ``cancel`` lets a failed trigger abandon the placement wait early
        # (no placement will ever publish); a failed data path poisons the
        # target buffer key so the handler's input wait fails NOW
        cancel = threading.Event()

        def data_path():
            placed = None
            try:
                rec.t_transfer_start = clock.now()
                placed = t.watcher.resolve_placement_cancellable(
                    request.fn, inv_id, cancel)                       # (4)
                if placed is None:
                    return          # trigger already failed — nothing to move
                target = cluster.node(placed["node"])
                if fetchable:
                    target.truffle.engine.fetch(ref, buffer_key=buf_key,
                                                policy=policy,
                                                chunk_bytes=chunk_bytes,
                                                record=rec)  # (3)-(4a)
                else:
                    # inline body (or non-adapter-ref fallback): ``digest``
                    # already content-addresses exactly these bytes. A
                    # speculative backup (avoid set) bounds its wait on an
                    # in-flight relay by the join budget — see CSP
                    ship_payload(cluster, t.node, target, buf_key,
                                 request.payload or b"",
                                 stream=stream, digest=digest,
                                 chunk_bytes=chunk_bytes, codec=codec,
                                 record=rec,
                                 relay_wait_s=(min(RELAY_WAIT_S,
                                                   self.join_timeout_s)
                                               if avoid is not None
                                               else RELAY_WAIT_S))
                rec.t_transfer_end = clock.now()
            except BaseException as e:  # noqa: BLE001
                errbox.append(e)
                if placed is not None:
                    try:
                        cluster.node(placed["node"]).buffer.poison(buf_key)
                    except DATA_PLANE_FAULTS:
                        pass            # target may be dead too — the
                        #                 original error in errbox wins

        th = EXECUTOR.submit(data_path,
                             name=f"sdp-{request.fn}-{inv_id[:6]}")
        try:
            result = fut.result()   # (5)-(7): function reads from the buffer
        except BaseException:
            cancel.set()            # release the placement wait
            th.join(timeout=2.0)
            if errbox:              # data path saw the root cause
                raise errbox[0]
            raise
        join_or_stall(th, rec, self.join_timeout_s,
                      f"SDP data path for {request.fn} ({inv_id[:8]})")
        if errbox:
            raise errbox[0]
        return result, rec

