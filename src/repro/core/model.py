"""Truffle analytic latency model (paper §III-A, Eqs. 1-5).

Used three ways:
  * planner: decide whether engaging Truffle helps (hot functions: Δ=0 → proxy)
  * validation: benchmarks compare measured vs. predicted Δ (Eq. 4)
  * capacity: expected workflow latency for scheduling decisions
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional


@dataclass(frozen=True)
class PhaseEstimate:
    alpha: float      # scheduling
    nu: float         # infrastructure setup
    eta: float        # runtime startup
    delta: float      # input data transfer
    gamma: float      # function execution

    @property
    def beta(self) -> float:
        """Eq. 1: cold start β = ν + η."""
        return self.nu + self.eta


def overlap_window(p: PhaseEstimate) -> float:
    """Eq. 2: φ = max(β, δ) — cold start and transfer run concurrently."""
    return max(p.beta, p.delta)


def truffle_time(p: PhaseEstimate) -> float:
    """Eq. 3 (single function): τ = α + max(ν+η, δ) + γ."""
    return p.alpha + overlap_window(p) + p.gamma


def baseline_time(p: PhaseEstimate) -> float:
    """State-of-the-art sequential lifecycle: τ = α + β + δ + γ."""
    return p.alpha + p.beta + p.delta + p.gamma


def improvement(p: PhaseEstimate) -> float:
    """Eq. 4: Δ = (β + δ) − max(β, δ) = min(β, δ)."""
    return (p.beta + p.delta) - overlap_window(p)


# ------------------------------------------------- pipelined-transfer terms
# Chunked streaming extension of Eq. 4: with chunk-granular transfer the
# function consumes input while the tail is still in flight, so per-chunk
# compute overlaps the transfer too. ``exec_overlap`` is the portion of γ
# that can run concurrently with the transfer (for n chunks with per-chunk
# compute ε it is (n−1)·ε: everything but the first chunk's compute).

def pipelined_io_visible(p: PhaseEstimate, exec_overlap: float = 0.0) -> float:
    """Visible I/O ≈ max(0, δ − β − γ_overlap): transfer hidden behind cold
    start AND execution (vs. whole-blob Truffle's max(0, δ − β))."""
    return max(0.0, p.delta - p.beta - exec_overlap)


def streamed_time(p: PhaseEstimate, exec_overlap: float = 0.0) -> float:
    """Single function with a streamed input:
    τ = α + β + max(0, δ − β − γ_overlap) + γ."""
    return p.alpha + p.beta + pipelined_io_visible(p, exec_overlap) + p.gamma


def streamed_improvement(p: PhaseEstimate, exec_overlap: float = 0.0) -> float:
    """Gain of streaming over whole-blob Truffle (Eq. 3):
    Δ_stream = min(γ_overlap, max(0, δ − β))."""
    return truffle_time(p) - streamed_time(p, exec_overlap)


# --------------------------------------------- pipelined-chain (tandem) terms
# Function-to-function direct streaming extends the overlap past ONE edge:
# every pipelined consumer's lightweight trigger fires when the CHAIN HEAD
# dispatches (cold starts all overlap the head's execution), and producer
# output chunks flow to the consumer mid-execution. The chain then behaves
# like a tandem queue: each stage contributes a wire station (its input
# edge) and an execute station, each serving K chunks FIFO, and the chain
# makespan is the last chunk's departure from the last station — which
# approaches max(stage)+ε instead of Eq. 5's Σ(stage) as K grows.

def pipelined_chain_finish_times(head_ready_s: float, head_exec_s: float,
                                 edges: Iterable[tuple],
                                 n_chunks: int = 32) -> List[float]:
    """Per-stage completion times of a pipelined chain, from chain start.

    ``head_ready_s`` is everything before the head stage's execution can
    begin (α + max(β, δ_in) for its own, non-pipelined input edge);
    ``head_exec_s`` is its γ. Each downstream element of ``edges`` is a
    ``(ready_s, wire_s, exec_s)`` triple for one pipelined consumer:
    ``ready_s`` = α + β from *chain start* (its trigger fires when the
    head dispatches), ``wire_s`` = the edge's total transfer time (δ·r +
    overhead), ``exec_s`` = its γ. Chunk k of stage i starts executing
    once it is off the wire AND chunk k−1 finished AND the stage is
    ready — the classic tandem recurrence
    ``D(i,k) = max(D(i−1,k), D(i,k−1)) + s_i`` with per-station ready
    offsets. Returns ``[finish_head, finish_1, …]``."""
    k = max(int(n_chunks), 1)
    finishes: List[float] = []
    # Head produces its output chunk-by-chunk while executing.
    prev = [head_ready_s + head_exec_s * (i + 1) / k for i in range(k)]
    finishes.append(prev[-1])
    for ready_s, wire_s, exec_s in edges:
        s_w = wire_s / k
        s_e = exec_s / k
        wire_free = 0.0
        exec_free = ready_s
        out: List[float] = []
        for i in range(k):
            wire_free = max(prev[i], wire_free) + s_w
            exec_free = max(wire_free, exec_free) + s_e
            out.append(exec_free)
        finishes.append(out[-1])
        prev = out
    return finishes


def pipelined_chain_time(head_ready_s: float, head_exec_s: float,
                         edges: Iterable[tuple],
                         n_chunks: int = 32) -> float:
    """Chain makespan under direct streaming — the last chunk's departure
    from the last stage (see ``pipelined_chain_finish_times``). Compare
    against Eq. 5's Σ(edge_time) to size the pipelining gain."""
    return pipelined_chain_finish_times(head_ready_s, head_exec_s, edges,
                                        n_chunks)[-1]


# --------------------------------------------------- locality-aware terms
# Digest-aware placement extension of Eq. 4: when a fraction f of the input
# is already resident on the chosen node, only (1−f)·δ crosses the fabric.
# Fully resident (f = 1, the fan-out alias case) degenerates the transfer
# term to 0 and τ to α + β + γ — placement itself becomes the data plane.

def effective_delta(p: PhaseEstimate, resident_fraction: float = 0.0) -> float:
    """Transfer time after locality credit: δ_eff = (1 − f)·δ, f ∈ [0, 1]."""
    f = min(max(resident_fraction, 0.0), 1.0)
    return p.delta * (1.0 - f)


def locality_truffle_time(p: PhaseEstimate,
                          resident_fraction: float = 0.0) -> float:
    """Eq. 3 with locality: τ = α + max(β, (1−f)·δ) + γ."""
    return p.alpha + max(p.beta, effective_delta(p, resident_fraction)) + p.gamma


def locality_improvement(p: PhaseEstimate,
                         resident_fraction: float = 0.0) -> float:
    """Gain of placing on a node holding fraction f of the input, vs. a
    plain Truffle placement with the full transfer:
    Δ_loc = max(β, δ) − max(β, (1−f)·δ)  (0 when δ ≤ β: already hidden)."""
    return overlap_window(p) - max(p.beta, effective_delta(p, resident_fraction))


# ------------------------------------------------------- per-edge Eq. 4 terms
# ExecutionPlan extension of Eq. 4: each workflow edge carries its own
# DataPolicy, so the transfer term δ is shaped per edge — compression
# shrinks the wire bytes (δ·r), locality removes the resident fraction
# (δ·(1−f)), streaming overlaps the remainder with execution. These terms
# compose; the planner/benchmarks use them to predict a mixed-policy plan.

def edge_delta(p: PhaseEstimate, *, wire_ratio: float = 1.0,
               resident_fraction: float = 0.0) -> float:
    """Per-edge transfer term: δ_e = r · (1 − f) · δ, r > 0, f ∈ [0, 1]
    (compression acts only on the bytes that actually move). ``r > 1``
    models a codec-bound transfer: the codec's throughput, not the wire,
    sets the effective rate (r = bandwidth / codec_bps), so compressing on
    a link faster than the codec *stretches* the transfer."""
    r = max(wire_ratio, 0.0)
    f = min(max(resident_fraction, 0.0), 1.0)
    return p.delta * r * (1.0 - f)


def edge_time(p: PhaseEstimate, *, use_truffle: bool = True,
              stream_exec_overlap: Optional[float] = None,
              wire_ratio: float = 1.0,
              resident_fraction: float = 0.0,
              overhead_s: float = 0.0) -> float:
    """Eq. 3/4 for ONE edge under its resolved policy.

    ``stream_exec_overlap`` is None for whole-blob edges; for streamed
    edges it is the portion of γ that overlaps the transfer ((n−1)·ε for
    n chunks with per-chunk compute ε — see ``pipelined_io_visible``).
    ``overhead_s`` is additive, un-compressible transfer overhead: link
    RTT, per-chunk grant overhead (n × the channel's ``chunk_overhead_s``),
    codec startup (first-chunk compression) — the terms the adaptive
    planner's chunk-size/codec grid trades against the wire time."""
    d = edge_delta(p, wire_ratio=wire_ratio,
                   resident_fraction=resident_fraction) + overhead_s
    if not use_truffle:
        return p.alpha + p.beta + d + p.gamma
    if stream_exec_overlap is None:
        return p.alpha + max(p.beta, d) + p.gamma
    return p.alpha + p.beta + max(0.0, d - p.beta - stream_exec_overlap) \
        + p.gamma


def edge_improvement(p: PhaseEstimate, **edge_kw) -> float:
    """Per-edge Δ: plain whole-blob Truffle (Eq. 3) minus the edge's time
    under its resolved policy — what this edge's policy buys."""
    return truffle_time(p) - edge_time(p, **edge_kw)


def plan_time(edges: Iterable[tuple]) -> float:
    """End-to-end over a chain of (PhaseEstimate, edge-kwargs) pairs —
    Eq. 5 with per-edge terms instead of one global configuration."""
    return sum(edge_time(p, **kw) for p, kw in edges)


# --------------------------------------------------- re-planning drift terms
# Mid-flight re-planning extension of Eqs. 4/5: a compiled plan carries a
# frozen per-stage prediction; between stage waves the same per-edge model
# is re-evaluated against CURRENT telemetry over the not-yet-dispatched
# subgraph. The ratio between the fresh and frozen remaining-time sums is
# the drift signal a ReplanPolicy thresholds.

def remaining_time(preds: Iterable[Optional[float]]) -> Optional[float]:
    """Eq. 5 over the remaining (not-yet-dispatched) stages' predicted
    times. Unprofiled stages (None) are skipped — same convention as
    ``ExecutionPlan.predicted_total``; None when nothing was profiled
    (no drift signal exists, so no replan can trigger)."""
    vals = [p for p in preds if p is not None]
    return sum(vals) if vals else None


def drift(fresh_s: Optional[float], frozen_s: Optional[float]) -> float:
    """Symmetric drift ratio ``max(fresh/frozen, frozen/fresh) >= 1``
    between the re-predicted and compile-time remaining times. Both
    directions matter: a degraded link makes the frozen plan slower than
    promised (fresh > frozen), a recovered one strands it on a policy that
    is now paying for nothing (fresh < frozen). Missing or non-positive
    predictions yield 1.0 — no evidence is never drift."""
    if not fresh_s or not frozen_s or fresh_s <= 0 or frozen_s <= 0:
        return 1.0
    return max(fresh_s / frozen_s, frozen_s / fresh_s)


def should_replan(fresh_s: Optional[float], frozen_s: Optional[float],
                  drift_ratio: float) -> bool:
    """ReplanPolicy trigger: predicted remaining time drifted past the
    threshold (``drift_ratio > 1``, validated by ReplanPolicy)."""
    return drift(fresh_s, frozen_s) >= drift_ratio


# ------------------------------------------------- stage-time calibration
# Node-health / speculation extension of Eq. 4: compile-time predictions
# assume nominal node speed. A sick node inflates measured stage time by a
# roughly multiplicative factor (slow CPU, thrashing disk), so the ratio
# measured/predicted — EWMA-folded per node and per run — is both the
# health signal (suspect/degraded thresholds) and the correction applied
# to speculation budgets mid-run: a budget derived from an optimistic
# prediction would otherwise never fire on the straggler it exists for.

def stage_inflation(measured_s: Optional[float],
                    predicted_s: Optional[float]) -> Optional[float]:
    """Measured/predicted stage-time ratio; None when either side is
    missing or non-positive (no evidence — same convention as ``drift``)."""
    if not measured_s or not predicted_s \
            or measured_s <= 0 or predicted_s <= 0:
        return None
    return measured_s / predicted_s


def fold_inflation(ewma: Optional[float], ratio: float,
                   alpha: float = 0.3) -> float:
    """EWMA fold of one inflation observation (first sample seeds)."""
    if ewma is None:
        return ratio
    return ewma + alpha * (ratio - ewma)


def calibrated_budget(budget_s: Optional[float],
                      inflation: Optional[float],
                      lo: float = 0.5, hi: float = 4.0) -> Optional[float]:
    """Speculation budget rescaled by the run's measured inflation,
    clamped to [lo, hi]× the compile-time value: stages really are running
    ``inflation``× their predictions, so the straggler threshold moves
    with them — but never collapses to zero (hair-trigger backups) or
    runs away (never fires)."""
    if budget_s is None or inflation is None:
        return budget_s
    return budget_s * min(max(inflation, lo), hi)


def workflow_time(phases: Iterable[PhaseEstimate], use_truffle: bool = True) -> float:
    """Eq. 3/5: end-to-end over a function chain."""
    f = truffle_time if use_truffle else baseline_time
    return sum(f(p) for p in phases)


def should_engage(p: PhaseEstimate, is_warm: bool) -> bool:
    """Planner: hot functions gain nothing (β=0 → Δ=0); Truffle degrades to a
    transparent proxy (paper §III-B). Engage when predicted Δ > 0."""
    if is_warm:
        return False
    return improvement(p) > 0.0


def optimal_order(phase_sets: List[List[PhaseEstimate]]) -> int:
    """Eq. 5: pick the plan minimizing Σ (α + max(β,δ) + γ)."""
    times = [workflow_time(ps) for ps in phase_sets]
    return times.index(min(times))
