"""Truffle analytic latency model (paper §III-A, Eqs. 1-5).

Used three ways:
  * planner: decide whether engaging Truffle helps (hot functions: Δ=0 → proxy)
  * validation: benchmarks compare measured vs. predicted Δ (Eq. 4)
  * capacity: expected workflow latency for scheduling decisions
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List


@dataclass(frozen=True)
class PhaseEstimate:
    alpha: float      # scheduling
    nu: float         # infrastructure setup
    eta: float        # runtime startup
    delta: float      # input data transfer
    gamma: float      # function execution

    @property
    def beta(self) -> float:
        """Eq. 1: cold start β = ν + η."""
        return self.nu + self.eta


def overlap_window(p: PhaseEstimate) -> float:
    """Eq. 2: φ = max(β, δ) — cold start and transfer run concurrently."""
    return max(p.beta, p.delta)


def truffle_time(p: PhaseEstimate) -> float:
    """Eq. 3 (single function): τ = α + max(ν+η, δ) + γ."""
    return p.alpha + overlap_window(p) + p.gamma


def baseline_time(p: PhaseEstimate) -> float:
    """State-of-the-art sequential lifecycle: τ = α + β + δ + γ."""
    return p.alpha + p.beta + p.delta + p.gamma


def improvement(p: PhaseEstimate) -> float:
    """Eq. 4: Δ = (β + δ) − max(β, δ) = min(β, δ)."""
    return (p.beta + p.delta) - overlap_window(p)


def workflow_time(phases: Iterable[PhaseEstimate], use_truffle: bool = True) -> float:
    """Eq. 3/5: end-to-end over a function chain."""
    f = truffle_time if use_truffle else baseline_time
    return sum(f(p) for p in phases)


def should_engage(p: PhaseEstimate, is_warm: bool) -> bool:
    """Planner: hot functions gain nothing (β=0 → Δ=0); Truffle degrades to a
    transparent proxy (paper §III-B). Engage when predicted Δ > 0."""
    if is_warm:
        return False
    return improvement(p) > 0.0


def optimal_order(phase_sets: List[List[PhaseEstimate]]) -> int:
    """Eq. 5: pick the plan minimizing Σ (α + max(β,δ) + γ)."""
    times = [workflow_time(ps) for ps in phase_sets]
    return times.index(min(times))
