"""ZeRO-1: shard optimizer state over the DP axes.

Each (m, v) tensor inherits its parameter's TP sharding, then its largest
still-unsharded, divisible dimension is additionally sharded over the DP
axes. GSPMD inserts the reduce-scatter/all-gather pair around the update.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamDef
from repro.distributed.sharding import specs_for, dp_axes, _mesh_axis_size

PyTree = Any


def zero1_spec(param_spec: P, shape: Tuple[int, ...], mesh: Mesh,
               dp: Tuple[str, ...]) -> P:
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    # pick the largest unsharded dim divisible by |DP|
    best, best_dim = -1, -1
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % dp_size == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best >= 0:
        entries[best] = tuple(dp) if len(dp) > 1 else dp[0]
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def zero1_specs(defs: PyTree, mesh: Mesh, rules: Dict[str, Any]) -> PyTree:
    """Spec tree for optimizer-moment tensors mirroring a ParamDef tree."""
    base = specs_for(defs, mesh, rules)
    dp = dp_axes(rules)

    def f(d: ParamDef, spec: P) -> P:
        return zero1_spec(spec, d.shape, mesh, dp)

    return jax.tree.map(f, defs, base, is_leaf=lambda x: isinstance(x, ParamDef))
