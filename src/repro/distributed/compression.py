"""Compression for the two bandwidth-bound paths in the system:

1. Gradient compression for the DP all-reduce: int8 quantized
   reduce-scatter + all-gather with per-tensor scales and error feedback.
   Wire bytes vs fp32 ring all-reduce: ~4x less (1B/elem each way + scalar
   scales). Used inside a ``shard_map`` over the DP axes
   (``steps.build_train_step(..., dp_mode="shardmap_int8")``).

2. Chunk codecs for the Truffle data plane (:class:`ChunkCodec`): a WAN
   edge whose :class:`~repro.runtime.policy.DataPolicy` sets
   ``compression="lz4-like"`` ships compressed chunks through
   ``Channel.stream``/``transfer`` — the codec estimates the payload's
   compressibility from a sampled window and the channel grants only the
   compressed wire bytes. Pure stdlib; the data plane imports it lazily so
   runtime code paths never pay the jax import unless compression engages.
"""
from __future__ import annotations

import functools
import zlib
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Optional, Tuple

if TYPE_CHECKING:                     # postponed annotations only
    import jax

PyTree = Any

# jax is imported INSIDE the gradient functions (not at module top): the
# Truffle data plane resolves ChunkCodec from this module, and a WAN edge
# enabling compression must not pay a ~1s ML-stack import on its first
# dispatch (it showed up as tens of simulated seconds at small clock
# scales).


# ------------------------------------------------------- data-plane codecs
@dataclass(frozen=True)
class ChunkCodec:
    """An lz4-like chunk codec model: fast, modest-ratio byte compression.

    ``ratio`` estimates the wire/payload byte ratio by deflating a sampled
    window (zlib level 1 ≈ an upper bound on what an lz4-class codec
    keeps); ``floor`` models the codec's framing overhead — even an
    all-zeros payload ships ~5% of its bytes. ``compress_bps`` is the
    codec's steady-state throughput (single core of the paper's 4-core
    Xeon edge VMs, ~100 MB/s with small chunks): pipelined compression
    hides behind links *slower* than the codec (every WAN tier), but on a
    link faster than the codec the transfer becomes codec-bound — the
    data plane paces the stream at ``compress_bps`` and the adaptive
    planner models it as an effective wire ratio of bandwidth/codec_bps.
    ``compress_s`` prices the startup (first-chunk) compression, the only
    codec time on the critical path of a pipelined wire-bound stream."""
    name: str
    level: int = 1
    floor: float = 0.05
    compress_bps: float = 1.0e8           # bytes/sec, single core
    sample_bytes: int = 64 * 1024
    #: compressibility probe: "deflate" (measure: zlib level 1 on the
    #: window — the default, what the pinned benchmark numbers were taken
    #: with) or "entropy" (estimate: the jax byte-histogram kernel in
    #: ``repro.kernels.ops`` — vectorizable/offloadable, but order-0 only:
    #: blind to match structure, so strictly an opt-in)
    estimator: str = "deflate"

    def ratio(self, data) -> float:
        view = bytes(memoryview(data)[:self.sample_bytes])
        if not view:
            return 1.0
        if self.estimator == "entropy":
            # lazy: the runtime data plane must not pay the ML-stack
            # import unless a plan actually selects the entropy codec
            from repro.kernels.ops import entropy_wire_ratio
            return entropy_wire_ratio(view, floor=self.floor)
        compressed = zlib.compress(view, self.level)
        return min(1.0, max(self.floor, len(compressed) / len(view)))

    def compress_s(self, nbytes: int) -> float:
        return max(nbytes, 0) / self.compress_bps


LZ4_LIKE = ChunkCodec("lz4-like")
#: same codec model, entropy-probed: the ratio estimate comes from the
#: jit'd byte-histogram kernel instead of deflating the sample window
LZ4_ENTROPY = ChunkCodec("lz4-entropy", estimator="entropy")
_CHUNK_CODECS = {"lz4-like": LZ4_LIKE, "lz4-entropy": LZ4_ENTROPY}


def chunk_codec(name: Optional[str]) -> Optional[ChunkCodec]:
    """Resolve a :class:`~repro.runtime.policy.DataPolicy.compression`
    value to a codec (``None``/"none" -> no codec)."""
    if name in (None, "none"):
        return None
    try:
        return _CHUNK_CODECS[name]
    except KeyError:
        raise KeyError(f"no chunk codec {name!r} "
                       f"(have: {sorted(_CHUNK_CODECS)})") from None


def _axis_size(axis_name: str) -> int:
    """jax.lax.axis_size (jax >= 0.6) with the 0.4.x psum(1) idiom as
    fallback (statically concretized under shard_map/pmap tracing)."""
    import jax
    impl = getattr(jax.lax, "axis_size", None)
    if impl is not None:
        return impl(axis_name)
    return jax.lax.psum(1, axis_name)


def quantize(x: jax.Array, bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor quantization -> (int8 codes, fp32 scale)."""
    import jax.numpy as jnp
    assert bits == 8, "int8 path only"
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    import jax.numpy as jnp
    return q.astype(jnp.float32) * scale


def quantization_error(x: jax.Array) -> jax.Array:
    """Residual for error feedback: x - dequant(quant(x))."""
    q, s = quantize(x)
    return x - dequantize(q, s)


def compressed_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` using int8 RS+AG (call inside shard_map).

    Stage 1 (reduce-scatter): all_to_all int8 chunks; each device dequantizes
    its chunk from every peer (per-peer scales via a tiny fp32 all_gather)
    and reduces in fp32. Stage 2 (all-gather): requantize the reduced chunk
    and gather codes+scales."""
    import jax
    import jax.numpy as jnp
    n = _axis_size(axis_name)
    if n == 1:
        return x
    size = x.size
    chunk = -(-size // n)
    flat = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, chunk * n - size))
    xs = flat.reshape(n, chunk)

    q, s = quantize(xs)
    qt = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                            tiled=False)              # [n, chunk] peers' rows
    ss = jax.lax.all_gather(s, axis_name)             # [n]
    mine = jnp.sum(dequantize(qt, ss[:, None, None] if qt.ndim == 3
                              else ss[:, None]), axis=0) / n

    q2, s2 = quantize(mine)
    qg = jax.lax.all_gather(q2, axis_name)            # [n, chunk]
    sg = jax.lax.all_gather(s2, axis_name)            # [n]
    out = dequantize(qg, sg[:, None]).reshape(-1)[:size]
    return out.reshape(x.shape).astype(x.dtype)


def compressed_grad_sync(grads: PyTree, axis_name: str) -> PyTree:
    """Apply compressed_mean leaf-wise (large leaves only; small ones go
    fp32 — scales/biases are latency- not bandwidth-bound)."""
    import jax

    def sync(g):
        if g.size < 16384:
            return jax.lax.pmean(g, axis_name)
        return compressed_mean(g, axis_name)
    return jax.tree.map(sync, grads)
