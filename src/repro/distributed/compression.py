"""Gradient compression for the DP all-reduce: int8 quantized
reduce-scatter + all-gather with per-tensor scales and error feedback.

Wire bytes vs fp32 ring all-reduce: ~4x less (1B/elem each way + scalar
scales). Used inside a ``shard_map`` over the DP axes
(``steps.build_train_step(..., dp_mode="shardmap_int8")`` lowers it in the
dry-run so the collective-term reduction is visible in the §Perf log)."""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _axis_size(axis_name: str) -> int:
    """jax.lax.axis_size (jax >= 0.6) with the 0.4.x psum(1) idiom as
    fallback (statically concretized under shard_map/pmap tracing)."""
    impl = getattr(jax.lax, "axis_size", None)
    if impl is not None:
        return impl(axis_name)
    return jax.lax.psum(1, axis_name)


def quantize(x: jax.Array, bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor quantization -> (int8 codes, fp32 scale)."""
    assert bits == 8, "int8 path only"
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantization_error(x: jax.Array) -> jax.Array:
    """Residual for error feedback: x - dequant(quant(x))."""
    q, s = quantize(x)
    return x - dequantize(q, s)


def compressed_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` using int8 RS+AG (call inside shard_map).

    Stage 1 (reduce-scatter): all_to_all int8 chunks; each device dequantizes
    its chunk from every peer (per-peer scales via a tiny fp32 all_gather)
    and reduces in fp32. Stage 2 (all-gather): requantize the reduced chunk
    and gather codes+scales."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    size = x.size
    chunk = -(-size // n)
    flat = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, chunk * n - size))
    xs = flat.reshape(n, chunk)

    q, s = quantize(xs)
    qt = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                            tiled=False)              # [n, chunk] peers' rows
    ss = jax.lax.all_gather(s, axis_name)             # [n]
    mine = jnp.sum(dequantize(qt, ss[:, None, None] if qt.ndim == 3
                              else ss[:, None]), axis=0) / n

    q2, s2 = quantize(mine)
    qg = jax.lax.all_gather(q2, axis_name)            # [n, chunk]
    sg = jax.lax.all_gather(s2, axis_name)            # [n]
    out = dequantize(qg, sg[:, None]).reshape(-1)[:size]
    return out.reshape(x.shape).astype(x.dtype)


def compressed_grad_sync(grads: PyTree, axis_name: str) -> PyTree:
    """Apply compressed_mean leaf-wise (large leaves only; small ones go
    fp32 — scales/biases are latency- not bandwidth-bound)."""
    def sync(g):
        if g.size < 16384:
            return jax.lax.pmean(g, axis_name)
        return compressed_mean(g, axis_name)
    return jax.tree.map(sync, grads)
