"""Logical-axis sharding: rules map logical names -> mesh axes (GSPMD).

Rules are plain dicts ``{logical_axis: mesh_axis | tuple | None}``. Spec
construction checks divisibility — an axis that does not divide evenly falls
back to replication (e.g. glm4's 2 KV heads on a 16-way model axis).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamDef

PyTree = Any

# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------

def default_rules(multi_pod: bool = False, *, seq_shard_decode: bool = False) -> Dict[str, Any]:
    """Megatron-style TP over 'model', DP over ('pod','data').

    ``seq_shard_decode``: shard long KV caches over the *data* axis
    (flash-decode sequence parallelism) — used by decode/long shapes where
    the cache, not the weights, is the resident giant.
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    rules: Dict[str, Any] = {
        # --- parameters ---
        "vocab": "model",
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "expert": "model",
        "mamba_inner": "model",
        "layers": None,
        "lora": None,
        # --- activations ---
        "batch": dp,
        "dp_groups": dp,          # MoE shard-local dispatch groups
        "seq": None,
        "act_heads": "model",
        "act_ff": "model",
        # --- kv cache ---
        "cache_batch": dp,
        "cache_seq": "data" if seq_shard_decode else None,
        "cache_heads": "model" if not seq_shard_decode else None,
    }
    return rules


def dp_axes(rules: Dict[str, Any]) -> Tuple[str, ...]:
    b = rules["batch"]
    return tuple(b) if isinstance(b, (tuple, list)) else (b,)


def rules_for_shape(kind: str, *, multi_pod: bool = False,
                    global_batch: int = 0, seq_len: int = 0) -> Dict[str, Any]:
    """Per-shape rule presets.

    train/prefill: Megatron TP + DP.
    decode: KV cache sequence-sharded over 'model' (flash-decode layout) —
      robust to tiny KV-head counts (glm4 kv=2, qwen2-vl kv=4) and keeps the
      resident cache, not the weights, as the sharded giant.
    long-context decode (batch=1): cache sequence sharded over ALL axes.
    """
    rules = default_rules(multi_pod)
    if kind == "decode":
        if global_batch == 1:
            rules["cache_batch"] = None
            rules["batch"] = None
            rules["cache_seq"] = (("pod", "data", "model") if multi_pod
                                  else ("data", "model"))
        else:
            rules["cache_seq"] = "model"
        rules["cache_heads"] = None
    return rules


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------

def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def spec_for_axes(mesh: Optional[Mesh], rules: Dict[str, Any],
                  shape: Sequence[int], axes: Sequence[Optional[str]]) -> P:
    """PartitionSpec for (shape, logical axes) under rules; divisibility-safe."""
    if mesh is None:
        return P()
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        mesh_axis = rules.get(name) if name is not None else None
        if mesh_axis is None:
            entries.append(None)
            continue
        key = tuple(mesh_axis) if isinstance(mesh_axis, (tuple, list)) else (mesh_axis,)
        if used & set(key):  # a mesh axis can shard only one dim
            entries.append(None)
            continue
        if dim % _mesh_axis_size(mesh, mesh_axis) != 0:
            entries.append(None)
            continue
        used |= set(key)
        entries.append(tuple(key) if len(key) > 1 else key[0])
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def specs_for(defs: PyTree, mesh: Optional[Mesh], rules: Dict[str, Any]) -> PyTree:
    """PartitionSpec tree mirroring a ParamDef tree."""
    def f(d: ParamDef) -> P:
        return spec_for_axes(mesh, rules, d.shape, d.axes)
    return jax.tree.map(f, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def shardings_for(defs: PyTree, mesh: Optional[Mesh], rules: Dict[str, Any]) -> PyTree:
    specs = specs_for(defs, mesh, rules)
    if mesh is None:
        return specs
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation constraints — threaded through model code as a context object.
# ---------------------------------------------------------------------------

class ShardCtx:
    """Carries (mesh, rules) into model forward code; no-op off-mesh."""

    def __init__(self, mesh: Optional[Mesh] = None, rules: Optional[Dict[str, Any]] = None):
        self.mesh = mesh
        self.rules = rules or {}

    def constrain(self, x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
        if self.mesh is None:
            return x
        spec = spec_for_axes(self.mesh, self.rules, x.shape, axes)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def axis_size(self, logical: str) -> int:
        if self.mesh is None:
            return 1
        return _mesh_axis_size(self.mesh, self.rules.get(logical))


NULL_CTX = ShardCtx()
