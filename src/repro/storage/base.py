"""Storage services: in-process Direct / KVS / Object-store with calibrated
latency+bandwidth. Real bytes move; measured time = modeled time.

Calibration targets the paper's testbed (4-core Xeon VMs, MicroK8s LAN +
AWS S3): KVS reads fast / writes slower (paper Fig 9b: Truffle gains only
~5% on KVS because little read time is left to mask), S3 slow both ways
(Fig 9c: ~18% gain). See EXPERIMENTS.md §Calibration.

Streaming (chunked data plane): ``get_stream``/``put_stream`` move the same
bytes chunk-at-a-time over the service channels (default chunk:
``DEFAULT_CHUNK_BYTES``), so the Data Engine can pipeline storage-get ->
relay -> buffer-append instead of waiting for the last byte. A
``put_stream`` in progress is *tailable*: a concurrent ``get_stream`` on
the same key attaches to the in-flight object and yields each chunk as the
writer lands it (reader chases writer), raising ``IOError`` if the writer
aborts mid-stream — so storage-strategy edges pipeline producer→consumer
too. The whole-blob ``get``/``put`` (and ``exists``) still see an object
only once its last chunk lands. ``digest`` returns (and caches) the
content address of a stored object for content-addressed dedup
downstream."""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

from repro.core.buffer import content_digest
from repro.runtime.clock import Clock, DEFAULT_CLOCK
from repro.runtime.netsim import Channel, DEFAULT_CHUNK_BYTES, GBPS


class StorageError(KeyError):
    pass


class _InflightObject:
    """A ``put_stream`` in progress: chunk list shared with tail readers."""

    __slots__ = ("chunks", "complete", "aborted")

    def __init__(self) -> None:
        self.chunks: List[bytes] = []
        self.complete = False
        self.aborted = False


@dataclass
class StorageService:
    """Key-value blob service with asymmetric put/get channels."""
    type_name: str = "generic"
    put_bandwidth: float = 1.0 * GBPS
    get_bandwidth: float = 1.0 * GBPS
    latency: float = 0.001
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)

    def __post_init__(self) -> None:
        self._data: Dict[str, bytes] = {}
        self._digests: Dict[str, str] = {}
        self._inflight: Dict[str, _InflightObject] = {}
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._put_ch = Channel(f"{self.type_name}.put", self.put_bandwidth,
                               self.latency, self.clock)
        self._get_ch = Channel(f"{self.type_name}.get", self.get_bandwidth,
                               self.latency, self.clock)

    def put(self, key: str, data: bytes) -> float:
        t = self._put_ch.transfer(data)
        with self._lock:
            self._data[key] = data
            self._digests.pop(key, None)
        return t

    def get(self, key: str) -> Tuple[bytes, float]:
        data = self._require(key)
        t = self._get_ch.transfer(data)
        return data, t

    # ------------------------------------------------------------- streaming
    def get_stream(self, key: str,
                   chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                   timeout: Optional[float] = 120.0) -> Iterator[bytes]:
        """Yield the object chunk-by-chunk as each chunk "arrives" off the
        read channel (per-chunk bandwidth grants — fair-share). If a
        ``put_stream`` for ``key`` is in flight, TAIL it instead: each
        chunk is yielded as the writer lands it (chunks sized by the
        writer), raising IOError if the writer aborts mid-stream and
        TimeoutError if the next chunk never arrives within ``timeout``."""
        with self._lock:
            obj = self._inflight.get(key)
        if obj is None:
            data = self._require(key)
            return self._get_ch.stream(data, chunk_bytes)
        return self._tail_stream(key, obj, timeout)

    def _tail_stream(self, key: str, obj: _InflightObject,
                     timeout: Optional[float]) -> Iterator[bytes]:
        """Chase an in-flight writer chunk-by-chunk. Channel time is paid
        OUTSIDE the service lock (channels serialize their own grants)."""
        idx = 0
        first = True
        deadline = None
        while True:
            with self._cond:
                while (idx >= len(obj.chunks) and not obj.complete
                       and not obj.aborted):
                    if not self._cond.wait(timeout):
                        raise TimeoutError(
                            f"{self.type_name}: tail of {key!r} stalled "
                            f"at chunk {idx}")
                if obj.aborted:
                    raise IOError(f"{self.type_name}: in-flight object "
                                  f"{key!r} aborted mid-stream")
                if idx >= len(obj.chunks):      # complete and fully drained
                    return
                chunk = obj.chunks[idx]
                idx += 1
            deadline = self._get_ch.transfer_chunk(len(chunk),
                                                   pay_latency=first,
                                                   after=deadline)
            first = False
            yield chunk

    def put_stream(self, key: str, chunks: Iterable[bytes]) -> float:
        """Consume an incoming chunk iterator, paying write-channel time per
        chunk. Each chunk becomes tailable by concurrent ``get_stream``
        readers the moment it lands; whole-blob ``get``/``exists`` see the
        object once the last chunk lands. Returns the channel-derived
        elapsed time (wall clock over the granted chunk deadlines, so
        records agree with measured time under grant contention). If the
        source iterator fails mid-stream the in-flight object is aborted
        (tail readers wake with IOError) and the error re-raised."""
        obj = _InflightObject()
        with self._cond:
            prev = self._inflight.get(key)
            if prev is not None:         # displaced writer: fail its readers
                prev.aborted = True
            self._inflight[key] = obj
            self._cond.notify_all()
        first = True
        deadline = None
        t = 0.0
        try:
            for chunk in chunks:
                chunk = bytes(chunk)     # memoryview-safe to share w/ readers
                deadline, dt = self._put_ch.transfer_chunk_timed(
                    len(chunk), pay_latency=first, after=deadline)
                t += dt
                first = False
                with self._cond:
                    if obj.aborted:
                        raise IOError(f"{self.type_name}: put_stream "
                                      f"{key!r} displaced")
                    obj.chunks.append(chunk)
                    self._cond.notify_all()
            if first:                    # empty stream still pays the RTT
                self.clock.sleep(self.latency)
                t = self.latency
            with self._cond:
                if obj.aborted:
                    raise IOError(f"{self.type_name}: put_stream "
                                  f"{key!r} displaced")
                obj.complete = True
                self._data[key] = b"".join(obj.chunks)
                self._digests.pop(key, None)
                if self._inflight.get(key) is obj:
                    del self._inflight[key]
                self._cond.notify_all()
        except BaseException:
            with self._cond:
                obj.aborted = True
                if self._inflight.get(key) is obj:
                    del self._inflight[key]
                self._cond.notify_all()
            raise
        return t

    def digest(self, key: str) -> str:
        """Content address of a stored object (computed lazily, cached)."""
        data = self._require(key)
        with self._lock:
            if key not in self._digests:
                self._digests[key] = content_digest(data)
            return self._digests[key]

    # -------------------------------------------------------------- plumbing
    def _require(self, key: str) -> bytes:
        with self._lock:
            if key not in self._data:
                raise StorageError(f"{self.type_name}: no object {key!r}")
            return self._data[key]

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def delete(self, key: str) -> None:
        with self._cond:
            self._data.pop(key, None)
            self._digests.pop(key, None)
            obj = self._inflight.pop(key, None)
            if obj is not None:          # fail tail readers, not hang them
                obj.aborted = True
                self._cond.notify_all()


def make_kvs(clock: Clock = DEFAULT_CLOCK) -> StorageService:
    """Redis-like: sub-ms latency, fast reads, slower writes (AOF/replication)."""
    return StorageService("kvs", put_bandwidth=0.40 * GBPS,
                          get_bandwidth=2.50 * GBPS, latency=0.001, clock=clock)


def make_object_store(clock: Clock = DEFAULT_CLOCK) -> StorageService:
    """S3-like: tens-of-ms latency, moderate bandwidth both ways."""
    return StorageService("s3", put_bandwidth=0.35 * GBPS,
                          get_bandwidth=0.50 * GBPS, latency=0.030, clock=clock)
