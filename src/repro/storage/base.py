"""Storage services: in-process Direct / KVS / Object-store with calibrated
latency+bandwidth. Real bytes move; measured time = modeled time.

Calibration targets the paper's testbed (4-core Xeon VMs, MicroK8s LAN +
AWS S3): KVS reads fast / writes slower (paper Fig 9b: Truffle gains only
~5% on KVS because little read time is left to mask), S3 slow both ways
(Fig 9c: ~18% gain). See EXPERIMENTS.md §Calibration.

Streaming (chunked data plane): ``get_stream``/``put_stream`` move the same
bytes chunk-at-a-time over the service channels (default chunk:
``DEFAULT_CHUNK_BYTES``), so the Data Engine can pipeline storage-get ->
relay -> buffer-append instead of waiting for the last byte. ``digest``
returns (and caches) the content address of a stored object for
content-addressed dedup downstream. The whole-blob ``get``/``put`` remain
the non-streaming baseline."""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, Optional, Tuple

from repro.core.buffer import content_digest
from repro.runtime.clock import Clock, DEFAULT_CLOCK
from repro.runtime.netsim import Channel, DEFAULT_CHUNK_BYTES, GBPS


class StorageError(KeyError):
    pass


@dataclass
class StorageService:
    """Key-value blob service with asymmetric put/get channels."""
    type_name: str = "generic"
    put_bandwidth: float = 1.0 * GBPS
    get_bandwidth: float = 1.0 * GBPS
    latency: float = 0.001
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)

    def __post_init__(self) -> None:
        self._data: Dict[str, bytes] = {}
        self._digests: Dict[str, str] = {}
        self._lock = threading.Lock()
        self._put_ch = Channel(f"{self.type_name}.put", self.put_bandwidth,
                               self.latency, self.clock)
        self._get_ch = Channel(f"{self.type_name}.get", self.get_bandwidth,
                               self.latency, self.clock)

    def put(self, key: str, data: bytes) -> float:
        t = self._put_ch.transfer(data)
        with self._lock:
            self._data[key] = data
            self._digests.pop(key, None)
        return t

    def get(self, key: str) -> Tuple[bytes, float]:
        data = self._require(key)
        t = self._get_ch.transfer(data)
        return data, t

    # ------------------------------------------------------------- streaming
    def get_stream(self, key: str,
                   chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> Iterator[bytes]:
        """Yield the object chunk-by-chunk as each chunk "arrives" off the
        read channel (per-chunk bandwidth grants — fair-share)."""
        data = self._require(key)
        return self._get_ch.stream(data, chunk_bytes)

    def put_stream(self, key: str, chunks: Iterable[bytes]) -> float:
        """Consume an incoming chunk iterator, paying write-channel time per
        chunk; the object becomes visible once the last chunk lands."""
        t = self.latency
        first = True
        deadline = None
        parts = []
        for chunk in chunks:
            deadline = self._put_ch.transfer_chunk(len(chunk),
                                                   pay_latency=first,
                                                   after=deadline)
            first = False
            t += len(chunk) / self.put_bandwidth
            parts.append(chunk)
        with self._lock:
            self._data[key] = b"".join(parts)   # joins bytes and memoryviews
            self._digests.pop(key, None)
        return t

    def digest(self, key: str) -> str:
        """Content address of a stored object (computed lazily, cached)."""
        data = self._require(key)
        with self._lock:
            if key not in self._digests:
                self._digests[key] = content_digest(data)
            return self._digests[key]

    # -------------------------------------------------------------- plumbing
    def _require(self, key: str) -> bytes:
        with self._lock:
            if key not in self._data:
                raise StorageError(f"{self.type_name}: no object {key!r}")
            return self._data[key]

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)
            self._digests.pop(key, None)


def make_kvs(clock: Clock = DEFAULT_CLOCK) -> StorageService:
    """Redis-like: sub-ms latency, fast reads, slower writes (AOF/replication)."""
    return StorageService("kvs", put_bandwidth=0.40 * GBPS,
                          get_bandwidth=2.50 * GBPS, latency=0.001, clock=clock)


def make_object_store(clock: Clock = DEFAULT_CLOCK) -> StorageService:
    """S3-like: tens-of-ms latency, moderate bandwidth both ways."""
    return StorageService("s3", put_bandwidth=0.35 * GBPS,
                          get_bandwidth=0.50 * GBPS, latency=0.030, clock=clock)
