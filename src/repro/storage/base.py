"""Storage services: in-process Direct / KVS / Object-store with calibrated
latency+bandwidth. Real bytes move; measured time = modeled time.

Calibration targets the paper's testbed (4-core Xeon VMs, MicroK8s LAN +
AWS S3): KVS reads fast / writes slower (paper Fig 9b: Truffle gains only
~5% on KVS because little read time is left to mask), S3 slow both ways
(Fig 9c: ~18% gain). See EXPERIMENTS.md §Calibration."""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.runtime.clock import Clock, DEFAULT_CLOCK
from repro.runtime.netsim import Channel, GBPS


class StorageError(KeyError):
    pass


@dataclass
class StorageService:
    """Key-value blob service with asymmetric put/get channels."""
    type_name: str = "generic"
    put_bandwidth: float = 1.0 * GBPS
    get_bandwidth: float = 1.0 * GBPS
    latency: float = 0.001
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)

    def __post_init__(self) -> None:
        self._data: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self._put_ch = Channel(f"{self.type_name}.put", self.put_bandwidth,
                               self.latency, self.clock)
        self._get_ch = Channel(f"{self.type_name}.get", self.get_bandwidth,
                               self.latency, self.clock)

    def put(self, key: str, data: bytes) -> float:
        t = self._put_ch.transfer(data)
        with self._lock:
            self._data[key] = data
        return t

    def get(self, key: str) -> Tuple[bytes, float]:
        with self._lock:
            if key not in self._data:
                raise StorageError(f"{self.type_name}: no object {key!r}")
            data = self._data[key]
        t = self._get_ch.transfer(data)
        return data, t

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)


def make_kvs(clock: Clock = DEFAULT_CLOCK) -> StorageService:
    """Redis-like: sub-ms latency, fast reads, slower writes (AOF/replication)."""
    return StorageService("kvs", put_bandwidth=0.40 * GBPS,
                          get_bandwidth=2.50 * GBPS, latency=0.001, clock=clock)


def make_object_store(clock: Clock = DEFAULT_CLOCK) -> StorageService:
    """S3-like: tens-of-ms latency, moderate bandwidth both ways."""
    return StorageService("s3", put_bandwidth=0.35 * GBPS,
                          get_bandwidth=0.50 * GBPS, latency=0.030, clock=clock)
