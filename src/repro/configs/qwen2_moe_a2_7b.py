"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) routed expert
d_ff=1408 vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936, head_dim=128,
    qkv_bias=True,
    block_pattern=(("attn", "moe"),),
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408, num_shared=4),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, loss_chunk=0,
    moe=MoEConfig(num_experts=6, top_k=2, d_expert=64, num_shared=2),
)
