"""xlstm-125m [ssm] — 12L d_model=768 4H vocab=50304, d_ff=0 (block-internal
projections only). sLSTM blocks at layers 3 and 9 (period-6 pattern), mLSTM
elsewhere; mLSTM proj factor 2.0, sLSTM GLU-FFN factor 4/3.
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig, XLSTMConfig

_PATTERN = (
    ("mlstm", "none"), ("mlstm", "none"), ("mlstm", "none"),
    ("slstm", "glu"), ("mlstm", "none"), ("mlstm", "none"),
)

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=192,
    block_pattern=_PATTERN, tie_embeddings=True,
    xlstm=XLSTMConfig(mlstm_proj_factor=2.0, slstm_ffn_factor=4.0 / 3.0, chunk=128),
)

SMOKE = CONFIG.replace(
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    vocab_size=512, loss_chunk=0,
    xlstm=XLSTMConfig(mlstm_proj_factor=2.0, slstm_ffn_factor=4.0 / 3.0, chunk=8),
)
