"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig, shape_applicable

_MODULES: Dict[str, str] = {
    "glm4-9b": "glm4_9b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen3-4b": "qwen3_4b",
    "stablelm-1.6b": "stablelm_1_6b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "whisper-medium": "whisper_medium",
    "xlstm-125m": "xlstm_125m",
    "qwen2-vl-7b": "qwen2_vl_7b",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


def iter_cells():
    """All (arch, shape) cells with applicability flags — 40 total."""
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            yield arch, shape, ok, why
