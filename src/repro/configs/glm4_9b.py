"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
RoPE (partial, 0.5 of head_dim), GQA, QKV bias. [hf:THUDM/glm-4-9b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=151552, head_dim=128,
    partial_rotary=0.5, qkv_bias=True, rope_theta=1e4,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, loss_chunk=0,
)
