"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2. Mamba+attn 1:7 interleave (attn period 8 offset 4),
MoE every 2nd layer (offset 1). No positional encoding on attention layers.
[arXiv:2403.19887]"""
from repro.configs.base import MambaConfig, MoEConfig, ModelConfig

_PATTERN = tuple(
    ("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    partial_rotary=0.0,  # Jamba attention layers use no positional encoding
    block_pattern=_PATTERN,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=256),
)

SMOKE = CONFIG.replace(
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, loss_chunk=0,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=128),
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2, chunk=16),
)
