"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448.
Multi-head Latent Attention (MLA): latent KV cache (kv_lora 256 + rope 32
per token vs 2*40*64 for vanilla MHA — the CSP handoff payload shrinks ~18x).
[hf:openbmb/MiniCPM3-4B]"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=6400, vocab_size=73448, head_dim=64,
    attention_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, loss_chunk=0,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
)
