"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. The layer stack
is described by a repeating *superblock* pattern (``block_pattern``) so that
heterogeneous stacks (Jamba's 1:7 attn:mamba interleave, xLSTM's m/s pattern)
lower to a single ``lax.scan`` over ``num_layers // len(block_pattern)``
periods — compile time stays O(1) in depth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_expert: int = 0              # expert FFN hidden size (0 -> use d_ff)
    num_shared: int = 0            # shared (always-on) experts, each d_expert wide
    capacity_factor: float = 1.25  # train-time token capacity per expert
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)
    chunk: int = 256               # scan chunk (memory/parallelism trade-off)
    # §Perf: compute SSM params (A_bar/Bx) per chunk inside the scan (True)
    # vs materializing them for the full sequence (False, paper-naive).
    perchunk_params: bool = True


@dataclass(frozen=True)
class XLSTMConfig:
    # positions (mod len(block_pattern)) handled via block_pattern entries
    mlstm_proj_factor: float = 2.0
    slstm_ffn_factor: float = 4.0 / 3.0
    chunk: int = 128               # mLSTM chunkwise-parallel chunk length


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). Frontend is a STUB:
    ``input_specs`` supplies precomputed frame embeddings."""
    num_layers: int = 24
    num_frames: int = 1500         # whisper-medium: 30 s -> 1500 frames


@dataclass(frozen=True)
class VisionConfig:
    """VLM frontend STUB: precomputed patch embeddings + M-RoPE sections."""
    num_image_tokens: int = 1024
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w over head_dim/2


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # --- attention ---
    attention_type: str = "gqa"    # gqa | mla
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    partial_rotary: float = 1.0    # fraction of head_dim that is rotated
    mla: Optional[MLAConfig] = None

    # --- layer stack ---
    # One *superblock* period; each entry is (mixer, mlp):
    #   mixer in {attn, mamba, mlstm, slstm}; mlp in {mlp, moe, none, glu}
    # Dense default: (("attn", "mlp"),)
    block_pattern: Tuple[Tuple[str, str], ...] = (("attn", "mlp"),)
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "silu"              # silu (SwiGLU MLP) | gelu (plain MLP)
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None   # != None -> enc-dec (whisper)
    vision: Optional[VisionConfig] = None     # != None -> VLM (qwen2-vl)

    # --- numerics / performance knobs (hillclimb levers) ---
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"   # canonical parameter dtype
    remat: str = "dots"            # none | dots | full  (train-time only)
    loss_chunk: int = 2048         # vocab-loss computed over seq chunks (memory)
    scan_layers: bool = True       # lax.scan over superblocks (vs unrolled)
    unroll_scans: bool = False     # unroll inner seq-chunk scans (probe compiles)
    kv_cache_dtype: str = "model"  # model | int8 (quantized decode cache)
    attention_impl: str = "xla"    # xla | pallas | pallas_interpret

    # Sub-quadratic? (drives long_500k applicability)
    @property
    def subquadratic(self) -> bool:
        mixers = {m for m, _ in self.block_pattern}
        return bool(mixers & {"mamba", "mlstm", "slstm"})

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.period == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"block_pattern period={self.period}")
        return self.num_layers // self.period

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for 6ND model-flops accounting) ----
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count (embedding included once)."""
        d, h = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.attention_type == "mla":
                m = self.mla
                qdim = n_q * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                return (d * m.q_lora_rank + m.q_lora_rank * qdim
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                        + n_q * m.v_head_dim * d)
            return d * (n_q * h) + 2 * d * (n_kv * h) + (n_q * h) * d

        def mlp_params(dff: int) -> int:
            n_mat = 3 if self.act == "silu" else 2
            return n_mat * d * dff

        def moe_params(active: bool) -> int:
            m = self.moe
            dff = m.d_expert or self.d_ff
            n_e = (m.top_k if active else m.num_experts) + m.num_shared
            return n_e * mlp_params(dff) + d * m.num_experts

        def mamba_params() -> int:
            mc = self.mamba
            d_in = mc.expand * d
            dt_rank = mc.dt_rank or -(-d // 16)
            return (d * 2 * d_in + mc.d_conv * d_in
                    + d_in * (dt_rank + 2 * mc.d_state) + dt_rank * d_in
                    + d_in * mc.d_state + d_in + d_in * d)

        def mlstm_params() -> int:
            d_in = int(self.xlstm.mlstm_proj_factor * d)
            # up(2x), q/k/v, gates (i,f,o from x), down
            return d * 2 * d_in + 3 * d_in * d_in + 3 * d_in + d_in * d

        def slstm_params() -> int:
            dff = int(self.xlstm.slstm_ffn_factor * d)
            # 4 gates x (input + recurrent) + GLU ffn
            return 4 * (d * d + d * d // max(self.num_heads, 1)) + 3 * d * dff

        per_period = 0
        for mixer, mlp in self.block_pattern:
            per_period += {"attn": attn_params, "mamba": mamba_params,
                           "mlstm": mlstm_params, "slstm": slstm_params}[mixer]()
            if mlp == "mlp":
                per_period += mlp_params(self.d_ff)
            elif mlp == "moe":
                per_period += moe_params(active_only)
            elif mlp == "glu":
                per_period += mlp_params(int(self.xlstm.slstm_ffn_factor * d)) if self.xlstm else mlp_params(self.d_ff)
        total += per_period * self.num_periods

        if self.encoder is not None:  # whisper: encoder self-attn + mlp, decoder cross-attn
            enc = self.encoder.num_layers * (attn_params() + mlp_params(self.d_ff))
            xattn = self.num_layers * attn_params()
            total += enc + xattn
        return int(total)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (all 10 archs share this grid).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
