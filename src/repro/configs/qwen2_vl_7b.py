"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064. M-RoPE (t/h/w sections 16/24/24 over head_dim/2). Vision
frontend is a STUB: input_specs supplies precomputed patch embeddings and
M-RoPE position ids. [arXiv:2409.12191]"""
from repro.configs.base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6,
    vision=VisionConfig(num_image_tokens=1024, mrope_sections=(16, 24, 24)),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, loss_chunk=0,
    vision=VisionConfig(num_image_tokens=8, mrope_sections=(2, 3, 3)),
)
