"""whisper-medium [audio] — enc-dec, 24L each, d_model=1024 16H (MHA)
d_ff=4096 vocab=51865. Conv/log-mel frontend is a STUB: input_specs supplies
precomputed frame embeddings [B, 1500, 1024]. [arXiv:2212.04356; unverified]"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=51865, head_dim=64,
    norm="layernorm", act="gelu", qkv_bias=True, tie_embeddings=True,
    encoder=EncoderConfig(num_layers=24, num_frames=1500),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, loss_chunk=0,
    encoder=EncoderConfig(num_layers=2, num_frames=24),
)
