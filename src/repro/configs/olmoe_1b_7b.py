"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (kv=16) expert d_ff=1024
vocab=50304, MoE 64e top-8 on every layer, QK-norm. [arXiv:2409.02060]"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    qk_norm=True,
    block_pattern=(("attn", "moe"),),
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, loss_chunk=0,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=128),
)
