"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.
QK-norm, GQA, head_dim=128. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=9728, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, loss_chunk=0,
)
