"""Unified model API: dispatches lm.py vs whisper.py by family, and builds
the abstract ``input_specs`` (ShapeDtypeStructs) every dry-run cell lowers
against — the same pattern production launchers use (no allocation)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models import lm, whisper
from repro.models.params import abstract_params, init_params

Params = Dict[str, Any]


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.encoder is not None


def model_defs(cfg: ModelConfig):
    return whisper.whisper_defs(cfg) if is_encdec(cfg) else lm.lm_defs(cfg)


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    return init_params(model_defs(cfg), key, cfg.param_dtype)


def abstract(cfg: ModelConfig) -> Params:
    return abstract_params(model_defs(cfg), cfg.param_dtype)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            ctx: ShardCtx = NULL_CTX):
    if is_encdec(cfg):
        return whisper.loss_fn(cfg, params, batch, ctx)
    return lm.loss_fn(cfg, params, batch, ctx)


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            ctx: ShardCtx = NULL_CTX):
    if is_encdec(cfg):
        return whisper.prefill(cfg, params, batch["frames"], batch["tokens"], ctx)
    return lm.prefill(cfg, params, batch["tokens"], ctx=ctx,
                      vision_embeds=batch.get("vision_embeds"),
                      mrope_positions=batch.get("mrope_positions"))


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                token: jax.Array, pos: jax.Array, ctx: ShardCtx = NULL_CTX):
    if is_encdec(cfg):
        return whisper.decode_step(cfg, params, cache, token, pos, ctx)
    return lm.decode_step(cfg, params, cache, token, pos, ctx=ctx)


def cache_sds(cfg: ModelConfig, batch: int, max_len: int):
    if is_encdec(cfg):
        return whisper.cache_sds(cfg, batch, max_len)
    return lm.cache_sds(cfg, batch, max_len)


def cache_axes(cfg: ModelConfig, batch: int = 1, max_len: int = 8):
    """Logical-axis tree matching cache_sds structure."""
    if is_encdec(cfg):
        return whisper.cache_axes_tree()
    _, _, axes = lm.cache_spec(cfg, batch, max_len)
    return axes


def make_cache(cfg: ModelConfig, batch: int, max_len: int):
    if is_encdec(cfg):
        sds = whisper.cache_sds(cfg, batch, max_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)
    return lm.make_cache(cfg, batch, max_len)


# ---------------------------------------------------------------------------
# Abstract input specs per (arch x shape) — the dry-run contract.
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.dtype(jnp.int32)
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct

    if shape.kind == "train":
        batch: Dict[str, Any] = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if is_encdec(cfg):
            batch["frames"] = sds((B, cfg.encoder.num_frames, cfg.d_model), dt)
        if cfg.vision is not None:
            batch["vision_embeds"] = sds((B, cfg.vision.num_image_tokens, cfg.d_model), dt)
            batch["mrope_positions"] = sds((B, 3, S), i32)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), i32)}
        if is_encdec(cfg):
            batch["frames"] = sds((B, cfg.encoder.num_frames, cfg.d_model), dt)
        if cfg.vision is not None:
            batch["vision_embeds"] = sds((B, cfg.vision.num_image_tokens, cfg.d_model), dt)
            batch["mrope_positions"] = sds((B, 3, S), i32)
        return {"batch": batch}

    # decode: one new token against a seq_len cache
    return {
        "cache": cache_sds(cfg, B, S),
        "token": sds((B, 1), i32),
        "pos": sds((), i32),
    }


def concrete_inputs(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> Dict[str, Any]:
    """Small concrete version of input_specs (smoke tests / examples)."""
    key = jax.random.PRNGKey(seed)
    specs = input_specs(cfg, shape)

    def mk(s: jax.ShapeDtypeStruct):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jax.random.randint(key, s.shape, 0, max(cfg.vocab_size - 1, 2)
                                      ).astype(s.dtype)
        return jax.random.normal(key, s.shape, jnp.float32).astype(s.dtype) * 0.1

    out = jax.tree.map(mk, specs)
    if "pos" in out:
        out["pos"] = jnp.asarray(shape.seq_len - 1, jnp.int32)
        out["cache"] = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                    specs["cache"])
    if "batch" in out and "mrope_positions" in out.get("batch", {}):
        B, _, S = specs["batch"]["mrope_positions"].shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, 3, S))
        out["batch"]["mrope_positions"] = pos
    return out
