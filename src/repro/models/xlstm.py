"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly recurrent), after arXiv:2405.04517.

TPU adaptation: mLSTM's recurrent form is reorganized into a *chunkwise*
algorithm — intra-chunk attention-like einsums (MXU-friendly matmuls) plus an
inter-chunk carried state (C, n, m), all in stabilized log-space. sLSTM is an
exact ``lax.scan`` recurrence (its memory-mixing recurrence is inherently
sequential; that is the point of the architecture).

Simplifications vs. the reference implementation (noted in DESIGN.md):
the mLSTM block's causal-conv pre-layer and learnable skip are omitted;
output gating uses the block's z-branch (silu) as in the paper's block figure.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models.params import ParamDef, dense

Params = Dict[str, Any]
MIN_LOG = -1e30


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = int(cfg.xlstm.mlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    return d_in, H, d_in // H


def mlstm_defs(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_in, H, _ = _mlstm_dims(cfg)
    return {
        "up_proj": dense(d, 2 * d_in, ("embed", "heads")),
        "wq": dense(d_in, d_in, ("heads", None)),
        "wk": dense(d_in, d_in, ("heads", None)),
        "wv": dense(d_in, d_in, ("heads", None)),
        "w_i": dense(d_in, H, (None, None)),
        "b_i": ParamDef((H,), (None,), "zeros"),
        "w_f": dense(d_in, H, (None, None)),
        "b_f": ParamDef((H,), (None,), "ones", scale=3.0),  # long-memory bias
        "mh_norm": ParamDef((d_in,), ("heads",), "ones"),
        "down_proj": dense(d_in, d, ("heads", "embed")),
    }


def mlstm_cache_shapes(cfg: ModelConfig, batch: int) -> Dict[str, Tuple[int, ...]]:
    _, H, dk = _mlstm_dims(cfg)
    return {"C": (batch, H, dk, dk), "n": (batch, H, dk), "m": (batch, H)}


def slstm_defs(cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    return {
        "w_x": dense(d, 4 * d, ("embed", "heads")),
        "b_x": ParamDef((4 * d,), ("heads",), "zeros"),
        "r": ParamDef((4, H, dh, dh), (None, "heads", None, None), "normal", dh ** -0.5),
        "gn": ParamDef((d,), ("embed",), "ones"),
    }


def slstm_cache_shapes(cfg: ModelConfig, batch: int) -> Dict[str, Tuple[int, ...]]:
    d, H = cfg.d_model, cfg.num_heads
    return {"c": (batch, d), "n": (batch, d), "h": (batch, d), "m": (batch, H)}


def _headwise_rms(x: jax.Array, scale: jax.Array, H: int, eps: float) -> jax.Array:
    """x [B,S,d_in] normalized per head (multi-head norm)."""
    B, S, d_in = x.shape
    xh = x.reshape(B, S, H, d_in // H).astype(jnp.float32)
    xh = xh * jax.lax.rsqrt(jnp.mean(jnp.square(xh), -1, keepdims=True) + eps)
    return (xh.reshape(B, S, d_in) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_chunk(q, k, v, i_pre, log_f, carry):
    """One chunk, stabilized log-space. q/k/v [B,L,H,dk]; gates [B,L,H].
    carry = (C [B,H,dk,dk], n [B,H,dk], m [B,H]), all fp32."""
    C0, n0, m0 = carry
    B, L, H, dk = q.shape
    qf = q.astype(jnp.float32) * dk ** -0.5
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)

    F = jnp.cumsum(log_f, axis=1)                            # [B,L,H]
    # intra-chunk log decay a[t,s] = F_t - F_s + i_s  (s <= t)
    a = F[:, :, None, :] - F[:, None, :, :] + i_pre[:, None, :, :]
    tri = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
    a = jnp.where(tri, a, MIN_LOG)
    b = m0[:, None, :] + F                                   # inter log decay [B,L,H]
    m_t = jnp.maximum(jnp.max(a, axis=2), b)                 # [B,L,H]

    w = jnp.exp(a - m_t[:, :, None, :]) * jnp.einsum("blhd,bshd->blsh", qf, kf)
    num = jnp.einsum("blsh,bshd->blhd", w, vf)
    den = jnp.sum(w, axis=2)                                 # [B,L,H]
    g = jnp.exp(b - m_t)                                     # [B,L,H]
    num = num + g[..., None] * jnp.einsum("blhd,bhde->blhe", qf, C0)
    den = den + g * jnp.einsum("blhd,bhd->blh", qf, n0)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # end-of-chunk carry
    FL = F[:, -1, :]                                         # [B,H]
    a_end = FL[:, None, :] - F + i_pre                       # [B,L,H]
    m_new = jnp.maximum(m0 + FL, jnp.max(a_end, axis=1))
    scale_old = jnp.exp(m0 + FL - m_new)                     # [B,H]
    wk_end = jnp.exp(a_end - m_new[:, None, :])              # [B,L,H]
    C_new = C0 * scale_old[..., None, None] + jnp.einsum("blh,blhd,blhe->bhde", wk_end, kf, vf)
    n_new = n0 * scale_old[..., None] + jnp.einsum("blh,blhd->bhd", wk_end, kf)
    return h, (C_new, n_new, m_new)


def mlstm_apply(cfg: ModelConfig, p: Params, x: jax.Array, *, mode: str,
                ctx: ShardCtx = NULL_CTX, cache: Optional[Params] = None,
                ) -> Tuple[jax.Array, Optional[Params]]:
    d_in, H, dk = _mlstm_dims(cfg)
    dt = x.dtype
    B, S, _ = x.shape
    xz = x @ p["up_proj"].astype(dt)
    xm, z = xz[..., :d_in], xz[..., d_in:]
    xm = ctx.constrain(xm, ("batch", "seq", "act_heads"))

    q = (xm @ p["wq"].astype(dt)).reshape(B, S, H, dk)
    k = (xm @ p["wk"].astype(dt)).reshape(B, S, H, dk)
    v = (xm @ p["wv"].astype(dt)).reshape(B, S, H, dk)
    i_pre = (xm @ p["w_i"].astype(dt)).astype(jnp.float32) + p["b_i"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        (xm @ p["w_f"].astype(dt)).astype(jnp.float32) + p["b_f"].astype(jnp.float32))

    if mode == "decode":
        carry = (cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32),
                 cache["m"].astype(jnp.float32))
        h, (C1, n1, m1) = _mlstm_chunk(q, k, v, i_pre, log_f, carry)
        new_cache = {"C": C1, "n": n1, "m": m1}
    else:
        L = min(cfg.xlstm.chunk, S)
        while S % L:          # largest divisor <= chunk (exact state carry)
            L -= 1
        nchunk = S // L

        def rs(t):
            return jnp.moveaxis(t.reshape(B, nchunk, L, *t.shape[2:]), 1, 0)

        def step(carry, inp):
            h, carry = _mlstm_chunk(*inp, carry)
            return carry, h

        carry0 = (jnp.zeros((B, H, dk, dk), jnp.float32),
                  jnp.zeros((B, H, dk), jnp.float32),
                  jnp.full((B, H), MIN_LOG, jnp.float32))
        carry, hs = jax.lax.scan(step, carry0, (rs(q), rs(k), rs(v), rs(i_pre), rs(log_f)),
                                 unroll=True if cfg.unroll_scans else 1)
        h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, dk)
        new_cache = ({"C": carry[0], "n": carry[1], "m": carry[2]}
                     if mode == "prefill" else None)

    h = h.reshape(B, S, d_in).astype(dt)
    h = _headwise_rms(h, p["mh_norm"], H, cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ p["down_proj"].astype(dt)
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_apply(cfg: ModelConfig, p: Params, x: jax.Array, *, mode: str,
                ctx: ShardCtx = NULL_CTX, cache: Optional[Params] = None,
                ) -> Tuple[jax.Array, Optional[Params]]:
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    dt = x.dtype
    B, S, _ = x.shape
    pre = (x @ p["w_x"].astype(dt)).astype(jnp.float32) + p["b_x"].astype(jnp.float32)
    pre = pre.reshape(B, S, 4, H, dh)                        # z, i, f, o
    r = p["r"].astype(jnp.float32)                           # [4,H,dh,dh]

    if cache is not None:
        st0 = (cache["c"].astype(jnp.float32).reshape(B, H, dh),
               cache["n"].astype(jnp.float32).reshape(B, H, dh),
               cache["h"].astype(jnp.float32).reshape(B, H, dh),
               cache["m"].astype(jnp.float32))
    else:
        st0 = (jnp.zeros((B, H, dh), jnp.float32), jnp.zeros((B, H, dh), jnp.float32),
               jnp.zeros((B, H, dh), jnp.float32), jnp.full((B, H), MIN_LOG, jnp.float32))

    def step(st, pre_t):                                     # pre_t [B,4,H,dh]
        c, n, h, m = st
        rec = jnp.einsum("bhd,ghde->gbhe", h, r)             # [4,B,H,dh]
        zt = jnp.tanh(pre_t[:, 0] + rec[0])
        it = pre_t[:, 1] + rec[1]                            # log-space input gate
        ft = jax.nn.log_sigmoid(pre_t[:, 2] + rec[2])        # log forget
        ot = jax.nn.sigmoid(pre_t[:, 3] + rec[3])
        # stabilizer per head: use max over head dims of gate pre-activations
        it_h = jnp.max(it, axis=-1)                          # [B,H]
        ft_h = jnp.min(ft, axis=-1)
        m_new = jnp.maximum(ft_h + m, it_h)
        ip = jnp.exp(it - m_new[..., None])
        fp = jnp.exp(ft + (m - m_new)[..., None])
        c_new = fp * c + ip * zt
        n_new = fp * n + ip
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    st, hs = jax.lax.scan(step, st0, jnp.moveaxis(pre, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d)

    new_cache = None
    if mode in ("prefill", "decode"):
        c, n, hh, m = st
        new_cache = {"c": c.reshape(B, d), "n": n.reshape(B, d),
                     "h": hh.reshape(B, d), "m": m}

    out = _headwise_rms(h.astype(dt), p["gn"], H, cfg.norm_eps)
    return out, new_cache
