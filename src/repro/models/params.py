"""Parameter definition trees.

A module's parameters are declared once as a nested dict of ``ParamDef``
leaves (shape + logical axes + init). From that single source of truth we
derive:
  * initialized arrays            (``init_params``)
  * PartitionSpecs for the mesh   (``distributed.sharding.specs_for``)
  * stacked per-layer variants    (``stack_defs``) for ``lax.scan`` stacks
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names (len == len(shape))
    init: str = "normal"              # normal | zeros | ones
    scale: float = 1.0                # stddev for "normal"
    dtype: Optional[str] = None       # override canonical param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def dense(d_in: int, d_out: int, axes: Tuple[Optional[str], ...],
          scale: Optional[float] = None) -> ParamDef:
    """Dense matrix with fan-in init."""
    return ParamDef((d_in, d_out), axes, "normal",
                    scale if scale is not None else d_in ** -0.5)


def stack_defs(defs: PyTree, n: int, axis: Optional[str] = None) -> PyTree:
    """Prepend a leading layer-stack dim of size ``n`` to every leaf."""
    def f(d: ParamDef) -> ParamDef:
        return replace(d, shape=(n,) + d.shape, axes=(axis,) + d.axes)
    return jax.tree.map(f, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def init_params(defs: PyTree, key: jax.Array, param_dtype: str = "float32") -> PyTree:
    """Initialize arrays from a def tree (path-stable RNG per leaf)."""
    def init_leaf(path, d: ParamDef):
        dtype = d.dtype or param_dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return (jnp.ones(d.shape, jnp.float32) * d.scale).astype(dtype)
        leaf_key = jax.random.fold_in(key, zlib.crc32(_path_str(path).encode()))
        return (jax.random.normal(leaf_key, d.shape, jnp.float32) * d.scale).astype(dtype)
    return jax.tree_util.tree_map_with_path(
        init_leaf, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def abstract_params(defs: PyTree, param_dtype: str = "float32") -> PyTree:
    """ShapeDtypeStructs for the def tree (no allocation — dry-run path)."""
    def f(d: ParamDef) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or param_dtype))
    return jax.tree.map(f, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def count_params(defs: PyTree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    total = 0
    for d in leaves:
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total
