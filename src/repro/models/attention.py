"""Attention: GQA (glm4/qwen3/stablelm/jamba/olmoe/qwen2-moe/whisper/vlm)
and MLA (minicpm3, DeepSeek-V2-style latent KV with absorbed decode).

Cache layout (per scanned layer-stack slot):
  GQA : {"k": [B, S_max, H_kv, hd], "v": [...]}        axes (cache_batch, cache_seq, cache_heads, None)
  MLA : {"ckv": [B, S_max, r], "kpe": [B, S_max, dr]}  axes (cache_batch, cache_seq, None)
The fill position ``pos`` (scalar int32) is carried outside the layer stack.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models.params import ParamDef, dense
from repro.models.layers import apply_rotary, rms_norm

Params = Dict[str, Any]
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Defs
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, cross: bool = False) -> Params:
    if cfg.attention_type == "mla" and not cross:
        return _mla_defs(cfg)
    return _gqa_defs(cfg, cross=cross)


def _gqa_defs(cfg: ModelConfig, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    out: Params = {
        "wq": dense(d, nq * hd, ("embed", "heads")),
        "wk": dense(d, nkv * hd, ("embed", "kv_heads")),
        "wv": dense(d, nkv * hd, ("embed", "kv_heads")),
        "wo": dense(nq * hd, d, ("heads", "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamDef((nq * hd,), ("heads",), "zeros")
        out["bk"] = ParamDef((nkv * hd,), ("kv_heads",), "zeros")
        out["bv"] = ParamDef((nkv * hd,), ("kv_heads",), "zeros")
    if cfg.qk_norm:
        out["q_norm"] = ParamDef((hd,), (None,), "ones")
        out["k_norm"] = ParamDef((hd,), (None,), "ones")
    return out


def _mla_defs(cfg: ModelConfig) -> Params:
    m, d, nq = cfg.mla, cfg.d_model, cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense(d, m.q_lora_rank, ("embed", "lora")),
        "q_norm": ParamDef((m.q_lora_rank,), (None,), "ones"),
        "wq_b": dense(m.q_lora_rank, nq * qd, ("lora", "heads")),
        "wkv_a": dense(d, m.kv_lora_rank + m.qk_rope_head_dim, ("embed", None)),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), "ones"),
        "wkv_b": dense(m.kv_lora_rank,
                       nq * (m.qk_nope_head_dim + m.v_head_dim), ("lora", "heads")),
        "wo": dense(nq * m.v_head_dim, d, ("heads", "embed")),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               stack_dims: Tuple[int, ...] = ()) -> Params:
    """Abstract per-layer-slot cache entry (use jnp.zeros / SDS externally)."""
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    if cfg.attention_type == "mla":
        m = cfg.mla
        return {"ckv": stack_dims + (batch, max_len, m.kv_lora_rank),
                "kpe": stack_dims + (batch, max_len, m.qk_rope_head_dim)}
    out = {"k": stack_dims + (batch, max_len, nkv, hd),
           "v": stack_dims + (batch, max_len, nkv, hd)}
    if cfg.kv_cache_dtype == "int8":
        out["k_scale"] = stack_dims + (batch, max_len, nkv)
        out["v_scale"] = stack_dims + (batch, max_len, nkv)
    return out


def cache_axes(cfg: ModelConfig, stacked: bool = True) -> Params:
    pre = ("layers",) if stacked else ()
    if cfg.attention_type == "mla":
        return {"ckv": pre + ("cache_batch", "cache_seq", None),
                "kpe": pre + ("cache_batch", "cache_seq", None)}
    ax = pre + ("cache_batch", "cache_seq", "cache_heads", None)
    out = {"k": ax, "v": ax}
    if cfg.kv_cache_dtype == "int8":
        sax = pre + ("cache_batch", "cache_seq", "cache_heads")
        out["k_scale"] = sax
        out["v_scale"] = sax
    return out


def _quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric int8 over the head_dim axis."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dt) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Core attention math (XLA path; pallas kernels dispatched from here)
# ---------------------------------------------------------------------------

def _sdpa(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
          mask: jax.Array, ctx: ShardCtx, scale: float) -> jax.Array:
    """q [B,Sq,Hq,hd], k/v [B,Skv,Hkv,hd], mask [B or 1, Sq, Skv] bool."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    dv = v.shape[-1]  # may differ from hd (MLA)
    return out.reshape(B, Sq, Hq * dv)


def _maybe_pallas_attention(cfg: ModelConfig, q, k, v, mode: str,
                            pos: Optional[jax.Array]) -> Optional[jax.Array]:
    if cfg.attention_impl == "xla":
        return None
    interpret = cfg.attention_impl == "pallas_interpret"
    from repro.kernels import ops as kops
    B, Sq, Hq, hd = q.shape
    if mode in ("train", "prefill") and Sq > 1:
        y = kops.flash_attention(q, k, v, True, interpret)
        return y.reshape(B, Sq, Hq * hd)
    if mode == "decode":
        y = kops.decode_attention(q, k, v, kv_len=pos + 1, interpret=interpret)
        return y.reshape(B, Sq, Hq * hd)
    return None


# ---------------------------------------------------------------------------
# GQA apply
# ---------------------------------------------------------------------------

def gqa_apply(cfg: ModelConfig, p: Params, x: jax.Array, *,
              rope: Optional[Tuple[jax.Array, jax.Array]],
              mode: str, ctx: ShardCtx = NULL_CTX,
              cache: Optional[Params] = None, pos: Optional[jax.Array] = None,
              kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
              causal: bool = True,
              ) -> Tuple[jax.Array, Optional[Params]]:
    """mode in {train, prefill, decode}; cross-attention via kv_override
    (pre-projected encoder k/v, no cache update)."""
    dt = x.dtype
    B, S, _ = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads

    q = x @ p["wq"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    q = q.reshape(B, S, nq, hd)

    if kv_override is None:
        k = x @ p["wk"].astype(dt)
        v = x @ p["wv"].astype(dt)
        if "bk" in p:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        k = k.reshape(B, S, nkv, hd)
        v = v.reshape(B, S, nkv, hd)
    else:
        k, v = kv_override

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if kv_override is None:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if rope is not None and kv_override is None:
        cos, sin = rope
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)

    scale = hd ** -0.5
    new_cache = None

    if mode == "decode" and kv_override is None:
        # insert new k/v at pos, attend over cache[0..pos]
        if cfg.kv_cache_dtype == "int8":
            # §Perf (decode): int8 cache halves the dominant HBM stream;
            # dequant fuses after the (int8) loads on TPU.
            qk, sk = _quantize_kv(k)
            qv, sv = _quantize_kv(v)
            ck = jax.lax.dynamic_update_slice(cache["k"], qk, (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], qv, (0, pos, 0, 0))
            csk = jax.lax.dynamic_update_slice(
                cache["k_scale"], sk.astype(cache["k_scale"].dtype), (0, pos, 0))
            csv = jax.lax.dynamic_update_slice(
                cache["v_scale"], sv.astype(cache["v_scale"].dtype), (0, pos, 0))
            new_cache = {"k": ck, "v": cv, "k_scale": csk, "v_scale": csv}
            ck_ = _dequantize_kv(ck, csk, dt)
            cv_ = _dequantize_kv(cv, csv, dt)
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, pos, 0, 0))
            new_cache = {"k": ck, "v": cv}
            ck_, cv_ = ck.astype(dt), cv.astype(dt)
        y = _maybe_pallas_attention(cfg, q, ck_, cv_, "decode", pos)
        if y is None:
            S_max = ck.shape[1]
            valid = (jnp.arange(S_max) <= pos)[None, None, :]  # [1,1,S_max]
            y = _sdpa(cfg, q, ck_, cv_, valid, ctx, scale)
    else:
        if mode == "prefill" and kv_override is None:
            if cfg.kv_cache_dtype == "int8":
                qk, sk = _quantize_kv(k)
                qv, sv = _quantize_kv(v)
                new_cache = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
            else:
                new_cache = {"k": k, "v": v}
        if kv_override is not None:  # cross-attention: full visibility
            mask = jnp.ones((1, S, k.shape[1]), bool)
            y = _sdpa(cfg, q, k.astype(dt), v.astype(dt), mask, ctx, scale)
        else:
            y = _maybe_pallas_attention(cfg, q, k, v, mode, pos) if causal else None
            if y is None:
                mask = (jnp.tril(jnp.ones((S, S), bool)) if causal
                        else jnp.ones((S, S), bool))[None]
                y = _sdpa(cfg, q, k, v, mask, ctx, scale)

    y = ctx.constrain(y, ("batch", "seq", "act_heads"))
    return y @ p["wo"].astype(dt), new_cache


# ---------------------------------------------------------------------------
# MLA apply
# ---------------------------------------------------------------------------

def mla_apply(cfg: ModelConfig, p: Params, x: jax.Array, *,
              rope: Optional[Tuple[jax.Array, jax.Array]],
              mode: str, ctx: ShardCtx = NULL_CTX,
              cache: Optional[Params] = None, pos: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Params]]:
    m = cfg.mla
    dt = x.dtype
    B, S, _ = x.shape
    nq = cfg.num_heads
    nope, rdim, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    cos, sin = rope

    ql = rms_norm(x @ p["wq_a"].astype(dt), p["q_norm"], cfg.norm_eps)
    q = (ql @ p["wq_b"].astype(dt)).reshape(B, S, nq, nope + rdim)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rotary(q_pe, cos, sin)

    kv_a = x @ p["wkv_a"].astype(dt)
    ckv = rms_norm(kv_a[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rotary(kv_a[..., m.kv_lora_rank:][:, :, None, :], cos, sin)[:, :, 0, :]

    scale = (nope + rdim) ** -0.5
    wkv_b = p["wkv_b"].astype(dt).reshape(m.kv_lora_rank, nq, nope + vd)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]

    new_cache = None
    if mode == "decode":
        cckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0))
        ckpe = jax.lax.dynamic_update_slice(cache["kpe"], k_pe.astype(cache["kpe"].dtype), (0, pos, 0))
        new_cache = {"ckv": cckv, "kpe": ckpe}
        # absorbed decode: scores in latent space (r + rdim per head)
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)           # [B,1,H,r]
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat, cckv.astype(dt))
                  + jnp.einsum("bqhp,bsp->bhqs", q_pe, ckpe.astype(dt))
                  ).astype(jnp.float32) * scale
        S_max = cckv.shape[1]
        valid = (jnp.arange(S_max) <= pos)[None, None, None, :]
        scores = jnp.where(valid, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(dt)
        o_lat = jnp.einsum("bhqs,bsr->bqhr", w, cckv.astype(dt))     # [B,1,H,r]
        y = jnp.einsum("bqhr,rhv->bqhv", o_lat, w_uv).reshape(B, S, nq * vd)
    else:
        kv = jnp.einsum("bsr,rhn->bshn", ckv, jnp.concatenate([w_uk, w_uv], -1))
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, nq, rdim))], -1)
        qf = jnp.concatenate([q_nope, q_pe], -1)
        causal = jnp.tril(jnp.ones((S, S), bool))[None]
        y = _sdpa(cfg, qf, k, v, causal, ctx, scale)  # -> [B, S, nq*vd]
        if mode == "prefill":
            new_cache = {"ckv": ckv, "kpe": k_pe}

    y = ctx.constrain(y, ("batch", "seq", "act_heads"))
    return y @ p["wo"].astype(dt), new_cache
