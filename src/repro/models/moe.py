"""Mixture-of-Experts with sort-based capacity dispatch.

Design notes (roofline-driven): the classic GShard one-hot dispatch einsum
[T,D]x[T,E,C] costs k*cf*T^2*D FLOPs — quadratic in tokens, catastrophic at
T=1M (train_4k). We instead sort token-expert assignments by expert id and
gather into a fixed [E, C, D] buffer: dispatch is pure data movement (gather/
scatter, O(T*k*D) bytes, zero matmul FLOPs) and expert compute is a batched
einsum costing exactly k*cf x the active FLOPs — so compiled HLO FLOPs track
6*N_active*D. Expert weights shard over the 'model' axis (EP); token->slot
assembly happens per-DP-shard (the LM wraps this under one GSPMD program, and
for very large T the caller lowers it inside shard_map over the DP axes).

For tiny token counts (decode steps) the sort overhead is irrelevant and the
same path is used.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models.params import ParamDef, dense

Params = Dict[str, Any]


def moe_defs(cfg: ModelConfig) -> Params:
    m = cfg.moe
    d = cfg.d_model
    f = m.d_expert or cfg.d_ff
    e = m.num_experts
    out: Params = {
        "router": dense(d, e, ("embed", None), scale=d ** -0.5),
        "w_gate": ParamDef((e, d, f), ("expert", "embed", "ff"), "normal", d ** -0.5),
        "w_up": ParamDef((e, d, f), ("expert", "embed", "ff"), "normal", d ** -0.5),
        "w_down": ParamDef((e, f, d), ("expert", "ff", "embed"), "normal", f ** -0.5),
    }
    if m.num_shared:
        fs = f * m.num_shared
        out["shared"] = {
            "wi_gate": dense(d, fs, ("embed", "ff")),
            "wi_up": dense(d, fs, ("embed", "ff")),
            "wo": dense(fs, d, ("ff", "embed")),
        }
    return out


def _capacity(cfg: ModelConfig, T: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * m.top_k * T / m.num_experts)
    return max(8, -(-c // 8) * 8)  # >=8, round up to multiple of 8


def _dispatch_group(cfg: ModelConfig, p: Params, xt: jax.Array, C: int):
    """Sort-based dispatch/combine for ONE token group [T, D] (shard-local:
    the caller vmaps this over DP groups so every sort/gather/scatter stays
    on-device — §Perf fix: the global-token version made GSPMD materialize
    partial [E*C, D] buffers and all-reduce them, 100x collective blowup)."""
    m = cfg.moe
    T, D = xt.shape
    k, E = m.top_k, m.num_experts
    dt = xt.dtype

    # ---- routing (fp32) ----
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)      # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                          # [T, k]
    top_w = (top_p / jnp.sum(top_p, -1, keepdims=True)).astype(dt)

    # ---- sort assignments by expert ----
    flat_e = top_i.reshape(-1)                                      # [T*k]
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)               # tokens/expert
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
    keep = pos_in_e < C
    slot = se * C + jnp.clip(pos_in_e, 0, C - 1)                    # [T*k]

    # ---- dispatch: gather tokens into [E, C, D] ----
    x_sorted = jnp.where(keep[:, None], xt[st], 0)
    buf = jnp.zeros((E * C, D), dt).at[slot].add(x_sorted)          # dropped -> +0
    xe = buf.reshape(E, C, D)

    # ---- expert FFN (batched einsum; k*cf x active FLOPs) ----
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt)).reshape(E * C, D)

    # ---- combine: gather back, weight, scatter-add over tokens ----
    out_sorted = ye[slot] * jnp.where(keep, sw, 0)[:, None]
    out = jnp.zeros((T, D), dt).at[st].add(out_sorted)
    return out, (counts, probs, logits, keep)


def moe_apply(cfg: ModelConfig, p: Params, x: jax.Array,
              ctx: ShardCtx = NULL_CTX) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, D] -> (out [B, S, D], aux metrics incl. load-balance loss).

    Tokens are regrouped [B,S,D] -> [dp, T/dp, D] along the DP shard
    boundary and the dispatch is vmapped per group: sort/gather/scatter are
    shard-local, expert weights stay EP-sharded over 'model' through the
    batched einsums. Per-group capacity keeps drop semantics local."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    k, E = m.top_k, m.num_experts
    dt = x.dtype

    dp = ctx.axis_size("batch")
    if B % dp != 0:
        dp = 1
    Tl = T // dp
    xg = x.reshape(dp, Tl, D)
    xg = ctx.constrain(xg, ("dp_groups", None, None))
    C = _capacity(cfg, Tl)

    out_g, (counts, probs, logits, keep) = jax.vmap(
        lambda xt: _dispatch_group(cfg, p, xt, C))(xg)
    out_g = ctx.constrain(out_g, ("dp_groups", None, None))
    out = out_g.reshape(T, D)
    xt = x.reshape(T, D)

    if m.num_shared:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["wi_gate"].astype(dt)) * (xt @ sp["wi_up"].astype(dt))
        out = out + hs @ sp["wo"].astype(dt)

    # ---- aux losses (Switch-style load balance + router z-loss) ----
    frac = jnp.sum(counts, 0).astype(jnp.float32) / (T * k)  # dispatch fraction
    mean_p = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(frac * mean_p)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {
        "moe_aux_loss": m.aux_loss_coef * lb_loss + m.router_z_coef * z_loss,
        "moe_lb": lb_loss,
        "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.reshape(B, S, D), aux
