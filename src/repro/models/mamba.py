"""Mamba-1 selective SSM block (Jamba's mixer).

TPU adaptation: the CUDA "hardware-aware" fused scan becomes a *chunked*
linear-recurrence — ``lax.scan`` over sequence chunks carrying the SSM state,
with a parallel ``associative_scan`` inside each chunk. Only one chunk's
[B, chunk, d_inner, d_state] tensor is live at a time (VMEM/HBM friendly),
and compile time is O(1) in sequence length.

Decode is the exact single-step recurrence with a (conv, ssm) state cache —
the cheapest CSP payload in the framework (O(1) in context length).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models.params import ParamDef, dense

Params = Dict[str, Any]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return d_in, mc.d_state, mc.d_conv, dt_rank


def mamba_defs(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_in, d_state, d_conv, dt_rank = _dims(cfg)
    return {
        "in_proj": dense(d, 2 * d_in, ("embed", "mamba_inner")),
        "conv_w": ParamDef((d_conv, d_in), (None, "mamba_inner"), "normal", d_conv ** -0.5),
        "conv_b": ParamDef((d_in,), ("mamba_inner",), "zeros"),
        "x_proj": dense(d_in, dt_rank + 2 * d_state, ("mamba_inner", None)),
        "dt_proj": dense(dt_rank, d_in, (None, "mamba_inner")),
        "dt_bias": ParamDef((d_in,), ("mamba_inner",), "zeros"),
        "A_log": ParamDef((d_in, d_state), ("mamba_inner", None), "ones"),
        "D": ParamDef((d_in,), ("mamba_inner",), "ones"),
        "out_proj": dense(d_in, d, ("mamba_inner", "embed")),
    }


def mamba_cache_shapes(cfg: ModelConfig, batch: int) -> Dict[str, Tuple[int, ...]]:
    d_in, d_state, d_conv, _ = _dims(cfg)
    return {"conv": (batch, d_conv - 1, d_in), "ssm": (batch, d_in, d_state)}


def _ssm_params(cfg: ModelConfig, p: Params, xc: jax.Array):
    """xc [B, S, d_in] (post-conv, post-silu) -> (A_bar, Bx) for the recurrence."""
    d_in, d_state, _, dt_rank = _dims(cfg)
    dt = xc.dtype
    proj = xc @ p["x_proj"].astype(dt)                      # [B,S,r+2n]
    delta_r = proj[..., :dt_rank]
    B_ssm = proj[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    C_ssm = proj[..., dt_rank + d_state:].astype(jnp.float32)
    delta = jax.nn.softplus((delta_r @ p["dt_proj"].astype(dt)).astype(jnp.float32)
                            + p["dt_bias"].astype(jnp.float32))  # [B,S,d_in]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # [d_in, n]
    A_bar = jnp.exp(delta[..., None] * A)                   # [B,S,d_in,n]
    Bx = (delta * xc.astype(jnp.float32))[..., None] * B_ssm[:, :, None, :]
    return A_bar, Bx, C_ssm


def _chunk_scan(A_bar, Bx, h0):
    """Linear recurrence h_t = A_t h_{t-1} + b_t within one chunk.

    A_bar/Bx: [B, L, d_in, n]; h0: [B, d_in, n] (fp32). Returns (h_all, h_last)."""
    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2
    a_cum, b_cum = jax.lax.associative_scan(op, (A_bar, Bx), axis=1)
    h_all = b_cum + a_cum * h0[:, None]                     # [B,L,d_in,n]
    return h_all, h_all[:, -1]


def _causal_conv(cfg, p, x, conv_state=None):
    """Depthwise causal conv over seq. x [B,S,d_in]; state [B, d_conv-1, d_in]."""
    d_in, _, d_conv, _ = _dims(cfg)
    dt = x.dtype
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], d_conv - 1, d_in), dt)
    else:
        pad = conv_state.astype(dt)
    xp = jnp.concatenate([pad, x], axis=1)                  # [B, S+dc-1, d_in]
    w = p["conv_w"].astype(dt)                              # [d_conv, d_in]
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(d_conv))
    new_state = xp[:, -(d_conv - 1):, :] if d_conv > 1 else pad
    return jax.nn.silu(out + p["conv_b"].astype(dt)), new_state


def mamba_apply(cfg: ModelConfig, p: Params, x: jax.Array, *,
                mode: str, ctx: ShardCtx = NULL_CTX,
                cache: Optional[Params] = None,
                ) -> Tuple[jax.Array, Optional[Params]]:
    """x [B,S,D]. mode train/prefill: chunked scan (prefill returns final
    state cache); mode decode: S==1 exact recurrence against the cache."""
    mc = cfg.mamba
    d_in, d_state, d_conv, _ = _dims(cfg)
    dt = x.dtype
    B, S, _ = x.shape

    xz = x @ p["in_proj"].astype(dt)
    xin, z = xz[..., :d_in], xz[..., d_in:]
    xin = ctx.constrain(xin, ("batch", "seq", "act_heads"))

    if mode == "decode":
        xc, new_conv = _causal_conv(cfg, p, xin, cache["conv"])
        A_bar, Bx, C_ssm = _ssm_params(cfg, p, xc)
        h = A_bar[:, 0] * cache["ssm"].astype(jnp.float32) + Bx[:, 0]  # [B,d_in,n]
        y = jnp.einsum("bdn,bn->bd", h, C_ssm[:, 0])[:, None, :]       # [B,1,d_in]
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": h}
    else:
        xc, last_conv = _causal_conv(cfg, p, xin)
        L = min(mc.chunk, S)
        while S % L:          # largest divisor <= chunk (exact state carry)
            L -= 1
        nchunk = S // L

        def rs(t):  # [B,S,...] -> [nchunk, B, L, ...]
            return jnp.moveaxis(t.reshape(B, nchunk, L, *t.shape[2:]), 1, 0)

        h0 = jnp.zeros((B, d_in, d_state), jnp.float32)
        if mc.perchunk_params:
            def step(h, xc_chunk):
                # §Perf: SSM params (A_bar/Bx, fp32, [B,L,d_in,n]) computed
                # PER CHUNK — materializing them for the full sequence was
                # the memory-term dominator (2 x 34 GiB/device at train_4k).
                a, b, c = _ssm_params(cfg, p, xc_chunk)
                h_all, h_last = _chunk_scan(a, b, h)
                yc = jnp.einsum("bldn,bln->bld", h_all, c)
                return h_last, yc.astype(xc_chunk.dtype)
            xs = rs(xc)
        else:
            def step(h, inp):  # paper-naive: full-sequence A_bar/Bx inputs
                a, b, c = inp
                h_all, h_last = _chunk_scan(a, b, h)
                yc = jnp.einsum("bldn,bln->bld", h_all, c)
                return h_last, yc.astype(xc.dtype)
            A_bar, Bx, C_full = _ssm_params(cfg, p, xc)
            xs = (rs(A_bar), rs(Bx), rs(C_full))
        h_last, ys = jax.lax.scan(step, h0, xs,
                                  unroll=True if cfg.unroll_scans else 1)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d_in)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": last_conv, "ssm": h_last}

    y = (y.astype(dt) + xc * p["D"].astype(dt)) * jax.nn.silu(z)
    y = ctx.constrain(y, ("batch", "seq", "act_heads"))
    return y @ p["out_proj"].astype(dt), new_cache
