"""Decoder LM assembly: superblock ``lax.scan`` over heterogeneous stacks.

Covers families dense / moe / hybrid / ssm / vlm (whisper enc-dec lives in
``whisper.py``; ``api.py`` dispatches). The layer stack is
``num_periods = num_layers / len(block_pattern)`` scan iterations; each
iteration applies one period of (mixer, mlp) blocks, so Jamba's 1:7
attn:mamba interleave and xLSTM's m/s pattern compile as a single scan.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.params import ParamDef, init_params, stack_defs

Params = Dict[str, Any]

MIXER_HAS_ROPE = {"attn"}


# ---------------------------------------------------------------------------
# Defs
# ---------------------------------------------------------------------------

def _mixer_defs(cfg: ModelConfig, mixer: str) -> Params:
    if mixer == "attn":
        return attn.attn_defs(cfg)
    if mixer == "mamba":
        return mb.mamba_defs(cfg)
    if mixer == "mlstm":
        return xl.mlstm_defs(cfg)
    if mixer == "slstm":
        return xl.slstm_defs(cfg)
    raise ValueError(mixer)


def _mlp_defs(cfg: ModelConfig, mlp: str) -> Optional[Params]:
    if mlp == "mlp":
        return L.mlp_defs(cfg)
    if mlp == "moe":
        return moe_mod.moe_defs(cfg)
    if mlp == "glu":
        d_ff = int(cfg.xlstm.slstm_ffn_factor * cfg.d_model) if cfg.xlstm else cfg.d_ff
        return L.mlp_defs(cfg, d_ff)
    if mlp == "none":
        return None
    raise ValueError(mlp)


def block_defs(cfg: ModelConfig, mixer: str, mlp: str) -> Params:
    out: Params = {"mixer_norm": L.norm_defs(cfg), "mixer": _mixer_defs(cfg, mixer)}
    m = _mlp_defs(cfg, mlp)
    if m is not None:
        out["mlp_norm"] = L.norm_defs(cfg)
        out["mlp"] = m
    return out


def lm_defs(cfg: ModelConfig) -> Params:
    blocks = {}
    for i, (mixer, mlp) in enumerate(cfg.block_pattern):
        blocks[f"pos{i}"] = stack_defs(block_defs(cfg, mixer, mlp),
                                       cfg.num_periods, "layers")
    return {"embed": L.embed_defs(cfg), "blocks": blocks,
            "final_norm": L.norm_defs(cfg)}


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    return init_params(lm_defs(cfg), key, cfg.param_dtype)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _pos_cache_shapes(cfg: ModelConfig, mixer: str, batch: int, max_len: int) -> Optional[Dict]:
    if mixer == "attn":
        return attn.init_cache(cfg, batch, max_len)
    if mixer == "mamba":
        return mb.mamba_cache_shapes(cfg, batch)
    if mixer == "mlstm":
        return xl.mlstm_cache_shapes(cfg, batch)
    if mixer == "slstm":
        return xl.slstm_cache_shapes(cfg, batch)
    raise ValueError(mixer)


def _cache_dtype(cfg: ModelConfig, mixer: str, name: str) -> jnp.dtype:
    if mixer == "attn" and name in ("k", "v"):
        return jnp.dtype(jnp.int8 if cfg.kv_cache_dtype == "int8" else cfg.dtype)
    if mixer == "attn" and name in ("ckv", "kpe"):
        return jnp.dtype(cfg.dtype)
    if mixer == "mamba" and name == "conv":
        return jnp.dtype(cfg.dtype)
    return jnp.dtype(jnp.float32)


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """(shapes, dtypes, logical_axes) trees for the stacked cache."""
    shapes: Params = {}
    dtypes: Params = {}
    axes: Params = {}
    np_ = cfg.num_periods
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        sh = _pos_cache_shapes(cfg, mixer, batch, max_len)
        shapes[f"pos{i}"] = {k: (np_,) + tuple(v) for k, v in sh.items()}
        dtypes[f"pos{i}"] = {k: _cache_dtype(cfg, mixer, k) for k in sh}
        if mixer == "attn":
            ax = attn.cache_axes(cfg, stacked=True)
        else:
            ax = {k: ("layers", "cache_batch") + (None,) * (len(v) - 1)
                  for k, v in sh.items()}
        axes[f"pos{i}"] = ax
    return shapes, dtypes, axes


def make_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    shapes, dtypes, _ = cache_spec(cfg, batch, max_len)
    return jax.tree.map(lambda s, d: jnp.zeros(s, d), shapes, dtypes,
                        is_leaf=lambda x: isinstance(x, tuple))


def cache_sds(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    shapes, dtypes, _ = cache_spec(cfg, batch, max_len)
    return jax.tree.map(lambda s, d: jax.ShapeDtypeStruct(s, d), shapes, dtypes,
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _rope_for(cfg: ModelConfig, positions: jax.Array,
              mrope_positions: Optional[jax.Array]):
    if cfg.attention_type == "mla":
        rot = cfg.mla.qk_rope_head_dim
    else:
        rot = int(cfg.partial_rotary * cfg.resolved_head_dim)
        rot -= rot % 2
    if rot == 0:
        return None  # e.g. Jamba: attention layers carry no positional encoding
    if cfg.vision is not None and mrope_positions is not None:
        return L.mrope_tables(mrope_positions, cfg.vision.mrope_sections, rot, cfg.rope_theta)
    return L.rope_tables(positions, rot, cfg.rope_theta)


def _apply_block(cfg: ModelConfig, p: Params, x: jax.Array, mixer: str, mlp: str,
                 *, rope, mode: str, ctx: ShardCtx, cache, pos):
    h = L.apply_norm(cfg, p["mixer_norm"], x)
    if mixer == "attn":
        fn = attn.mla_apply if cfg.attention_type == "mla" else attn.gqa_apply
        y, new_cache = fn(cfg, p["mixer"], h, rope=rope, mode=mode, ctx=ctx,
                          cache=cache, pos=pos)
    elif mixer == "mamba":
        y, new_cache = mb.mamba_apply(cfg, p["mixer"], h, mode=mode, ctx=ctx, cache=cache)
    elif mixer == "mlstm":
        y, new_cache = xl.mlstm_apply(cfg, p["mixer"], h, mode=mode, ctx=ctx, cache=cache)
    elif mixer == "slstm":
        y, new_cache = xl.slstm_apply(cfg, p["mixer"], h, mode=mode, ctx=ctx, cache=cache)
    else:
        raise ValueError(mixer)
    x = x + y
    aux = {}
    if mlp != "none":
        h = L.apply_norm(cfg, p["mlp_norm"], x)
        if mlp == "moe":
            y, aux = moe_mod.moe_apply(cfg, p["mlp"], h, ctx)
        else:
            y = L.apply_mlp(cfg, p["mlp"], h, ctx)
        x = x + y
    x = ctx.constrain(x, ("batch", "seq", None))
    return x, new_cache, aux


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            mode: str = "train", ctx: ShardCtx = NULL_CTX,
            cache: Optional[Params] = None, pos: Optional[jax.Array] = None,
            vision_embeds: Optional[jax.Array] = None,
            mrope_positions: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, Optional[Params], Dict[str, jax.Array]]:
    """tokens [B, S] -> (hidden [B,S,D], new_cache, aux). ``pos`` is the cache
    fill index for decode (scalar int32)."""
    B, S = tokens.shape
    x = L.embed_tokens(cfg, params["embed"], tokens, ctx)
    if cfg.vision is not None and vision_embeds is not None:
        n_img = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, n_img:]], axis=1)

    if mode == "decode":
        positions = jnp.full((B, S), pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if mrope_positions is None and cfg.vision is not None:
        mrope_positions = jnp.broadcast_to(positions[:, None, :], (B, 3, S))
    rope = _rope_for(cfg, positions, mrope_positions)

    has_cache = cache is not None
    want_cache = mode in ("prefill", "decode")

    def period_body(x, per_layer):
        p_by_pos, c_by_pos = per_layer
        new_caches = {}
        aux_sum = None
        for i, (mixer, mlp) in enumerate(cfg.block_pattern):
            c_i = c_by_pos[f"pos{i}"] if has_cache else None
            x, nc, aux = _apply_block(cfg, p_by_pos[f"pos{i}"], x, mixer, mlp,
                                      rope=rope, mode=mode, ctx=ctx, cache=c_i, pos=pos)
            if want_cache:
                new_caches[f"pos{i}"] = nc
            if aux:
                aux_sum = aux if aux_sum is None else jax.tree.map(jnp.add, aux_sum, aux)
        return x, (new_caches, aux_sum if aux_sum is not None else {})

    xs_cache = cache if has_cache else jax.tree.map(lambda _: None, params["blocks"])
    if cfg.scan_layers:
        body = period_body
        if mode == "train" and cfg.remat != "none":
            policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
                      else jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
            body = jax.checkpoint(period_body, policy=policy)
        x, (new_cache, auxs) = jax.lax.scan(body, x, (params["blocks"], xs_cache))
    else:
        body = period_body
        if mode == "train" and cfg.remat != "none":
            policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
                      else jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
            body = jax.checkpoint(period_body, policy=policy)
        new_cache, auxs = {}, []
        for li in range(cfg.num_periods):
            sl = jax.tree.map(lambda a: a[li], params["blocks"])
            cl = jax.tree.map(lambda a: a[li], cache) if has_cache else None
            x, (nc, aux) = body(x, (sl, cl))
            if want_cache:
                new_cache[li] = nc
            auxs.append(aux)
        if want_cache:
            new_cache = jax.tree.map(lambda *xs_: jnp.stack(xs_), *new_cache.values())
        auxs = jax.tree.map(lambda *xs_: jnp.stack(xs_), *auxs) if auxs and auxs[0] else {}

    x = L.apply_norm(cfg, params["final_norm"], x)
    aux_out = {k: jnp.sum(v) for k, v in auxs.items()} if auxs else {}
    return x, (new_cache if want_cache else None), aux_out


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------

def chunked_xent(cfg: ModelConfig, params: Params, h: jax.Array,
                 labels: jax.Array, ctx: ShardCtx = NULL_CTX) -> jax.Array:
    """Cross-entropy without materializing [B,S,V] logits for the full seq:
    scan over sequence chunks, remat'd so backward recomputes per-chunk."""
    W = L.unembed_matrix(cfg, params["embed"])
    B, S, D = h.shape
    Lc = cfg.loss_chunk if S % max(cfg.loss_chunk, 1) == 0 and cfg.loss_chunk > 0 else S
    n = S // Lc

    def chunk_nll(hc, lc):
        # All dots in the model dtype (bf16): the f32 casts sit AFTER the
        # matmuls so the backward cotangent entering the residual stream is
        # bf16 — an f32 gold-logit dot here made the ENTIRE backward pass
        # run in f32 (2x collective + memory traffic; §Perf global fix).
        logits = (hc @ W.astype(hc.dtype))
        logits = ctx.constrain(logits, ("batch", "seq", "vocab"))
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        # label logit via embedding-row gather (avoids take_along_axis over the
        # vocab-sharded [B,L,V] tensor — GSPMD handles the row gather cheaply)
        w_label = jnp.take(W.T, lc, axis=0).astype(hc.dtype)      # [B,L,D]
        gold = jnp.sum(hc * w_label, axis=-1).astype(jnp.float32)
        zreg = 1e-4 * jnp.square(logz)
        return jnp.sum(logz - gold + zreg)

    chunk_nll = jax.checkpoint(chunk_nll, policy=jax.checkpoint_policies.nothing_saveable)

    # Unrolled python loop (not lax.scan): chunk count is small and keeping it
    # out of a `while` op makes compiled cost_analysis FLOPs exact.
    total = jnp.zeros((), jnp.float32)
    for i in range(n):
        total = total + chunk_nll(h[:, i * Lc:(i + 1) * Lc, :],
                                  labels[:, i * Lc:(i + 1) * Lc])
    return total / (B * S)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            ctx: ShardCtx = NULL_CTX) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h, _, aux = forward(cfg, params, batch["tokens"], mode="train", ctx=ctx,
                        vision_embeds=batch.get("vision_embeds"),
                        mrope_positions=batch.get("mrope_positions"))
    loss = chunked_xent(cfg, params, h, batch["labels"], ctx)
    metrics = {"xent": loss}
    if "moe_aux_loss" in aux:
        loss = loss + aux["moe_aux_loss"]
        metrics.update({k: aux[k] for k in ("moe_aux_loss", "moe_lb", "moe_drop_frac")})
    metrics["loss"] = loss
    return loss, metrics


def logits_at_last(cfg: ModelConfig, params: Params, h: jax.Array,
                   ctx: ShardCtx = NULL_CTX) -> jax.Array:
    W = L.unembed_matrix(cfg, params["embed"])
    out = (h[:, -1:, :] @ W.astype(h.dtype)).astype(jnp.float32)
    return ctx.constrain(out, ("batch", "seq", "vocab"))


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            ctx: ShardCtx = NULL_CTX, vision_embeds=None, mrope_positions=None):
    h, cache, _ = forward(cfg, params, tokens, mode="prefill", ctx=ctx,
                          vision_embeds=vision_embeds, mrope_positions=mrope_positions)
    return logits_at_last(cfg, params, h, ctx), cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                token: jax.Array, pos: jax.Array, *, ctx: ShardCtx = NULL_CTX):
    """token [B,1]; pos scalar int32 (index where this token is written)."""
    h, new_cache, _ = forward(cfg, params, token, mode="decode", ctx=ctx, cache=cache, pos=pos)
    return logits_at_last(cfg, params, h, ctx), new_cache
