"""Shared layers: norms, RoPE (incl. partial + M-RoPE), MLPs, embeddings."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models.params import ParamDef, dense

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_defs(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    out = {"scale": ParamDef((d,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        out["bias"] = ParamDef((d,), ("embed",), "zeros")
    return out


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, rot_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables. positions [..., S] -> cos/sin [..., S, rot_dim//2]."""
    half = rot_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def mrope_tables(positions: jax.Array, sections: Tuple[int, ...], rot_dim: int,
                 theta: float) -> Tuple[jax.Array, jax.Array]:
    """M-RoPE (qwen2-vl): positions [B, 3, S]; frequency dims split into
    t/h/w sections; each section indexed by its own position row."""
    half = rot_dim // 2
    assert sum(sections) == half, (sections, half)
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang_all = positions[..., None].astype(jnp.float32) * freq  # [B, 3, S, half]
    parts, start = [], 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[:, i, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)  # [B, S, half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """NeoX half-split rotation over the first ``2*cos.shape[-1]`` dims of x.

    x: [B, S, H, D]; cos/sin: [B, S, half] or [S, half]."""
    rot = 2 * cos.shape[-1]
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :]  # [B, S, 1, half]
    sin = sin[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "silu":  # SwiGLU
        return {"wi_gate": dense(d, f, ("embed", "ff")),
                "wi_up": dense(d, f, ("embed", "ff")),
                "wo": dense(f, d, ("ff", "embed"))}
    return {"wi": dense(d, f, ("embed", "ff")),
            "wo": dense(f, d, ("ff", "embed"))}


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array,
              ctx: ShardCtx = NULL_CTX) -> jax.Array:
    dt = x.dtype
    if cfg.act == "silu":
        h = jax.nn.silu(x @ p["wi_gate"].astype(dt)) * (x @ p["wi_up"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(dt))
    h = ctx.constrain(h, ("batch", "seq", "act_ff"))
    return h @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig) -> Params:
    out = {"embedding": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                                 "normal", cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        out["unembed"] = dense(cfg.d_model, cfg.vocab_size, ("embed", "vocab"))
    return out


def embed_tokens(cfg: ModelConfig, p: Params, tokens: jax.Array,
                 ctx: ShardCtx = NULL_CTX) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return ctx.constrain(x, ("batch", "seq", None))


def unembed_matrix(cfg: ModelConfig, p: Params) -> jax.Array:
    return (p["embedding"].T if cfg.tie_embeddings else p["unembed"])
