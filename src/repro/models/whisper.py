"""Whisper-style encoder-decoder backbone (whisper-medium).

The audio frontend (log-mel + 2x conv1d) is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings [B, n_frames, d_model].
Encoder: non-causal self-attn + GELU MLP, sinusoidal positions.
Decoder: causal self-attn (KV cache) + cross-attn (encoder K/V cached at
prefill) + GELU MLP, learned positions. Embeddings tied.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardCtx, NULL_CTX
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.params import ParamDef, init_params, stack_defs

Params = Dict[str, Any]

MAX_DEC_POS = 32_768  # covers the assigned decode shapes


def _sinusoid(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000 ** (dim / (d // 2 - 1)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_block_defs(cfg: ModelConfig) -> Params:
    return {"attn_norm": L.norm_defs(cfg), "attn": attn.attn_defs(cfg),
            "mlp_norm": L.norm_defs(cfg), "mlp": L.mlp_defs(cfg)}


def _dec_block_defs(cfg: ModelConfig) -> Params:
    return {"self_norm": L.norm_defs(cfg), "self_attn": attn.attn_defs(cfg),
            "cross_norm": L.norm_defs(cfg), "cross_attn": attn.attn_defs(cfg),
            "mlp_norm": L.norm_defs(cfg), "mlp": L.mlp_defs(cfg)}


def whisper_defs(cfg: ModelConfig) -> Params:
    enc = cfg.encoder
    return {
        "embed": L.embed_defs(cfg),
        "dec_pos": ParamDef((MAX_DEC_POS, cfg.d_model), (None, "embed"),
                            "normal", 0.01),
        "enc_blocks": stack_defs(_enc_block_defs(cfg), enc.num_layers, "layers"),
        "enc_norm": L.norm_defs(cfg),
        "dec_blocks": stack_defs(_dec_block_defs(cfg), cfg.num_layers, "layers"),
        "final_norm": L.norm_defs(cfg),
    }


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    return init_params(whisper_defs(cfg), key, cfg.param_dtype)


# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: Params, frames: jax.Array,
           ctx: ShardCtx = NULL_CTX) -> jax.Array:
    """frames [B, S_enc, D] (stub embeddings) -> encoder states."""
    B, S, D = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + _sinusoid(S, D).astype(cfg.dtype)[None]
    x = ctx.constrain(x, ("batch", "seq", None))

    def body(x, p):
        h = L.apply_norm(cfg, p["attn_norm"], x)
        y, _ = attn.gqa_apply(cfg, p["attn"], h, rope=None, mode="train",
                              ctx=ctx, causal=False)
        x = x + y
        h = L.apply_norm(cfg, p["mlp_norm"], x)
        x = x + L.apply_mlp(cfg, p["mlp"], h, ctx)
        return ctx.constrain(x, ("batch", "seq", None)), {}

    x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                        unroll=True if not cfg.scan_layers else 1)
    return L.apply_norm(cfg, params["enc_norm"], x)


def _cross_kv(cfg: ModelConfig, p: Params, enc_out: jax.Array):
    dt = enc_out.dtype
    B, S, _ = enc_out.shape
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    k = enc_out @ p["wk"].astype(dt)
    v = enc_out @ p["wv"].astype(dt)
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k.reshape(B, S, nkv, hd), v.reshape(B, S, nkv, hd)


def _decode_stack(cfg: ModelConfig, params: Params, x: jax.Array, *, mode: str,
                  ctx: ShardCtx, enc_out: Optional[jax.Array],
                  self_cache, cross_cache, pos):
    """Runs decoder blocks via scan. cross_cache: {"k","v"} [Ld,B,Se,H,hd] or
    None (computed from enc_out on the fly)."""
    has_self = self_cache is not None
    has_cross = cross_cache is not None

    def body(x, per_layer):
        p, sc, cc = per_layer
        h = L.apply_norm(cfg, p["self_norm"], x)
        y, new_sc = attn.gqa_apply(cfg, p["self_attn"], h, rope=None, mode=mode,
                                   ctx=ctx, cache=sc if has_self else None, pos=pos)
        x = x + y
        h = L.apply_norm(cfg, p["cross_norm"], x)
        if has_cross:
            kv = (cc["k"].astype(x.dtype), cc["v"].astype(x.dtype))
        else:
            kv = _cross_kv(cfg, p["cross_attn"], enc_out)
        y, _ = attn.gqa_apply(cfg, p["cross_attn"], h, rope=None, mode="train",
                              ctx=ctx, kv_override=kv)
        x = x + y
        h = L.apply_norm(cfg, p["mlp_norm"], x)
        x = x + L.apply_mlp(cfg, p["mlp"], h, ctx)
        x = ctx.constrain(x, ("batch", "seq", None))
        ys = {}
        if mode == "prefill":
            ys = {"self": new_sc, "cross": {"k": kv[0], "v": kv[1]}}
        elif mode == "decode":
            ys = {"self": new_sc}
        return x, ys

    sc = self_cache if self_cache is not None else \
        jax.tree.map(lambda _: None, params["dec_blocks"])
    cc = cross_cache if cross_cache is not None else \
        jax.tree.map(lambda _: None, params["dec_blocks"])
    x, ys = jax.lax.scan(body, x, (params["dec_blocks"], sc, cc),
                         unroll=True if not cfg.scan_layers else 1)
    return x, ys


def _dec_embed(cfg, params, tokens, pos, ctx):
    B, S = tokens.shape
    x = L.embed_tokens(cfg, params["embed"], tokens, ctx)
    if pos is None:
        pe = jax.lax.dynamic_slice_in_dim(params["dec_pos"], 0, S, 0)
    else:
        pe = jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, S, 0)
    return x + pe.astype(x.dtype)[None]


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            ctx: ShardCtx = NULL_CTX):
    from repro.models import lm  # chunked_xent
    enc_out = encode(cfg, params, batch["frames"], ctx)
    x = _dec_embed(cfg, params, batch["tokens"], None, ctx)
    x, _ = _decode_stack(cfg, params, x, mode="train", ctx=ctx, enc_out=enc_out,
                         self_cache=None, cross_cache=None, pos=None)
    x = L.apply_norm(cfg, params["final_norm"], x)
    loss = lm.chunked_xent(cfg, params, x, batch["labels"], ctx)
    return loss, {"loss": loss, "xent": loss}


def prefill(cfg: ModelConfig, params: Params, frames: jax.Array,
            tokens: jax.Array, ctx: ShardCtx = NULL_CTX):
    from repro.models import lm
    enc_out = encode(cfg, params, frames, ctx)
    x = _dec_embed(cfg, params, tokens, None, ctx)
    x, cache = _decode_stack(cfg, params, x, mode="prefill", ctx=ctx,
                             enc_out=enc_out, self_cache=None, cross_cache=None,
                             pos=None)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return lm.logits_at_last(cfg, params, x, ctx), cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                token: jax.Array, pos: jax.Array, ctx: ShardCtx = NULL_CTX):
    from repro.models import lm
    x = _dec_embed(cfg, params, token, pos, ctx)
    x, ys = _decode_stack(cfg, params, x, mode="decode", ctx=ctx,
                          enc_out=None, self_cache=cache["self"],
                          cross_cache=cache["cross"], pos=pos)
    x = L.apply_norm(cfg, params["final_norm"], x)
    new_cache = {"self": ys["self"], "cross": cache["cross"]}
    return lm.logits_at_last(cfg, params, x, ctx), new_cache


def cache_sds(cfg: ModelConfig, batch: int, max_len: int):
    """Abstract decode cache (self KV at max_len + cross KV at n_frames)."""
    hd, nkv, Ld = cfg.resolved_head_dim, cfg.num_kv_heads, cfg.num_layers
    Se = cfg.encoder.num_frames
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    return {
        "self": {"k": sds((Ld, batch, max_len, nkv, hd), dt),
                 "v": sds((Ld, batch, max_len, nkv, hd), dt)},
        "cross": {"k": sds((Ld, batch, Se, nkv, hd), dt),
                  "v": sds((Ld, batch, Se, nkv, hd), dt)},
    }


def cache_axes_tree():
    ax = ("layers", "cache_batch", "cache_seq", "cache_heads", None)
    axe = ("layers", "cache_batch", None, "cache_heads", None)
    return {"self": {"k": ax, "v": ax}, "cross": {"k": axe, "v": axe}}
