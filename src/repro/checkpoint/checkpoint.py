"""Sharded checkpointing: save/restore pytrees as npz shards + manifest,
async (background-thread) saves, rotation, and CSP-streamed restore.

Fault-tolerance contract (exercised by launch/train.py --inject-failure):
  * saves are atomic (tmp dir + rename);
  * restore picks the latest complete step;
  * elastic restarts may restore onto a different mesh — values are host
    numpy, resharding happens at device_put against the new topology.
"""
from __future__ import annotations

import io
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "::"


_NPZ_SAVABLE = {"float64", "float32", "float16", "int64", "int32", "int16",
                "int8", "uint8", "uint16", "uint32", "uint64", "bool"}


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        v = np.asarray(leaf)
        if str(v.dtype) not in _NPZ_SAVABLE:   # bf16 etc. -> widen for npz
            v = v.astype(np.float32)
        flat[key] = v
    return flat


def _unflatten_into(like: PyTree, flat: Dict[str, np.ndarray]) -> PyTree:
    paths = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        leaves.append(flat[key].astype(leaf.dtype) if hasattr(leaf, "dtype")
                      else flat[key])
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def serialize(tree: PyTree) -> bytes:
    """Whole-tree bytes (CSP payloads, storage uploads)."""
    buf = io.BytesIO()
    np.savez(buf, **_flatten(tree))
    return buf.getvalue()


def deserialize(data: bytes, like: PyTree) -> PyTree:
    with np.load(io.BytesIO(data)) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten_into(like, flat)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 shard_bytes: int = 512 << 20):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.shard_bytes = shard_bytes
        self._inflight: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: PyTree) -> None:
        flat = _flatten(state)
        tmp = self.dir / f".tmp-{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "shards": [], "time": time.time()}
        shard, size, idx = {}, 0, 0

        def flush():
            nonlocal shard, size, idx
            if not shard:
                return
            name = f"shard-{idx:04d}.npz"
            with open(tmp / name, "wb") as f:
                np.savez(f, **shard)
            manifest["shards"].append({"file": name, "keys": list(shard)})
            shard, size = {}, 0
            idx += 1

        for k, v in flat.items():
            shard[k] = v
            size += v.nbytes
            if size >= self.shard_bytes:
                flush()
        flush()
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self.dir / f"step-{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                   # atomic publish
        self._rotate()

    def save_async(self, step: int, state: PyTree) -> threading.Thread:
        """Snapshot to host (blocking, cheap) then write in the background."""
        host_state = jax.tree.map(np.asarray, state)
        self.wait()
        t = threading.Thread(target=self.save, args=(step, host_state),
                             daemon=True, name=f"ckpt-save-{step}")
        t.start()
        self._inflight = t
        return t

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def _rotate(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step-{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step-*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: PyTree, step: Optional[int] = None
                ) -> Tuple[PyTree, int]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step-{step:08d}"
        flat: Dict[str, np.ndarray] = {}
        manifest = json.loads((d / "manifest.json").read_text())
        for sh in manifest["shards"]:
            with np.load(d / sh["file"]) as z:
                for k in z.files:
                    flat[k] = z[k]
        return _unflatten_into(like, flat), step

    def read_bytes(self, step: Optional[int] = None) -> bytes:
        """Raw checkpoint bytes (for CSP streaming to a restarting worker)."""
        step = step if step is not None else self.latest_step()
        d = self.dir / f"step-{step:08d}"
        buf = io.BytesIO()
        import zipfile
        with zipfile.ZipFile(buf, "w") as zf:
            for p in sorted(d.iterdir()):
                zf.write(p, p.name)
        return buf.getvalue()
