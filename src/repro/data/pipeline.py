"""Data pipeline: deterministic synthetic token shards + a Truffle-SDP-backed
prefetching loader.

The loader is the paper's SDP applied to training: batches live in a storage
service (object store by default); a background data-path thread fetches them
into a host-side Buffer *while the step function compiles* (the training
job's cold start) and keeps a double-buffer ahead of the consumer."""
from __future__ import annotations

import io
import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.buffer import Buffer
from repro.configs.base import ModelConfig


@dataclass
class TokenDataset:
    """Seeded synthetic LM token stream (shift-by-one labels)."""
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def batch(self, i: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed * 100_003 + i)
        toks = rng.integers(0, self.vocab_size,
                            (self.batch_size, self.seq_len + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def serialize(self, i: int) -> bytes:
        buf = io.BytesIO()
        np.savez(buf, **self.batch(i))
        return buf.getvalue()

    @staticmethod
    def deserialize(data: bytes) -> Dict[str, np.ndarray]:
        with np.load(io.BytesIO(data)) as z:
            return {k: z[k] for k in z.files}


class TruffleDataLoader:
    """SDP for batches: storage -> local buffer, prefetch_depth ahead."""

    def __init__(self, dataset: TokenDataset, storage, *,
                 prefetch_depth: int = 2, start_step: int = 0,
                 buffer: Optional[Buffer] = None, populate: int = 0):
        self.dataset = dataset
        self.storage = storage
        self.depth = prefetch_depth
        self.buffer = buffer or Buffer(capacity_bytes=8 << 30, name="data-buffer")
        self.start_step = start_step
        self._stop = threading.Event()
        self._q: "queue.Queue[int]" = queue.Queue()
        self._requested: set = set()
        self._lock = threading.Lock()
        for i in range(populate):          # seed the storage service
            self.put_batch(start_step + i)
        self._thread: Optional[threading.Thread] = None

    def put_batch(self, i: int) -> None:
        self.storage.put(self._key(i), self.dataset.serialize(i))

    def _key(self, i: int) -> str:
        return f"data/shard-{i:06d}"

    def _ensure(self, i: int) -> None:
        """Queue fetches for steps i..i+depth (request-driven: robust to
        resuming from an arbitrary checkpoint step)."""
        with self._lock:
            for j in range(i, i + self.depth + 1):
                if j not in self._requested:
                    self._requested.add(j)
                    self._q.put(j)

    # ------------------------------------------------------------- prefetch
    def start_prefetch(self, from_step: Optional[int] = None) -> None:
        """Kick the SDP data path (call when the cold start begins)."""
        self._ensure(self.start_step if from_step is None else from_step)
        if self._thread is not None:
            return

        def loop():
            while not self._stop.is_set():
                try:
                    i = self._q.get(timeout=0.2)
                except queue.Empty:
                    continue
                key = self._key(i)
                if not self.storage.exists(key):
                    self.put_batch(i)      # synthetic source is inexhaustible
                data, _ = self.storage.get(key)
                self.buffer.set(key, data)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="sdp-data-prefetch")
        self._thread.start()

    def get(self, i: int, timeout: float = 120.0) -> Dict[str, np.ndarray]:
        """Consume batch i (waits on the buffer; keeps depth batches ahead)."""
        if self._thread is None:
            self.start_prefetch(i)
        self._ensure(i)
        data = self.buffer.wait_for(self._key(i), timeout=timeout, pop=True)
        if data is None:
            raise TimeoutError(f"batch {i} never arrived")
        return TokenDataset.deserialize(data)

    def stop(self) -> None:
        self._stop.set()
