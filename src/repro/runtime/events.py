"""In-process event bus — the Kubernetes API / etcd watch-stream analogue.

The Truffle Watcher subscribes here exactly as the paper's Watcher subscribes
to Kube pod events (DESIGN §2: assumption change — no external etcd)."""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional


class EventBus:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._subs: Dict[str, List[Callable[[dict], None]]] = defaultdict(list)
        self._log: List[tuple] = []  # (topic, event) history for late joiners

    def publish(self, topic: str, event: dict) -> None:
        with self._cond:
            self._log.append((topic, event))
            subs = list(self._subs.get(topic, ()))
            self._cond.notify_all()
        for cb in subs:
            cb(event)

    def subscribe(self, topic: str, callback: Callable[[dict], None]) -> None:
        with self._lock:
            self._subs[topic].append(callback)

    def wait_for(self, topic: str, predicate: Callable[[dict], bool],
                 timeout: Optional[float] = None,
                 include_history: bool = True) -> Optional[dict]:
        """Block until an event on ``topic`` satisfies ``predicate``."""
        import time as _t
        deadline = None if timeout is None else _t.monotonic() + timeout
        with self._cond:
            idx = 0 if include_history else len(self._log)
            while True:
                while idx < len(self._log):
                    t, e = self._log[idx]
                    idx += 1
                    if t == topic and predicate(e):
                        return e
                remaining = None
                if deadline is not None:
                    remaining = deadline - _t.monotonic()
                    if remaining <= 0:
                        return None
                self._cond.wait(remaining)

    def history(self, topic: str) -> List[dict]:
        with self._lock:
            return [e for t, e in self._log if t == topic]
