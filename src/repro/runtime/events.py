"""In-process event bus — the Kubernetes API / etcd watch-stream analogue.

The Truffle Watcher subscribes here exactly as the paper's Watcher subscribes
to Kube pod events (DESIGN §2: assumption change — no external etcd).

Sharded per topic: each topic owns its lock, its subscriber list, and a
BOUNDED retained-event window (``retain`` events, default
:data:`DEFAULT_RETAIN`, env ``TRUFFLE_BUS_RETAIN``). Publishing on one
topic never contends with waiters or publishers on another, ``wait_for``
scans only its own topic's window from a sequence cursor (no full-log
rescans), and ``history`` is a copy of the per-topic window — O(window),
not O(total events ever published). Late-joiner semantics hold over the
retained window: a waiter that arrives after an event was published still
sees it as long as it hasn't aged out; soak runs publishing millions of
events stay at bounded memory (``stats()["dropped"]`` counts the aged-out
events). Topic locks are leaves — nothing is called, and no other lock is
taken, while one is held (subscriber callbacks fire after release)."""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

#: retained events per topic (the late-joiner replay window)
DEFAULT_RETAIN = int(os.environ.get("TRUFFLE_BUS_RETAIN", "4096"))


class _Topic:
    """One topic's bounded window + waiters + subscribers, behind its own
    lock. Sequence numbers are absolute: ``_base`` is the seq of the oldest
    retained event, ``_next`` the seq the next publish gets, so cursors
    survive trims (a cursor behind ``_base`` simply skips what aged out)."""

    __slots__ = ("_lock", "_cond", "_events", "_base", "_next",
                 "_subs", "_retain", "_dropped")

    def __init__(self, retain: int) -> None:
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._events: Deque[dict] = deque()
        self._base = 0              # seq of _events[0]
        self._next = 0              # seq of the next publish
        self._subs: List[Callable[[dict], None]] = []
        self._retain = retain
        self._dropped = 0           # events aged out of the window


class EventBus:
    def __init__(self, retain: int = DEFAULT_RETAIN) -> None:
        self._retain = retain
        self._topics: Dict[str, _Topic] = {}

    def _topic(self, topic: str) -> "_Topic":
        t = self._topics.get(topic)
        if t is None:
            # setdefault is atomic: concurrent first-publishers converge
            # on one _Topic without a bus-wide lock
            t = self._topics.setdefault(topic, _Topic(self._retain))
        return t

    def publish(self, topic: str, event: dict) -> None:
        t = self._topic(topic)
        with t._cond:
            t._events.append(event)
            t._next += 1
            if len(t._events) > t._retain:
                t._events.popleft()
                t._base += 1
                t._dropped += 1
            subs = list(t._subs) if t._subs else ()
            t._cond.notify_all()
        for cb in subs:
            cb(event)

    def subscribe(self, topic: str, callback: Callable[[dict], None]) -> None:
        t = self._topic(topic)
        with t._lock:
            t._subs.append(callback)

    def wait_for(self, topic: str, predicate: Callable[[dict], bool],
                 timeout: Optional[float] = None,
                 include_history: bool = True) -> Optional[dict]:
        """Block until an event on ``topic`` satisfies ``predicate``.
        ``include_history`` replays the retained window first; the cursor
        then follows live publishes (jumping past anything that ages out
        while this waiter sleeps)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        t = self._topic(topic)
        with t._cond:
            seq = t._base if include_history else t._next
            while True:
                if seq < t._base:
                    seq = t._base       # aged out while we slept
                while seq < t._next:
                    e = t._events[seq - t._base]
                    seq += 1
                    if predicate(e):
                        return e
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                t._cond.wait(remaining)

    def history(self, topic: str) -> List[dict]:
        """The retained window for ``topic``, oldest first."""
        t = self._topics.get(topic)
        if t is None:
            return []
        with t._lock:
            return list(t._events)

    def stats(self) -> Dict[str, int]:
        """Bus-wide occupancy: topic count, retained events, aged-out
        events. Counters are read racily (sum of per-topic snapshots) —
        good enough for soak assertions and dashboards."""
        topics = list(self._topics.values())
        return {"topics": len(topics),
                "retained": sum(len(t._events) for t in topics),
                "dropped": sum(t._dropped for t in topics)}
