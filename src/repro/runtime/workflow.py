"""Workflow DAG + executor.

Reproduces the paper's two evaluation workflows (Chained Functions;
Video Analytics with fan-out/fan-in) under four data-passing strategies:
  baseline x {direct, kvs, s3}  — sequential lifecycle (Fig. 2)
  truffle  x {direct, kvs, s3}  — SDP/CSP overlap (Figs. 5/6)

Also provides speculative straggler mitigation: a stage exceeding
``straggler_factor`` x its predicted time is re-dispatched and the first
finisher wins (duplicate results are idempotent by construction here).

Data-plane knobs (truffle mode): ``stream=True`` pipelines stage-to-stage
transfers at chunk granularity; ``dedup=True`` content-addresses stage
outputs so identical fan-out inputs alias the target buffer instead of
re-shipping — and propagates each stage input's digest on its ContentRef,
so the locality-aware scheduler can place downstream stages on the node
already holding their bytes. Defaults keep the whole-blob behavior."""
from __future__ import annotations

import threading
import uuid
from concurrent.futures import Future, ThreadPoolExecutor, FIRST_COMPLETED, wait
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.buffer import content_digest
from repro.core.model import PhaseEstimate, baseline_time, truffle_time
from repro.runtime.function import ContentRef, FunctionSpec, LifecycleRecord, Request


@dataclass
class Stage:
    spec: FunctionSpec
    deps: List[str] = field(default_factory=list)


@dataclass
class Workflow:
    name: str
    stages: Dict[str, Stage]

    def topo_order(self) -> List[str]:
        order, seen = [], set()

        def visit(n):
            if n in seen:
                return
            for d in self.stages[n].deps:
                visit(d)
            seen.add(n)
            order.append(n)

        for n in self.stages:
            visit(n)
        return order

    def roots(self) -> List[str]:
        return [n for n, s in self.stages.items() if not s.deps]


@dataclass
class StageResult:
    name: str
    output: bytes
    record: LifecycleRecord
    put_s: float = 0.0            # storage write time (kvs/s3 passing)
    speculated: bool = False


@dataclass
class WorkflowTrace:
    workflow: str
    mode: str                     # baseline | truffle
    storage: str                  # direct | kvs | s3
    stages: Dict[str, StageResult] = field(default_factory=dict)
    t_start: float = 0.0
    t_end: float = 0.0

    @property
    def total(self) -> float:
        return self.t_end - self.t_start

    def phase_totals(self) -> Dict[str, float]:
        tot = {"scheduling": 0.0, "cold_start": 0.0, "io": 0.0,
               "execution": 0.0, "put": 0.0}
        for sr in self.stages.values():
            for k, v in sr.record.phases().items():
                if k != "total":
                    tot[k] = tot.get(k, 0.0) + v
            tot["put"] += sr.put_s
        return tot

    @property
    def io_total(self) -> float:
        return self.phase_totals()["io"] + self.phase_totals()["put"]


class WorkflowRunner:
    def __init__(self, cluster, *, use_truffle: bool, storage: str = "direct",
                 straggler_factor: float = 0.0, prewarm_roots: bool = False,
                 estimates: Optional[Dict[str, PhaseEstimate]] = None,
                 stream: bool = False, dedup: bool = False):
        self.cluster = cluster
        self.use_truffle = use_truffle
        self.storage = storage
        self.straggler_factor = straggler_factor
        self.prewarm_roots = prewarm_roots
        self.estimates = estimates or {}
        # chunked-streaming data plane knobs (truffle mode only): stream
        # pipelines transfers at chunk granularity, dedup content-addresses
        # stage outputs so fan-out inputs alias instead of re-shipping
        self.stream = stream
        self.dedup = dedup

    # ------------------------------------------------------------------ run
    def run(self, wf: Workflow, input_data: bytes,
            source_node: str = None) -> WorkflowTrace:
        cluster = self.cluster
        for st in wf.stages.values():
            cluster.platform.register(st.spec)
        source_node = source_node or cluster.node_list[0].name
        if self.prewarm_roots:
            # the paper's latency metric starts at the *source* function's
            # send; warm the roots so measurement covers the passing path
            for name in wf.roots():
                cluster.platform.invoke(Request(fn=wf.stages[name].spec.name,
                                                payload=b"",
                                                source_node=source_node))
        trace = WorkflowTrace(wf.name, "truffle" if self.use_truffle else "baseline",
                              self.storage)
        trace.t_start = cluster.clock.now()

        results: Dict[str, StageResult] = {}
        lock = threading.Lock()
        done_cv = threading.Condition(lock)
        errbox: List[BaseException] = []

        def stage_input(name: str) -> Tuple[bytes, str]:
            st = wf.stages[name]
            if not st.deps:
                return input_data, source_node
            outs = [results[d].output for d in st.deps]
            src = results[st.deps[-1]].record.node or source_node
            return b"".join(outs), src

        def run_stage(name: str):
            try:
                data, src = stage_input(name)
                sr = self._dispatch(name, wf.stages[name], data, src)
                with done_cv:
                    results[name] = sr
                    done_cv.notify_all()
            except BaseException as e:  # noqa: BLE001
                with done_cv:
                    errbox.append(e)
                    done_cv.notify_all()

        order = wf.topo_order()
        started = set()
        with done_cv:
            while len(results) < len(order) and not errbox:
                for name in order:
                    if name in started:
                        continue
                    if all(d in results for d in wf.stages[name].deps):
                        started.add(name)
                        threading.Thread(target=run_stage, args=(name,),
                                         daemon=True).start()
                done_cv.wait(timeout=300)
        if errbox:
            raise errbox[0]

        trace.t_end = cluster.clock.now()
        trace.stages = results
        return trace

    # ------------------------------------------------------- stage dispatch
    def _dispatch(self, name: str, stage: Stage, data: bytes,
                  source_node: str) -> StageResult:
        def attempt() -> StageResult:
            return self._invoke_once(name, stage, data, source_node)

        est = self.estimates.get(name)
        if self.straggler_factor and est is not None:
            budget = self.straggler_factor * (
                truffle_time(est) if self.use_truffle else baseline_time(est))
            budget *= self.cluster.clock.scale      # sim -> wall seconds
            pool = ThreadPoolExecutor(max_workers=2)
            try:
                first = pool.submit(attempt)
                done, _ = wait([first], timeout=budget)
                if done:
                    return first.result()
                backup = pool.submit(attempt)    # speculative duplicate
                wait([first, backup], return_when=FIRST_COMPLETED)
                # deterministic winner: the original attempt wins whenever it
                # has finished (results are idempotent, and preferring it
                # keeps the speculated flag truthful when both are done or
                # when first completed between the two waits)
                winner = first if first.done() else backup
                sr = winner.result()
                sr.speculated = winner is backup
                return sr
            finally:
                # without this every straggler stage leaked a live executor
                # (two worker threads parked forever); cancel_futures stops a
                # not-yet-started duplicate from running after the winner
                pool.shutdown(wait=False, cancel_futures=True)
        return attempt()

    def _invoke_once(self, name: str, stage: Stage, data: bytes,
                     source_node: str) -> StageResult:
        cluster = self.cluster
        fn = stage.spec.name
        put_s = 0.0

        if self.storage in ("kvs", "s3"):
            # producer writes to the storage service first (both modes — the
            # storage flavor defines where the data lives; paper Fig. 9b/9c)
            key = f"{fn}/{uuid.uuid4().hex[:8]}"
            t0 = cluster.clock.now()
            cluster.storage[self.storage].put(key, data)
            put_s = cluster.clock.now() - t0
            # dedup: content-address the stage input so downstream placement
            # (and the target buffer's alias check) can see where it lives
            digest = content_digest(data) if self.dedup else None
            req = Request(fn=fn, content_ref=ContentRef(self.storage, key,
                                                        len(data),
                                                        digest=digest),
                          source_node=source_node)
            if self.use_truffle:
                truffle = cluster.node(source_node).truffle
                out, rec = truffle.handle_request(
                    req, stream=self.stream, dedup=self.dedup)   # SDP
            else:
                out, rec = cluster.platform.invoke(req)      # fetch after start
        else:  # direct
            if self.use_truffle:
                truffle = cluster.node(source_node).truffle
                out, rec = truffle.pass_data(
                    fn, data, stream=self.stream, dedup=self.dedup)  # CSP
            else:
                req = Request(fn=fn, payload=data, source_node=source_node)
                out, rec = cluster.platform.invoke(req)      # body held at ingress

        return StageResult(name=name, output=out, record=rec, put_s=put_s)
