"""Workflow DAG + executor.

Reproduces the paper's two evaluation workflows (Chained Functions;
Video Analytics with fan-out/fan-in) under four data-passing strategies:
  baseline x {direct, kvs, s3}  — sequential lifecycle (Fig. 2)
  truffle  x {direct, kvs, s3}  — SDP/CSP overlap (Figs. 5/6)

The data plane is configured at DATA-FLOW granularity: every edge of the
DAG resolves to a :class:`~repro.runtime.policy.DataPolicy` (strategy /
stream / dedup / compression / locality_weight / prefetch / speculation),
and the :class:`~repro.runtime.planner.Planner` compiles workflow +
policies into an immutable :class:`~repro.runtime.planner.ExecutionPlan`
that this runner dispatches from — a WAN hop can compress while a fan-out
hop dedups, and a fan-in stage hints one digest PER DEP so the scheduler
scores the sum of its resident inputs. Build workflows with
:class:`~repro.runtime.policy.WorkflowBuilder` (or hand-built
``Stage``/``Workflow`` dicts, which still work).

Back-compat shim: the legacy ``WorkflowRunner(stream=, dedup=, storage=,
straggler_factor=)`` kwargs construct a uniform default policy and compile
through the same Planner — every pre-existing call site behaves exactly as
before.

Speculative straggler mitigation: a stage exceeding its policy's
``speculation`` factor x its predicted time is re-dispatched; the backup
attempt carries an ``avoid`` hint for the straggler's node (failure
independence), and the first finisher wins (duplicate results are
idempotent by construction here)."""
from __future__ import annotations

import threading
import uuid
from concurrent.futures import Future, ThreadPoolExecutor, FIRST_COMPLETED, wait
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.buffer import content_digest
from repro.core.errors import PlanError, WorkflowCycleError
from repro.core.model import PhaseEstimate, baseline_time, truffle_time
from repro.core.transfer import publish_content
from repro.runtime.function import ContentRef, FunctionSpec, LifecycleRecord, Request
from repro.runtime.planner import ExecutionPlan, Planner, StagePlan
from repro.runtime.policy import DataPolicy


@dataclass
class Stage:
    spec: FunctionSpec
    deps: List[str] = field(default_factory=list)
    #: stage-level policy: default for every in-edge of this stage
    policy: Optional[DataPolicy] = None
    #: per-edge overrides: {dep name -> policy for the (dep -> this) edge}
    dep_policies: Dict[str, DataPolicy] = field(default_factory=dict)


@dataclass
class Workflow:
    name: str
    stages: Dict[str, Stage]
    #: workflow-level default policy (stage/edge policies override it)
    default_policy: Optional[DataPolicy] = None

    def topo_order(self) -> List[str]:
        """Dependency-respecting order. Raises
        :class:`~repro.core.errors.WorkflowCycleError` (naming the cycle)
        on cyclic deps instead of recursing forever, and ``KeyError`` on a
        dep that names no stage."""
        order: List[str] = []
        state: Dict[str, int] = {}       # 1 = on the current DFS path, 2 = done

        def visit(n: str, path: Tuple[str, ...]) -> None:
            if state.get(n) == 2:
                return
            if state.get(n) == 1:
                cycle = path[path.index(n):] + (n,)
                raise WorkflowCycleError(cycle)
            if n not in self.stages:
                raise KeyError(f"workflow {self.name!r}: dep {n!r} names no "
                               f"stage (have: {sorted(self.stages)})")
            state[n] = 1
            for d in self.stages[n].deps:
                visit(d, path + (n,))
            state[n] = 2
            order.append(n)

        for n in self.stages:
            visit(n, ())
        return order

    def roots(self) -> List[str]:
        return [n for n, s in self.stages.items() if not s.deps]


@dataclass
class StageResult:
    name: str
    output: bytes
    record: LifecycleRecord
    put_s: float = 0.0            # storage write time (kvs/s3 passing)
    speculated: bool = False
    digest: Optional[str] = None  # output content address (seed_output plans)


@dataclass
class WorkflowTrace:
    workflow: str
    mode: str                     # baseline | truffle
    storage: str                  # direct | kvs | s3 | mixed (plan label)
    stages: Dict[str, StageResult] = field(default_factory=dict)
    t_start: float = 0.0
    t_end: float = 0.0

    @property
    def total(self) -> float:
        return self.t_end - self.t_start

    def phase_totals(self) -> Dict[str, float]:
        tot = {"scheduling": 0.0, "cold_start": 0.0, "io": 0.0,
               "execution": 0.0, "put": 0.0}
        for sr in self.stages.values():
            for k, v in sr.record.phases().items():
                if k != "total":
                    tot[k] = tot.get(k, 0.0) + v
            tot["put"] += sr.put_s
        return tot

    @property
    def io_total(self) -> float:
        return self.phase_totals()["io"] + self.phase_totals()["put"]


class WorkflowRunner:
    def __init__(self, cluster, *, use_truffle: bool = True,
                 plan: Optional[ExecutionPlan] = None,
                 policy: Optional[DataPolicy] = None,
                 storage: str = "direct",
                 straggler_factor: float = 0.0, prewarm_roots: bool = False,
                 estimates: Optional[Dict[str, PhaseEstimate]] = None,
                 stream: bool = False, dedup: bool = False):
        """``policy`` (or a precompiled ``plan``) is the native surface.
        The legacy runner-global knobs — ``storage``/``stream``/``dedup``/
        ``straggler_factor`` — are a back-compat shim: they construct the
        equivalent uniform :class:`DataPolicy` and compile through the same
        Planner, so old call sites keep their exact behavior."""
        self.cluster = cluster
        self.use_truffle = use_truffle
        self.prewarm_roots = prewarm_roots
        self.estimates = estimates or {}
        if policy is None:
            policy = DataPolicy(strategy=storage, stream=stream, dedup=dedup,
                                speculation=straggler_factor)
        self.default_policy = policy
        self.plan = plan
        # legacy mirrors (kept readable for old call sites; the data plane
        # itself consumes the compiled ExecutionPlan, never these)
        self.storage = policy.strategy
        self.stream = policy.stream
        self.dedup = policy.dedup
        self.straggler_factor = policy.speculation

    def compile(self, wf: Workflow) -> ExecutionPlan:
        """Compile ``wf`` against this runner's default policy."""
        return Planner(default=self.default_policy).compile(wf)

    # ------------------------------------------------------------------ run
    def run(self, wf: Workflow, input_data: bytes,
            source_node: str = None,
            plan: Optional[ExecutionPlan] = None) -> WorkflowTrace:
        cluster = self.cluster
        plan = plan or self.plan or self.compile(wf)
        if set(plan.stages) != set(wf.stages):
            raise PlanError(f"plan {plan.workflow!r} does not cover workflow "
                            f"{wf.name!r}: plan stages {sorted(plan.stages)} "
                            f"!= workflow stages {sorted(wf.stages)}")
        for st in wf.stages.values():
            cluster.platform.register(st.spec)
        source_node = source_node or cluster.node_list[0].name
        if self.prewarm_roots:
            # the paper's latency metric starts at the *source* function's
            # send; warm the roots so measurement covers the passing path
            for name in wf.roots():
                cluster.platform.invoke(Request(fn=wf.stages[name].spec.name,
                                                payload=b"",
                                                source_node=source_node))
        trace = WorkflowTrace(wf.name, "truffle" if self.use_truffle else "baseline",
                              plan.label())
        trace.t_start = cluster.clock.now()

        results: Dict[str, StageResult] = {}
        lock = threading.Lock()
        done_cv = threading.Condition(lock)
        errbox: List[BaseException] = []

        def stage_input(name: str) -> Tuple[bytes, str, tuple]:
            sp = plan.stages[name]
            if not sp.deps:
                return input_data, source_node, ()
            outs = [results[d].output for d in sp.deps]
            src = results[sp.deps[-1]].record.node or source_node
            hints = tuple((results[d].digest, len(results[d].output))
                          for d in sp.hint_deps
                          if results[d].digest is not None)
            # single dep: hand the output through without a join copy
            return (outs[0] if len(outs) == 1 else b"".join(outs)), src, hints

        def run_stage(name: str):
            try:
                data, src, hints = stage_input(name)
                sr = self._dispatch(name, wf.stages[name].spec,
                                    plan.stages[name], data, src, hints)
                self._seed_output(plan.stages[name], sr)
                with done_cv:
                    results[name] = sr
                    done_cv.notify_all()
            except BaseException as e:  # noqa: BLE001
                with done_cv:
                    errbox.append(e)
                    done_cv.notify_all()

        order = plan.order
        started = set()
        with done_cv:
            while len(results) < len(order) and not errbox:
                for name in order:
                    if name in started:
                        continue
                    if all(d in results for d in plan.stages[name].deps):
                        started.add(name)
                        threading.Thread(target=run_stage, args=(name,),
                                         daemon=True).start()
                done_cv.wait(timeout=300)
        if errbox:
            raise errbox[0]

        trace.t_end = cluster.clock.now()
        trace.stages = results
        return trace

    def _seed_output(self, sp: StagePlan, sr: StageResult) -> None:
        """Content-address a stage's output and publish it on the node that
        produced it (plan ``seed_output`` directive: some consumer edge
        dedups). Downstream placement hints then score each dep's bytes
        where they actually live — the multi-input fan-in hint."""
        if not sp.seed_output or not self.use_truffle:
            return
        sr.digest = content_digest(sr.output)
        node = self.cluster.nodes.get(sr.record.node)
        if node is not None:
            publish_content(node, sr.output, sr.digest)

    # ------------------------------------------------------- stage dispatch
    def _dispatch(self, name: str, spec: FunctionSpec, sp: StagePlan,
                  data: bytes, source_node: str,
                  input_hints: tuple) -> StageResult:
        def attempt(avoid: Optional[str] = None) -> StageResult:
            return self._invoke_once(name, spec, sp, data, source_node,
                                     input_hints, avoid=avoid)

        est = self.estimates.get(name)
        if sp.transport.speculation and est is not None:
            budget = sp.transport.speculation * (
                truffle_time(est) if self.use_truffle else baseline_time(est))
            budget *= self.cluster.clock.scale      # sim -> wall seconds
            pool = ThreadPoolExecutor(max_workers=2)
            try:
                first = pool.submit(attempt)
                done, _ = wait([first], timeout=budget)
                if done:
                    return first.result()
                # failure independence: steer the backup OFF the node the
                # straggler was placed on (its placement event is on the bus
                # even though the attempt itself is still stuck)
                backup = pool.submit(attempt, self._placed_node(spec.name))
                wait([first, backup], return_when=FIRST_COMPLETED)
                # deterministic winner: the original attempt wins whenever it
                # has finished (results are idempotent, and preferring it
                # keeps the speculated flag truthful when both are done or
                # when first completed between the two waits)
                winner = first if first.done() else backup
                sr = winner.result()
                sr.speculated = winner is backup
                return sr
            finally:
                # without this every straggler stage leaked a live executor
                # (two worker threads parked forever); cancel_futures stops a
                # not-yet-started duplicate from running after the winner
                pool.shutdown(wait=False, cancel_futures=True)
        return attempt()

    def _placed_node(self, fn: str) -> Optional[str]:
        """Node the straggling attempt landed on, from the scheduling event
        stream (the attempt is stuck — its record isn't back yet)."""
        for ev in reversed(self.cluster.bus.history("scheduling.placed")):
            if ev["function"] == fn:
                return ev["node"]
        return None

    @staticmethod
    def _known_digest(pol: DataPolicy, data: bytes,
                      input_hints: tuple) -> Optional[str]:
        """The stage input's digest when an upstream seed already computed
        it (single-dep stage: input IS the dep's output) — re-hashing tens
        of MB per hop is pure waste on the dispatch path."""
        if not pol.dedup:
            return None
        if len(input_hints) == 1 and input_hints[0][1] == len(data):
            return input_hints[0][0]
        return content_digest(data)

    def _invoke_once(self, name: str, spec: FunctionSpec, sp: StagePlan,
                     data: bytes, source_node: str, input_hints: tuple,
                     avoid: Optional[str] = None) -> StageResult:
        cluster = self.cluster
        fn = spec.name
        pol = sp.transport
        put_s = 0.0
        meta = {}
        # baseline paths have no policy plumbing — the hint directives ride
        # the request meta and PlacementHint.from_request picks them up
        if avoid is not None:
            meta["avoid_node"] = avoid
        if pol.prefetch and self.use_truffle:
            # a prefetch relay lands in Truffle buffers — meaningless (and
            # wasted fabric) for the baseline's payload-carrying path
            meta["prefetch"] = True
        if pol.locality_weight is not None:
            meta["locality_weight"] = pol.locality_weight

        if pol.strategy in ("kvs", "s3"):
            # producer writes to the storage service first (both modes — the
            # storage flavor defines where the data lives; paper Fig. 9b/9c)
            key = f"{fn}/{uuid.uuid4().hex[:8]}"
            t0 = cluster.clock.now()
            cluster.storage[pol.strategy].put(key, data)
            put_s = cluster.clock.now() - t0
            # dedup: content-address the stage input so downstream placement
            # (and the target buffer's alias check) can see where it lives
            digest = self._known_digest(pol, data, input_hints)
            req = Request(fn=fn, content_ref=ContentRef(pol.strategy, key,
                                                        len(data),
                                                        digest=digest,
                                                        inputs=(input_hints
                                                                or None)),
                          source_node=source_node, meta=meta)
            if self.use_truffle:
                truffle = cluster.node(source_node).truffle
                out, rec = truffle.handle_request(req, policy=pol,
                                                  avoid=avoid)     # SDP
            else:
                out, rec = cluster.platform.invoke(req)      # fetch after start
        else:  # direct
            if self.use_truffle:
                truffle = cluster.node(source_node).truffle
                out, rec = truffle.pass_data(
                    fn, data, policy=pol, input_hints=input_hints or None,
                    avoid=avoid,
                    digest=self._known_digest(pol, data, input_hints))  # CSP
            else:
                req = Request(fn=fn, payload=data, source_node=source_node,
                              meta=meta)
                out, rec = cluster.platform.invoke(req)      # body held at ingress

        # profiled plans carry a compile-time Eq. 4 prediction per stage;
        # stamping it here makes predicted-vs-measured error assertable
        rec.predicted_s = sp.predicted_s
        return StageResult(name=name, output=out, record=rec, put_s=put_s)
