"""Workflow DAG + executor.

Reproduces the paper's two evaluation workflows (Chained Functions;
Video Analytics with fan-out/fan-in) under four data-passing strategies:
  baseline x {direct, kvs, s3}  — sequential lifecycle (Fig. 2)
  truffle  x {direct, kvs, s3}  — SDP/CSP overlap (Figs. 5/6)

The data plane is configured at DATA-FLOW granularity: every edge of the
DAG resolves to a :class:`~repro.runtime.policy.DataPolicy` (strategy /
stream / dedup / compression / locality_weight / prefetch / speculation),
and the :class:`~repro.runtime.planner.Planner` compiles workflow +
policies into an immutable :class:`~repro.runtime.planner.ExecutionPlan`
that this runner dispatches from — a WAN hop can compress while a fan-out
hop dedups, and a fan-in stage hints one digest PER DEP so the scheduler
scores the sum of its resident inputs. Build workflows with
:class:`~repro.runtime.policy.WorkflowBuilder` (or hand-built
``Stage``/``Workflow`` dicts, which still work).

Back-compat shim: the legacy ``WorkflowRunner(stream=, dedup=, storage=,
straggler_factor=)`` kwargs construct a uniform default policy and compile
through the same Planner — every pre-existing call site behaves exactly as
before.

Speculative straggler mitigation: a stage exceeding its policy's
``speculation`` factor x its predicted time is re-dispatched; the backup
attempt carries an ``avoid`` hint for the straggler's node (failure
independence), and the first finisher wins (duplicate results are
idempotent by construction here). The budget comes from a caller-provided
``estimates`` PhaseEstimate when given, else from the compiled plan's own
Eq. 4 prediction (``StagePlan.speculation_budget_s``) — which is what
makes ``DataPolicy(speculation="auto")`` self-contained: the planner
resolves the factor from link variability and the budget from its own
prediction, no user numbers required.

Mid-flight re-planning: construct the runner with
``replan=ReplanPolicy(...)`` (optionally ``planner=``; defaults to an
:class:`~repro.runtime.planner.AdaptivePlanner` on the cluster). Between
stage waves — every time a stage completes, before the newly-unblocked
stages are dispatched — a :class:`ReplanController` re-predicts the
remaining subgraph against current telemetry and, past the policy's drift
threshold, swaps in a plan recompiled for the not-yet-dispatched stages
only. In-flight stages keep the plan they were dispatched under; every
flip is published as a ``plan.replanned`` bus event and recorded on
``WorkflowTrace.replans``; each record's ``replan_count`` says which plan
generation dispatched it. The runner also publishes a
``workflow.stage_done`` event per completed stage (wave counter — the
fault-timeline harness keys on it)."""
from __future__ import annotations

import threading
import time
import uuid
from concurrent.futures import (Future, ThreadPoolExecutor, FIRST_COMPLETED,
                                TimeoutError as FuturesTimeout, wait)
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.buffer import content_digest
from repro.core.errors import (BufferOfflineError, LinkDownError,
                               NodeCrashError, PlanError, StageExecutionError,
                               TransferStallError, WorkflowCycleError)
from repro.core.model import (PhaseEstimate, baseline_time, calibrated_budget,
                              drift, fold_inflation, should_replan,
                              stage_inflation, truffle_time)
from repro.core.transfer import publish_content
from repro.runtime.executor import EXECUTOR
from repro.runtime.function import ContentRef, FunctionSpec, LifecycleRecord, Request
from repro.runtime.planner import ExecutionPlan, Planner, StagePlan
from repro.runtime.policy import DataPolicy, ReplanPolicy


@dataclass
class Stage:
    spec: FunctionSpec
    deps: List[str] = field(default_factory=list)
    #: stage-level policy: default for every in-edge of this stage
    policy: Optional[DataPolicy] = None
    #: per-edge overrides: {dep name -> policy for the (dep -> this) edge}
    dep_policies: Dict[str, DataPolicy] = field(default_factory=dict)


@dataclass
class Workflow:
    name: str
    stages: Dict[str, Stage]
    #: workflow-level default policy (stage/edge policies override it)
    default_policy: Optional[DataPolicy] = None

    def topo_order(self) -> List[str]:
        """Dependency-respecting order. Raises
        :class:`~repro.core.errors.WorkflowCycleError` (naming the cycle)
        on cyclic deps instead of recursing forever, and ``KeyError`` on a
        dep that names no stage."""
        order: List[str] = []
        state: Dict[str, int] = {}       # 1 = on the current DFS path, 2 = done

        def visit(n: str, path: Tuple[str, ...]) -> None:
            if state.get(n) == 2:
                return
            if state.get(n) == 1:
                cycle = path[path.index(n):] + (n,)
                raise WorkflowCycleError(cycle)
            if n not in self.stages:
                raise KeyError(f"workflow {self.name!r}: dep {n!r} names no "
                               f"stage (have: {sorted(self.stages)})")
            state[n] = 1
            for d in self.stages[n].deps:
                visit(d, path + (n,))
            state[n] = 2
            order.append(n)

        for n in self.stages:
            visit(n, ())
        return order

    def roots(self) -> List[str]:
        return [n for n, s in self.stages.items() if not s.deps]


@dataclass
class StageResult:
    name: str
    output: bytes
    record: LifecycleRecord
    put_s: float = 0.0            # storage write time (kvs/s3 passing)
    speculated: bool = False
    digest: Optional[str] = None  # output content address (seed_output plans)
    attempts: int = 1             # dispatch attempts this result took


@dataclass
class WorkflowTrace:
    workflow: str
    mode: str                     # baseline | truffle
    storage: str                  # direct | kvs | s3 | mixed (plan label)
    stages: Dict[str, StageResult] = field(default_factory=dict)
    t_start: float = 0.0
    t_end: float = 0.0
    #: mid-flight replan trail: one dict per plan flip (mirrors the
    #: ``plan.replanned`` bus events), empty when re-planning was off/quiet
    replans: List[dict] = field(default_factory=list)
    #: generation of the plan in force when the run finished
    plan_generation: int = 0
    #: crash-restart recovery tally: stage retry attempts beyond the first,
    #: and upstream stages re-executed because their output's LAST replica
    #: died with a node (retries that re-shipped from a surviving replica
    #: count only in ``retries``)
    retries: int = 0
    upstream_reruns: int = 0

    @property
    def total(self) -> float:
        return self.t_end - self.t_start

    def phase_totals(self) -> Dict[str, float]:
        tot = {"scheduling": 0.0, "cold_start": 0.0, "io": 0.0,
               "execution": 0.0, "put": 0.0}
        for sr in self.stages.values():
            for k, v in sr.record.phases().items():
                if k != "total":
                    tot[k] = tot.get(k, 0.0) + v
            tot["put"] += sr.put_s
        return tot

    @property
    def io_total(self) -> float:
        return self.phase_totals()["io"] + self.phase_totals()["put"]


class ReplanController:
    """Applies a :class:`~repro.runtime.policy.ReplanPolicy` between stage
    waves: re-predict the not-yet-dispatched subgraph against current
    telemetry, and recompile it when the drift crosses the threshold.

    Kept separate from the runner (and free of any thread machinery) so
    the rate-limiting contract — ``max_replans`` is a hard cap,
    ``min_interval`` sim-seconds must pass between flips, frozen telemetry
    never replans — is directly property-testable against scripted drift
    sequences."""

    def __init__(self, planner, policy: ReplanPolicy, wf,
                 clock=None, bus=None, health=None):
        self.planner = planner
        self.policy = policy
        self.wf = wf
        self.clock = clock
        self.bus = bus
        self.health = health                # NodeHealthMonitor (optional)
        self.count = 0                      # replans performed
        self.events: List[dict] = []        # trail, mirrored on the bus
        self._last: Optional[float] = None  # wall time of the last replan
        self._health_gen = (health.generation if health is not None else 0)

    def consider(self, plan: ExecutionPlan, dispatched,
                 now: Optional[float] = None) -> Optional[ExecutionPlan]:
        """Return a spliced replacement plan, or None to keep ``plan``.
        ``dispatched`` is the set of stages already handed to a thread —
        those keep their StagePlan verbatim. ``now`` defaults to the
        clock's wall reading (tests may script it).

        A node-health state flip since the last wave (monitor generation
        changed: a node died, degraded, or recovered) FORCES the recompile
        for the undispatched subgraph — drift gating, the min-interval
        rate limit, and even a missing prediction signal are bypassed; the
        cluster's topology changed and the remaining stages' predictions
        and speculation budgets must reflect it. ``max_replans`` stays a
        hard cap either way."""
        pol = self.policy
        forced = False
        if self.health is not None:
            gen = self.health.generation
            if gen != self._health_gen:
                self._health_gen = gen      # consume the flip either way
                forced = True
        if self.count >= pol.max_replans:
            return None
        remaining = [n for n in plan.order if n not in dispatched]
        if not remaining:
            return None
        if now is None:
            now = (self.clock.now() if self.clock is not None
                   else time.monotonic())
        if not forced and self._last is not None and pol.min_interval > 0:
            elapsed = now - self._last
            if self.clock is not None:
                elapsed = self.clock.elapsed_sim(elapsed)
            if elapsed < pol.min_interval:
                return None
        pred = self.planner.predict_remaining(self.wf, plan, remaining)
        if pred is None and not forced:
            return None                     # no comparable edge: no signal
        fresh, frozen = pred if pred is not None else (None, None)
        if not forced and not should_replan(fresh, frozen, pol.drift_ratio):
            return None
        new = self.planner.recompile_remaining(self.wf, plan, dispatched)
        self.count += 1
        self._last = now
        event = {
            "workflow": plan.workflow,
            "generation": new.generation,
            "drift": (drift(fresh, frozen) if pred is not None else None),
            "fresh_s": fresh,
            "frozen_s": frozen,
            "remaining": list(remaining),
            # stages whose in-edge POLICIES actually changed (predictions
            # refresh on every replan; a flip is a mechanism change)
            "flips": [n for n in remaining
                      if [e.policy for e in new.stages[n].in_edges]
                      != [e.policy for e in plan.stages[n].in_edges]],
            "reason": "node-health" if forced else "drift",
            "t": now,
        }
        self.events.append(event)
        if self.bus is not None:
            self.bus.publish("plan.replanned", event)
        return new


class _RunState:
    """Mutable per-run context the recovery machinery threads through:
    completed results (the lineage a retry re-derives its input from),
    the plan box, the recovery tallies, and the run-wide stage-time
    inflation EWMA that calibrates speculation budgets mid-flight."""

    def __init__(self, wf, input_data: bytes, source_node: str,
                 planbox: dict, lock: threading.Lock):
        self.wf = wf
        self.input_data = input_data
        self.source_node = source_node
        self.planbox = planbox
        self.lock = lock
        self.results: Dict[str, StageResult] = {}
        self.counters = {"retries": 0, "upstream_reruns": 0}
        self.inflation: List[Optional[float]] = [None]   # EWMA box


class WorkflowRunner:
    def __init__(self, cluster, *, use_truffle: bool = True,
                 plan: Optional[ExecutionPlan] = None,
                 policy: Optional[DataPolicy] = None,
                 storage: str = "direct",
                 straggler_factor: float = 0.0, prewarm_roots: bool = False,
                 estimates: Optional[Dict[str, PhaseEstimate]] = None,
                 stream: bool = False, dedup: bool = False,
                 replan: Optional[ReplanPolicy] = None,
                 planner: Optional[Planner] = None,
                 tenant: Optional[str] = None,
                 cas_salt: Optional[bytes] = None):
        """``policy`` (or a precompiled ``plan``) is the native surface.
        The legacy runner-global knobs — ``storage``/``stream``/``dedup``/
        ``straggler_factor`` — are a back-compat shim: they construct the
        equivalent uniform :class:`DataPolicy` and compile through the same
        Planner, so old call sites keep their exact behavior.

        ``replan`` enables mid-flight re-planning between stage waves (see
        module docstring); ``planner`` overrides the planner used for
        compiles AND replans (default: a telemetry-wired
        :class:`~repro.runtime.planner.AdaptivePlanner` when either
        ``replan`` is set or ``compile`` receives edge profiles).

        ``tenant``/``cas_salt`` are the fleet context (set by
        :class:`~repro.runtime.fleet.serving.Fleet`): the tenant tags
        requests and claims seeded digests on the fleet's per-tenant
        ledger; a salt namespaces this run's content digests — the
        sharing layer's isolation switch (salted content can never alias
        to another tenant's bytes)."""
        self.cluster = cluster
        self.tenant = tenant
        self.cas_salt = cas_salt
        self.use_truffle = use_truffle
        self.prewarm_roots = prewarm_roots
        self.estimates = estimates or {}
        if policy is None:
            policy = DataPolicy(strategy=storage, stream=stream, dedup=dedup,
                                speculation=straggler_factor)
        self.default_policy = policy
        self.plan = plan
        self.replan = replan
        self.planner = planner
        # legacy mirrors (kept readable for old call sites; the data plane
        # itself consumes the compiled ExecutionPlan, never these)
        self.storage = policy.strategy
        self.stream = policy.stream
        self.dedup = policy.dedup
        self.straggler_factor = policy.speculation

    def _adaptive_planner(self) -> Planner:
        """The planner replans (and profile-aware compiles) go through —
        lazily an AdaptivePlanner on the live cluster unless one was
        injected."""
        if self.planner is None:
            from repro.runtime.planner import AdaptivePlanner
            self.planner = AdaptivePlanner(self.cluster,
                                           default=self.default_policy)
        return self.planner

    def compile(self, wf: Workflow, profiles=None) -> ExecutionPlan:
        """Compile ``wf`` against this runner's default policy.
        ``profiles`` (``{(src, dst): EdgeProfile}``) enables Eq. 4
        predictions / auto resolution and is kept on the plan for the
        re-planning hook."""
        if self.planner is not None or self.replan is not None or profiles:
            return self._adaptive_planner().compile(wf, profiles=profiles)
        return Planner(default=self.default_policy).compile(wf)

    # ------------------------------------------------------------------ run
    def run(self, wf: Workflow, input_data: bytes,
            source_node: str = None,
            plan: Optional[ExecutionPlan] = None,
            profiles=None) -> WorkflowTrace:
        cluster = self.cluster
        plan = plan or self.plan or self.compile(wf, profiles=profiles)
        if set(plan.stages) != set(wf.stages):
            raise PlanError(f"plan {plan.workflow!r} does not cover workflow "
                            f"{wf.name!r}: plan stages {sorted(plan.stages)} "
                            f"!= workflow stages {sorted(wf.stages)}")
        for st in wf.stages.values():
            cluster.platform.register(st.spec)
        source_node = source_node or cluster.node_list[0].name
        if self.prewarm_roots:
            # the paper's latency metric starts at the *source* function's
            # send; warm the roots so measurement covers the passing path
            for name in wf.roots():
                cluster.platform.invoke(Request(fn=wf.stages[name].spec.name,
                                                payload=b"",
                                                source_node=source_node))
        trace = WorkflowTrace(wf.name, "truffle" if self.use_truffle else "baseline",
                              plan.label())
        trace.t_start = cluster.clock.now()

        controller = None
        if self.replan is not None:
            controller = ReplanController(self._adaptive_planner(),
                                          self.replan, wf,
                                          clock=cluster.clock,
                                          bus=cluster.bus,
                                          health=getattr(cluster, "health",
                                                         None))

        lock = threading.Lock()
        done_cv = threading.Condition(lock)
        errbox: List[BaseException] = []
        # the plan currently in force: replans swap it; a stage reads it
        # exactly once, at ITS dispatch, so in-flight stages keep the plan
        # they started under and later stages see the latest generation
        planbox = {"plan": plan}
        rs = _RunState(wf, input_data, source_node, planbox, lock)
        results = rs.results
        wave = [0]                          # completed-stage counter

        def finish_stage(name: str, sr: StageResult,
                         current: ExecutionPlan) -> None:
            sr.record.replan_count = current.generation
            self._seed_output(current.stages[name], sr)
            self._report_stage(sr, rs)
            with lock:
                wave[0] += 1
                k = wave[0]
            # published BEFORE the completion is recorded: a fault
            # timeline keyed on this wave acts (and returns) before the
            # dispatcher can wake and start the next wave — so between
            # "stage N done" and "stage N+1 dispatched" there is a
            # well-defined point where faults land and replans decide
            cluster.bus.publish("workflow.stage_done", {
                "workflow": wf.name, "stage": name, "wave": k,
                "node": sr.record.node, "t": cluster.clock.now()})
            with done_cv:
                results[name] = sr
                done_cv.notify_all()

        def run_stage(name: str, current: ExecutionPlan, pipes=()):
            # ``current`` is the plan in force when the DISPATCHER started
            # this thread — passed in rather than read here, so a replan
            # landing between Thread.start() and the first statement can
            # never stamp a generation the stage was not dispatched under
            try:
                sp = current.stages[name]
                data, src, hints = self._stage_input(sp, rs)
                sr = self._dispatch(name, wf.stages[name].spec,
                                    sp, data, src, hints, rs, pipes=pipes)
                # pipes the handler never streamed into get the whole
                # output shipped now (the pipe still bought the consumer
                # its early trigger)
                self._settle_pipes(pipes, sr)
                finish_stage(name, sr, current)
            except BaseException as e:  # noqa: BLE001
                for p in pipes:        # wake pipelined consumers NOW; they
                    p.abort(e)         # fall back against the errbox/retry
                e = self._wrap_failure(name, wf.stages[name].spec, e,
                                       wf_name=wf.name)
                with done_cv:
                    errbox.append(e)
                    done_cv.notify_all()

        def wait_pipelined(name: str, pipe, child_pipes,
                           current: ExecutionPlan):
            """Consumer side of a pipelined edge: its invocation is already
            in flight (the pipe's trigger fired at producer dispatch) — only
            the join differs from run_stage. Any failure on the fast path
            falls back to the robust whole-blob dispatch against the
            producer's completed output, composing with the retry layer."""
            sp = current.stages[name]
            try:
                out = pipe.result()
                rec = pipe.record
                rec.predicted_s = sp.predicted_s
                sr = StageResult(name=name, output=out, record=rec)
                self._settle_pipes(child_pipes, sr)
                finish_stage(name, sr, current)
            except BaseException:  # noqa: BLE001 — fast path down, fall back
                dep = sp.deps[0]
                with done_cv:
                    while dep not in results and not errbox:
                        if not done_cv.wait(timeout=300):
                            break
                    ok = dep in results
                if not ok:             # producer failed for good: its error
                    return             # (already in errbox) ends the run
                run_stage(name, current, pipes=child_pipes)

        def open_pipes(producer: str, current: ExecutionPlan):
            """Open a Pipe per pipelined single-dep consumer of ``producer``
            — firing each consumer's lightweight trigger NOW, at producer
            dispatch — and recurse so a whole chain cascades from one
            dispatch (a consumer's own pipes ride its trigger request).
            Consumers claimed here are marked ``started``; a waiter thread
            joins each one. Runs on the dispatcher thread (single-threaded
            ``started`` mutation, same as normal dispatch)."""
            if not self.use_truffle:
                return ()
            pipes = []
            for cname in order:
                cp = current.stages[cname]
                if (cname in started or cp.deps != (producer,)
                        or cp.in_edges[0].policy.pipeline is not True
                        or cp.speculation_budget_s is not None):
                    continue
                child = open_pipes(cname, current)
                prof = current.profiles.get((producer, cname))
                node = cluster.node(rs.source_node)
                pipe = node.truffle.csp.open_pipe(
                    wf.stages[cname].spec.name,
                    policy=cp.in_edges[0].policy,
                    size_hint=(prof.size if prof is not None else 0),
                    pipes=child)
                started.add(cname)
                EXECUTOR.submit(wait_pipelined,
                                args=(cname, pipe, child, current),
                                name=f"pipe-wait-{cname}")
                pipes.append(pipe)
            return tuple(pipes)

        order = plan.order
        started = set()
        checked_at = -1
        while True:
            with done_cv:
                done = len(results)
                failed = bool(errbox)
            if failed or done >= len(order):
                break
            # the re-planning hook runs BETWEEN waves: after each batch of
            # completions, before the stages they unblock dispatch. It (and
            # the dispatch itself) must run OUTSIDE the completion lock:
            # consider() publishes plan.replanned on the bus and reads the
            # telemetry/health locks — a subscriber that blocks on stage
            # completion would deadlock against a dispatcher holding done_cv
            if controller is not None and done > checked_at:
                checked_at = done
                fresh = controller.consider(planbox["plan"], started)
                if fresh is not None:
                    planbox["plan"] = fresh
            for name in order:
                if name in started:
                    continue
                if all(d in results
                       for d in planbox["plan"].stages[name].deps):
                    current = planbox["plan"]
                    started.add(name)
                    # function-to-function direct streaming: fire the
                    # pipelined consumers' triggers AT PRODUCER DISPATCH
                    # (their cold starts overlap its whole execution) and
                    # hand the producer the pipes its put_stream writes to
                    pipes = open_pipes(name, current)
                    EXECUTOR.submit(run_stage, args=(name, current, pipes),
                                    name=f"stage-{name}")
            # plan-aware pre-warming: a stage whose deps are ALL dispatched
            # triggers next wave — the fleet pool provisions its sandboxes
            # now, so the CSP ship lands in an already-provisioning sandbox
            # (runs outside done_cv: provisioning threads publish on the bus)
            pools = getattr(cluster.platform, "pools", None)
            if pools is not None:
                pools.prewarm_next_wave(wf, planbox["plan"], started)
            with done_cv:
                # re-check under the lock: a stage that completed while we
                # were dispatching already notified — don't sleep past it
                if len(results) == done and not errbox:
                    done_cv.wait(timeout=300)
        if errbox:
            raise errbox[0]

        trace.t_end = cluster.clock.now()
        trace.stages = results
        if controller is not None:
            trace.replans = list(controller.events)
        trace.plan_generation = planbox["plan"].generation
        trace.retries = rs.counters["retries"]
        trace.upstream_reruns = rs.counters["upstream_reruns"]
        return trace

    def _seed_output(self, sp: StagePlan, sr: StageResult) -> None:
        """Content-address a stage's output and publish it on the node that
        produced it (plan ``seed_output`` directive: some consumer edge
        dedups). Downstream placement hints then score each dep's bytes
        where they actually live — the multi-input fan-in hint."""
        if not sp.seed_output or not self.use_truffle:
            return
        rec = sr.record
        if (self.cas_salt is None and rec.output_digest is not None
                and rec.output_digest_bytes == len(sr.output)):
            # streamed producers folded the digest chunk-by-chunk during
            # put_stream — no re-hash of the joined blob here
            sr.digest = rec.output_digest
        else:
            sr.digest = self._digest(sr.output)
        node = self.cluster.nodes.get(sr.record.node)
        if node is not None:
            publish_content(node, sr.output, sr.digest)
        # fleet context: claim the seeded bytes on the tenant's CAS ledger
        # (per-tenant accounting + cross-tenant alias detection)
        fleet = getattr(self.cluster, "fleet", None)
        if fleet is not None and self.tenant is not None:
            fleet.claim(self.tenant, sr.digest, len(sr.output))

    # ------------------------------------------------- input (re)derivation
    def _stage_input(self, sp: StagePlan,
                     rs: _RunState) -> Tuple[bytes, str, tuple]:
        results = rs.results
        if not sp.deps:
            return rs.input_data, rs.source_node, ()
        outs = [results[d].output for d in sp.deps]
        src = results[sp.deps[-1]].record.node or rs.source_node
        hints = tuple((results[d].digest, len(results[d].output))
                      for d in sp.hint_deps
                      if results[d].digest is not None)
        # single dep: hand the output through without a join copy
        return (outs[0] if len(outs) == 1 else b"".join(outs)), src, hints

    def _recover_input(self, name: str, sp: StagePlan,
                       rs: _RunState) -> Tuple[bytes, str, tuple]:
        """Re-derive a stage's input for a retry after a node fault. Per
        dep: a dead producer whose output still resolves on a LIVE replica
        (DigestRegistry) costs nothing — the re-ship aliases or relays from
        the replica; only a dep whose last replica died with its node is
        re-executed (recursively, the lineage contract). The re-ship source
        is then steered to a live node holding the most input bytes."""
        cluster = self.cluster
        for d in sp.deps:
            sr = rs.results.get(d)
            if sr is None:
                continue
            prod = cluster.nodes.get(sr.record.node)
            if prod is not None and getattr(prod, "alive", True):
                continue
            holders = []
            if sr.digest is not None:
                holders = [
                    n for n in cluster.digests.nodes_for(sr.digest)
                    if getattr(cluster.nodes.get(n), "alive", True)]
            if not holders:
                self._rerun_upstream(d, rs)
        data, src, hints = self._stage_input(sp, rs)
        src_node = cluster.nodes.get(src)
        if src_node is None or not getattr(src_node, "alive", True):
            src = self._alive_source(hints)
        return data, src, hints

    def _alive_source(self, hints: tuple) -> str:
        """A live node to re-ship from, preferring the one already holding
        the most hinted input bytes (the surviving replica)."""
        cluster = self.cluster
        best, best_bytes = None, -1
        for n in cluster.node_list:
            if not getattr(n, "alive", True):
                continue
            res = sum(cluster.digests.resident_bytes(n.name, d)
                      for d, _ in hints)
            if res > best_bytes:
                best, best_bytes = n.name, res
        if best is None:
            raise NodeCrashError(None, "no live node to re-ship from")
        return best

    def _rerun_upstream(self, name: str, rs: _RunState) -> None:
        """Lineage re-execution: the ONLY path that re-runs a completed
        stage — its output's last replica died with a node. Publishes
        ``stage.rerun`` (NOT ``workflow.stage_done``: re-runs must not
        advance the fault-timeline wave counter)."""
        plan = rs.planbox["plan"]
        sp = plan.stages[name]
        spec = rs.wf.stages[name].spec
        data, src, hints = self._recover_input(name, sp, rs)
        sr = self._dispatch(name, spec, sp, data, src, hints, rs)
        sr.record.replan_count = plan.generation
        self._seed_output(sp, sr)
        with rs.lock:
            rs.results[name] = sr
            rs.counters["upstream_reruns"] += 1
        self.cluster.bus.publish("stage.rerun", {
            "workflow": rs.wf.name, "stage": name, "node": sr.record.node,
            "t": self.cluster.clock.now()})

    # --------------------------------------------------- health reporting
    def _report_stage(self, sr: StageResult, rs: Optional[_RunState]) -> None:
        """Feed the health monitor (per-node inflation EWMA) and the run's
        own calibration box from one completed stage."""
        clock = self.cluster.clock
        measured = clock.elapsed_sim(sr.record.total)
        health = getattr(self.cluster, "health", None)
        if health is not None and sr.record.node:
            health.report_stage(sr.record.node, measured,
                                sr.record.predicted_s)
        ratio = stage_inflation(measured, sr.record.predicted_s)
        if ratio is not None and rs is not None:
            with rs.lock:
                rs.inflation[0] = fold_inflation(rs.inflation[0], ratio)

    def _report_failure(self, exc: BaseException,
                        node: Optional[str]) -> None:
        health = getattr(self.cluster, "health", None)
        if health is None or node is None:
            return
        if isinstance(exc, TransferStallError):
            health.report_stall(node)
        elif isinstance(exc, (NodeCrashError, LinkDownError,
                              BufferOfflineError, TimeoutError, IOError)):
            health.report_failure(node)

    def _wrap_failure(self, name: str, spec: FunctionSpec,
                      e: BaseException,
                      wf_name: str = "") -> BaseException:
        """Every stage error surfaces as a StageExecutionError carrying
        stage/node/attempt/cause (+ the LifecycleRecord when the data plane
        attached one). The retry loop wraps exhausted retries itself; this
        covers the no-retry-policy path."""
        if not isinstance(e, Exception) or isinstance(
                e, (StageExecutionError, PlanError, WorkflowCycleError)):
            return e
        node = getattr(e, "node", None) or self._placed_node(spec.name)
        self._report_failure(e, node)
        self.cluster.bus.publish("stage.failed", {
            "workflow": wf_name, "stage": name, "node": node, "attempt": 1,
            "error": repr(e), "will_retry": False,
            "t": self.cluster.clock.now()})
        return StageExecutionError(name, node=node, attempt=1, cause=e,
                                   record=getattr(e, "record", None))

    def _settle_pipes(self, pipes, sr: StageResult) -> None:
        """Whole-output fallback for pipes the producing handler never
        streamed into (non-``streaming_output`` handler, or the streaming
        attempt failed and a retry produced the output whole): ship the
        completed output through each unused pipe from the node that
        produced it. Used/aborted pipes no-op; a flush failure aborts that
        pipe (its consumer falls back) without failing the producer."""
        if not pipes:
            return
        node = self.cluster.nodes.get(sr.record.node)
        for p in pipes:
            try:
                if node is None:
                    raise NodeCrashError(sr.record.node or None,
                                         "producer node unknown — cannot "
                                         "flush pipe")
                p.flush(node, sr.output)
            except Exception as e:  # noqa: BLE001 — consumer-side fault
                p.abort(e)

    # ------------------------------------------------------- stage dispatch
    def _dispatch(self, name: str, spec: FunctionSpec, sp: StagePlan,
                  data: bytes, source_node: str, input_hints: tuple,
                  rs: Optional[_RunState] = None,
                  pipes=()) -> StageResult:
        """Crash-restart recovery wrapper: without a RetryPolicy this is
        exactly one attempt (pre-retry behavior); with one, a failed or
        timed-out attempt is retried on a DIFFERENT node (``avoid`` steers
        placement off the failed node; the health monitor's penalty keeps
        suspect nodes out anyway), with the input re-derived from surviving
        replicas (``_recover_input``) and linear backoff between attempts."""
        rp = sp.retry if sp.retry is not None else getattr(spec, "retry",
                                                           None)
        if rp is None:
            return self._attempt_stage(name, spec, sp, data, source_node,
                                       input_hints, rs, pipes=pipes)
        clock = self.cluster.clock
        avoid = None
        attempt = 1
        while True:
            try:
                # pipes ride only the FIRST attempt: a failed streaming
                # attempt already aborted them (consumers fell back), and a
                # retry writing into a consumed pipe would corrupt it — the
                # post-dispatch _settle_pipes flush covers a retry that
                # succeeds with pipes still unused
                sr = self._attempt_with_timeout(name, spec, sp, data,
                                                source_node, input_hints,
                                                rs, avoid, rp,
                                                pipes=(pipes if attempt == 1
                                                       else ()))
                sr.attempts = attempt
                sr.record.attempt = attempt
                return sr
            except Exception as e:  # noqa: BLE001 — the retry
                # classification boundary: user handlers raise arbitrary
                # exceptions, so this must stay broad. Nothing is
                # swallowed — every catch publishes stage.failed, and
                # exhaustion re-raises as StageExecutionError with the
                # original as __cause__
                failed_node = (getattr(e, "node", None)
                               or self._placed_node(spec.name))
                self._report_failure(e, failed_node)
                will_retry = attempt < rp.max_attempts
                self.cluster.bus.publish("stage.failed", {
                    "workflow": (rs.wf.name if rs is not None else ""),
                    "stage": name, "node": failed_node, "attempt": attempt,
                    "error": repr(e), "will_retry": will_retry,
                    "t": clock.now()})
                if not will_retry:
                    raise StageExecutionError(
                        name, node=failed_node, attempt=attempt, cause=e,
                        record=getattr(e, "record", None)) from e
                if rs is not None:
                    with rs.lock:
                        rs.counters["retries"] += 1
                clock.sleep(rp.backoff_s * attempt)   # linear backoff
                avoid = failed_node
                attempt += 1
                if rs is not None:
                    data, source_node, input_hints = self._recover_input(
                        name, sp, rs)

    def _attempt_with_timeout(self, name, spec, sp, data, source_node,
                              input_hints, rs, avoid, rp,
                              pipes=()) -> StageResult:
        """One attempt under the policy's per-attempt sim-second deadline
        (a wedged data path must not eat the whole run before the retry)."""
        if rp.timeout_s is None:
            return self._attempt_stage(name, spec, sp, data, source_node,
                                       input_hints, rs, avoid, pipes=pipes)
        pool = ThreadPoolExecutor(max_workers=1)
        try:
            fut = pool.submit(self._attempt_stage, name, spec, sp, data,
                              source_node, input_hints, rs, avoid,
                              pipes=pipes)
            try:
                return fut.result(
                    timeout=rp.timeout_s * self.cluster.clock.scale)
            except FuturesTimeout:
                raise TimeoutError(
                    f"stage {name!r} attempt exceeded its "
                    f"{rp.timeout_s}s budget") from None
        finally:
            pool.shutdown(wait=False, cancel_futures=True)

    def _attempt_stage(self, name: str, spec: FunctionSpec, sp: StagePlan,
                       data: bytes, source_node: str, input_hints: tuple,
                       rs: Optional[_RunState] = None,
                       avoid: Optional[str] = None,
                       pipes=()) -> StageResult:
        def attempt(backup_avoid: Optional[str] = None) -> StageResult:
            return self._invoke_once(name, spec, sp, data, source_node,
                                     input_hints,
                                     avoid=(backup_avoid if backup_avoid
                                            is not None else avoid),
                                     pipes=pipes)

        est = self.estimates.get(name)
        budget_sim = None
        if sp.transport.speculation and est is not None:
            budget_sim = sp.transport.speculation * (
                truffle_time(est) if self.use_truffle else baseline_time(est))
        elif sp.speculation_budget_s is not None:
            # no caller estimate: the plan's own Eq. 4 prediction carries
            # the budget (speculation="auto" needs no user numbers)
            budget_sim = sp.speculation_budget_s
        if budget_sim and pipes:
            # pipelining and speculation compose badly: a backup attempt
            # writing the same pipes would double-stream into the
            # consumers' entries. Pipelining wins — the chain overlap it
            # buys is the larger, surer gain
            budget_sim = None
        if budget_sim:
            # mid-run calibration: scale the plan's budget by the measured
            # stage-time inflation so far (clamped — see calibrated_budget).
            # The record keeps the PLAN's budget in speculation_budget_s and
            # the armed value in calibrated_budget_s.
            armed_sim = budget_sim
            if rs is not None and rs.inflation[0] is not None:
                cal = calibrated_budget(budget_sim, rs.inflation[0])
                if cal is not None:
                    armed_sim = cal
            budget = armed_sim * self.cluster.clock.scale  # sim -> wall s
            pool = ThreadPoolExecutor(max_workers=2)
            try:
                first = pool.submit(attempt)
                done, _ = wait([first], timeout=budget)
                if done:
                    sr = first.result()
                    sr.record.speculation_budget_s = budget_sim
                    if armed_sim != budget_sim:
                        sr.record.calibrated_budget_s = armed_sim
                    return sr
                # failure independence: steer the backup OFF the node the
                # straggler was placed on (its placement event is on the bus
                # even though the attempt itself is still stuck)
                backup = pool.submit(attempt, self._placed_node(spec.name))
                wait([first, backup], return_when=FIRST_COMPLETED)
                # deterministic winner: the original attempt wins whenever it
                # has finished (results are idempotent, and preferring it
                # keeps the speculated flag truthful when both are done or
                # when first completed between the two waits)
                winner = first if first.done() else backup
                sr = winner.result()
                sr.speculated = winner is backup
                sr.record.speculation_budget_s = budget_sim
                if armed_sim != budget_sim:
                    sr.record.calibrated_budget_s = armed_sim
                return sr
            finally:
                # without this every straggler stage leaked a live executor
                # (two worker threads parked forever); cancel_futures stops a
                # not-yet-started duplicate from running after the winner
                pool.shutdown(wait=False, cancel_futures=True)
        return attempt()

    def _placed_node(self, fn: str) -> Optional[str]:
        """Node the straggling attempt landed on, from the scheduling event
        stream (the attempt is stuck — its record isn't back yet)."""
        for ev in reversed(self.cluster.bus.history("scheduling.placed")):
            if ev["function"] == fn:
                return ev["node"]
        return None

    def _digest(self, data: bytes) -> str:
        """Content digest, namespaced by the fleet's tenant salt when one
        is set (``share_cas=False`` isolation: salted digests can never
        collide with — so never alias to — another tenant's content)."""
        if self.cas_salt is not None:
            return content_digest(self.cas_salt + data)
        return content_digest(data)

    def _known_digest(self, pol: DataPolicy, data: bytes,
                      input_hints: tuple) -> Optional[str]:
        """The stage input's digest when an upstream seed already computed
        it (single-dep stage: input IS the dep's output) — re-hashing tens
        of MB per hop is pure waste on the dispatch path."""
        if not pol.dedup:
            return None
        if len(input_hints) == 1 and input_hints[0][1] == len(data):
            return input_hints[0][0]
        return self._digest(data)

    def _invoke_once(self, name: str, spec: FunctionSpec, sp: StagePlan,
                     data: bytes, source_node: str, input_hints: tuple,
                     avoid: Optional[str] = None, pipes=()) -> StageResult:
        cluster = self.cluster
        fn = spec.name
        pol = sp.transport
        put_s = 0.0
        meta = {}
        if pipes:
            # downstream pipelined edges: the invocation's put_stream
            # writes into these while the function executes
            meta["pipes"] = list(pipes)
        # baseline paths have no policy plumbing — the hint directives ride
        # the request meta and PlacementHint.from_request picks them up
        if self.tenant is not None:
            meta["tenant"] = self.tenant    # fleet context (observability)
        if avoid is not None:
            meta["avoid_node"] = avoid
        if pol.prefetch and self.use_truffle:
            # a prefetch relay lands in Truffle buffers — meaningless (and
            # wasted fabric) for the baseline's payload-carrying path
            meta["prefetch"] = True
        if pol.locality_weight is not None:
            meta["locality_weight"] = pol.locality_weight

        if pol.strategy in ("kvs", "s3"):
            # producer writes to the storage service first (both modes — the
            # storage flavor defines where the data lives; paper Fig. 9b/9c)
            key = f"{fn}/{uuid.uuid4().hex[:8]}"
            t0 = cluster.clock.now()
            cluster.storage[pol.strategy].put(key, data)
            put_s = cluster.clock.now() - t0
            # dedup: content-address the stage input so downstream placement
            # (and the target buffer's alias check) can see where it lives
            digest = self._known_digest(pol, data, input_hints)
            req = Request(fn=fn, content_ref=ContentRef(pol.strategy, key,
                                                        len(data),
                                                        digest=digest,
                                                        inputs=(input_hints
                                                                or None)),
                          source_node=source_node, meta=meta)
            if self.use_truffle:
                truffle = cluster.node(source_node).truffle
                out, rec = truffle.handle_request(req, policy=pol,
                                                  avoid=avoid)     # SDP
            else:
                out, rec = cluster.platform.invoke(req)      # fetch after start
        else:  # direct
            if self.use_truffle:
                truffle = cluster.node(source_node).truffle
                out, rec = truffle.pass_data(
                    fn, data, policy=pol, input_hints=input_hints or None,
                    avoid=avoid,
                    digest=self._known_digest(pol, data, input_hints),
                    pipes=pipes or None)  # CSP
            else:
                req = Request(fn=fn, payload=data, source_node=source_node,
                              meta=meta)
                out, rec = cluster.platform.invoke(req)      # body held at ingress

        # profiled plans carry a compile-time Eq. 4 prediction per stage;
        # stamping it here makes predicted-vs-measured error assertable
        rec.predicted_s = sp.predicted_s
        return StageResult(name=name, output=out, record=rec, put_s=put_s)
