"""Fleet facade: admission gate + warm pools + CAS sharing on one cluster.

``Fleet(cluster)`` wires the three fleet layers together and exposes the
multi-tenant serving surface::

    fleet = Fleet(cluster, fleet_max=8, ordering="predicted")
    fleet.register_tenant("acme", TenantQuota(max_concurrent=2))
    run = fleet.submit("acme", wf, input_data, profiles=profiles)
    trace = run.result()          # blocks: queued -> admitted -> ran

``submit`` compiles the workflow's :class:`ExecutionPlan` FIRST — its
``predicted_total`` (the paper's Eq. 5 plan-total) is what the gate
ranks arrivals by — then queues a ticket and drives the run on its own
thread once admitted. Pool policies for the workflow's functions are
sized from the tenant's ``warm_slots`` quota; tenant identity and the
CAS salt (isolation switch) thread into the
:class:`~repro.runtime.workflow.WorkflowRunner`.

``fleet.stats()`` is the per-tenant observability snapshot: queue
depth, shed count, warm-hit rate, shared-CAS bytes saved/charged.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from typing import Dict, Optional

from repro.runtime.executor import EXECUTOR
from repro.runtime.fleet.admission import FleetGate, TenantQuota, Ticket
from repro.runtime.fleet.pools import PoolPolicy, WarmPools
from repro.runtime.fleet.sharing import CasSharing
from repro.runtime.workflow import WorkflowRunner


class FleetRun:
    """Handle for one submitted workflow instance. ``result()`` blocks
    through the whole queued -> admitted -> ran lifecycle. Sojourn
    bounds (``submitted_s`` / ``admitted_s`` / ``completed_s``, fleet
    sim-seconds) are what the multitenant benchmark's latency
    percentiles are computed from."""

    def __init__(self, ticket: Ticket):
        self.ticket = ticket
        self.submitted_s: float = 0.0
        self.admitted_s: Optional[float] = None
        self.completed_s: Optional[float] = None
        self._fut: Future = Future()

    @property
    def state(self) -> str:
        return self.ticket.state

    def done(self) -> bool:
        return self._fut.done()

    def result(self, timeout: Optional[float] = None):
        """The run's :class:`WorkflowTrace` (or raises what the run
        raised)."""
        return self._fut.result(timeout)


class Fleet:
    def __init__(self, cluster, *, fleet_max: int = 8,
                 ordering: str = "predicted", pools: bool = True,
                 pool_policy: Optional[PoolPolicy] = None,
                 share_cas: bool = True, aging_weight: float = 1.0,
                 default_quota: Optional[TenantQuota] = None):
        self.cluster = cluster
        self._t0 = cluster.clock.now()
        self.gate = FleetGate(fleet_max=fleet_max, ordering=ordering,
                              aging_weight=aging_weight, now_fn=self.now,
                              bus=cluster.bus, default_quota=default_quota)
        self.pools = (WarmPools(cluster, default=pool_policy)
                      if pools else None)
        self.sharing = CasSharing(cluster, share_default=share_cas)
        self._lock = threading.Lock()
        self._tenant_runs: Dict[str, Dict[str, int]] = {}
        cluster.fleet = self          # runner discovers the claim hook here

    def now(self) -> float:
        """Fleet-relative sim-seconds (the gate's aging clock)."""
        clock = self.cluster.clock
        return clock.elapsed_sim(clock.now() - self._t0)

    # ------------------------------------------------------------ tenants
    def register_tenant(self, tenant: str,
                        quota: Optional[TenantQuota] = None) -> TenantQuota:
        quota = quota or TenantQuota()
        self.gate.register(tenant, quota)
        self.sharing.register(tenant, quota)
        return quota

    def claim(self, tenant: str, digest: str, nbytes: int) -> bool:
        """Runner hook: tenant content seeded into the CAS."""
        return self.sharing.claim(tenant, digest, nbytes)

    # ------------------------------------------------------------- submit
    def submit(self, tenant: str, wf, input_data: bytes, *,
               source_node: Optional[str] = None, profiles=None,
               use_truffle: bool = True, policy=None,
               replan=None) -> FleetRun:
        """Queue one workflow instance for ``tenant``. Compiles the plan
        now (admission ranks on its ``predicted_total``), runs it on its
        own thread once the gate admits. Raises
        :class:`~repro.runtime.fleet.admission.AdmissionRejected` when the
        tenant's queue quota sheds the arrival."""
        runner = WorkflowRunner(self.cluster, use_truffle=use_truffle,
                                policy=policy, replan=replan, tenant=tenant,
                                cas_salt=self.sharing.salt_for(tenant))
        plan = runner.compile(wf, profiles=profiles)
        if self.pools is not None:
            quota = self.gate.quota(tenant)
            base = self.pools.default
            cap = (min(base.max, quota.warm_slots) if quota.warm_slots
                   else base.max)
            for st in wf.stages.values():
                self.cluster.platform.register(st.spec)
                self.pools.configure(st.spec, PoolPolicy(
                    min=base.min, warm=min(base.warm, cap), max=max(cap, 1),
                    idle_ttl_s=base.idle_ttl_s))
        ticket = self.gate.submit(tenant,
                                  predicted_s=plan.predicted_total,
                                  tag=wf.name)
        run = FleetRun(ticket)
        run.submitted_s = self.now()
        EXECUTOR.submit(self._drive,
                        args=(run, runner, wf, plan, input_data,
                              source_node),
                        name=f"fleet-{tenant}-{wf.name}")
        return run

    def _drive(self, run: FleetRun, runner: WorkflowRunner, wf, plan,
               input_data: bytes, source_node: Optional[str]) -> None:
        ticket = run.ticket
        try:
            if not ticket.admitted_evt.wait(timeout=600.0):
                raise TimeoutError(
                    f"tenant {ticket.tenant!r}: {wf.name} never admitted")
            run.admitted_s = self.now()
            trace = runner.run(wf, input_data, source_node=source_node,
                               plan=plan)
            run.completed_s = self.now()
            self._tally(ticket.tenant, trace)
            run._fut.set_result(trace)
        except BaseException as e:  # noqa: BLE001 — the run thread's
            # boundary: whatever the workflow raised is re-raised to the
            # submitter via the future, nothing is swallowed
            run._fut.set_exception(e)
        finally:
            self.gate.complete(ticket)
            # quota pressure runs BETWEEN runs (never on the data path):
            # evict the tenant's oldest private digests down to quota
            self.sharing.pressure(ticket.tenant)

    def _tally(self, tenant: str, trace) -> None:
        recs = [sr.record for sr in trace.stages.values()]
        warm = sum(1 for r in recs if r.warm_hit)
        pre = sum(1 for r in recs if r.prewarmed)
        # a pooled pre-warm hit sets BOTH flags — count it once
        absorbed = sum(1 for r in recs if r.warm_hit or r.prewarmed)
        with self._lock:
            t = self._tenant_runs.setdefault(
                tenant, {"runs": 0, "stages": 0, "warm_hits": 0,
                         "prewarmed": 0, "absorbed": 0})
            t["runs"] += 1
            t["stages"] += len(recs)
            t["warm_hits"] += warm
            t["prewarmed"] += pre
            t["absorbed"] += absorbed

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Per-tenant fleet snapshot: admission counters + queue depth,
        warm-hit rate over executed stages, shared-CAS bytes
        saved/charged — plus platform pool counters."""
        gate = self.gate.stats()
        ledger = self.sharing.ledger.snapshot()
        with self._lock:
            runs = {t: dict(v) for t, v in self._tenant_runs.items()}
        tenants = {}
        for t in set(gate) | set(runs) | set(ledger):
            g = gate.get(t, {})
            r = runs.get(t, {})
            led = ledger.get(t, {})
            stages = r.get("stages", 0)
            absorbed = r.get("absorbed", 0)
            tenants[t] = {
                "queue_depth": g.get("queue_depth", 0),
                "running": g.get("running", 0),
                "submitted": g.get("submitted", 0),
                "admitted": g.get("admitted", 0),
                "shed": g.get("shed", 0),
                "completed": g.get("completed", 0),
                "stages": stages,
                "warm_hit_rate": (absorbed / stages) if stages else 0.0,
                "prewarmed_stages": r.get("prewarmed", 0),
                "cas_charged_bytes": led.get("charged", 0.0),
                "cas_saved_bytes": led.get("saved", 0),
            }
        out = {"tenants": tenants,
               "platform": dict(self.cluster.platform.stats),
               "sharing": self.sharing.stats_snapshot()}
        if self.pools is not None:
            out["pools"] = self.pools.stats_snapshot()
        return out
