"""Warm container pools with plan-aware pre-warming.

The paper hides input transfer inside the cold-start window (SDP/CSP);
the pool generalizes that: provision the NEXT wave's sandboxes while the
current wave executes, so by the time a trigger fires its CSP ship lands
in an already-provisioning (or already-warm) sandbox. Two mechanisms:

* **pool checkin/checkout** — extends ``Platform._checkout_warm`` /
  ``_checkin`` (never bypasses them): ``PoolPolicy`` sizes each
  function's pool (``min`` floor, ``warm`` target, ``max`` cap, idle
  TTL), pushed down via ``Platform.set_pool_limit``.
* **adoption** — a checkout miss while a pre-warm provision is in
  flight hands that instance to the live request
  (``Platform._adopt_provisioning`` <- ``WarmPools.adopt``): the
  request pays only the residual ν+η, not a fresh cold start.

Locking: ``WarmPools._lock`` is a leaf guarding the policy table and the
in-flight provision lists. Provisioning itself (clock sleeps) runs on
dedicated ``prewarm-*`` threads, never under the lock; bus publishes
happen outside it.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.runtime.executor import EXECUTOR
from repro.runtime.function import (FunctionInstance, FunctionSpec,
                                    LifecycleRecord)


@dataclass(frozen=True)
class PoolPolicy:
    """Sizing for one function's warm pool (tensorlake-style
    min/warm/max): ``min`` instances survive TTL expiry, ``warm`` is the
    pre-warm target per next-wave stage, ``max`` caps the pool (and
    checkins past it discard)."""
    min: int = 0
    warm: int = 1
    max: int = 8
    idle_ttl_s: Optional[float] = None

    def __post_init__(self):
        if not (0 <= self.min <= self.max):
            raise ValueError("need 0 <= min <= max")
        if self.warm < 0 or self.warm > self.max:
            raise ValueError("need 0 <= warm <= max")


class _Prewarm:
    """One in-flight pre-warm provision. ``ready`` fires when provisioning
    finished (instance WARM) or failed (``error`` set). ``adopted`` means
    a live request took it — it must not also land in the pool."""

    __slots__ = ("fn", "instance", "ready", "error", "adopted")

    def __init__(self, fn: str):
        self.fn = fn
        self.instance: Optional[FunctionInstance] = None
        self.ready = threading.Event()
        self.error: Optional[BaseException] = None
        self.adopted = False


class WarmPools:
    def __init__(self, cluster, default: Optional[PoolPolicy] = None):
        self.cluster = cluster
        self.default = default or PoolPolicy()
        self._lock = threading.Lock()
        self._policies: Dict[str, PoolPolicy] = {}
        self._provisioning: Dict[str, List[_Prewarm]] = {}
        self.stats = {"prewarms_started": 0, "prewarms_pooled": 0,
                      "adoptions": 0}
        cluster.platform.pools = self     # the platform's adoption hook

    # ------------------------------------------------------------- config
    def configure(self, spec: FunctionSpec,
                  policy: Optional[PoolPolicy] = None) -> None:
        """Apply (or default) a policy for ``spec`` — pushes the cap/TTL
        down to the platform pool and provisions the ``min`` floor."""
        pol = policy or self.default
        with self._lock:
            self._policies[spec.name] = pol
        self.cluster.platform.set_pool_limit(spec.name, pol.max,
                                             pol.idle_ttl_s, pol.min)
        if pol.min > 0:
            self.prewarm(spec, pol.min)

    def policy(self, fn: str) -> PoolPolicy:
        with self._lock:
            return self._policies.get(fn, self.default)

    # ----------------------------------------------------------- pre-warm
    def prewarm(self, spec: FunctionSpec, target: int) -> int:
        """Provision toward ``target`` instances for ``spec``
        asynchronously, counting what is already warm or in flight (so
        repeated calls converge instead of stacking). Returns how many
        provisions were started."""
        platform = self.cluster.platform
        pol = self.policy(spec.name)
        warm = len(platform.warm_instances(spec.name))
        started: List[_Prewarm] = []
        with self._lock:
            inflight = self._provisioning.setdefault(spec.name, [])
            need = min(target, pol.max) - warm - len(inflight)
            for _ in range(max(need, 0)):
                pw = _Prewarm(spec.name)
                inflight.append(pw)
                started.append(pw)
            self.stats["prewarms_started"] += len(started)
        for pw in started:
            EXECUTOR.submit(self._provision_one, args=(spec, pw),
                            name=f"prewarm-{spec.name}")
        return len(started)

    def prewarm_next_wave(self, wf, plan, started) -> int:
        """Plan-aware pre-warming (the runner's between-waves hook): a
        stage whose deps are ALL dispatched will trigger as soon as they
        complete — provision its sandboxes NOW, placed by the same
        locality/health scoring a real dispatch would use."""
        total = 0
        for name in plan.order:
            if name in started:
                continue
            deps = plan.stages[name].deps
            if not deps or not all(d in started for d in deps):
                continue
            spec = wf.stages[name].spec
            target = self.policy(spec.name).warm
            if target > 0:
                total += self.prewarm(spec, target)
        return total

    def adopt(self, fn: str) -> Optional[_Prewarm]:
        """Hand an in-flight provision to a live request (the platform's
        checkout-miss path). Exactly-once: an adopted handle never also
        lands in the pool. None when nothing is provisioning for ``fn``."""
        with self._lock:
            inflight = self._provisioning.get(fn)
            if not inflight:
                return None
            pw = inflight.pop(0)
            pw.adopted = True
            self.stats["adoptions"] += 1
            return pw

    def _provision_one(self, spec: FunctionSpec, pw: _Prewarm) -> None:
        cluster = self.cluster
        try:
            node = cluster.scheduler.pick_node(spec)
            inst = FunctionInstance(spec, node, cluster)
            inst.prewarmed = True
            rec = LifecycleRecord(fn=spec.name)
            rec.t_request = cluster.clock.now()
            inst.provision(rec)          # ν + η on this thread's time
            pw.instance = inst
        except BaseException as e:  # noqa: BLE001 — surfaced via pw.error:
            # the adopter (or nobody) inspects it; a dead node mid-provision
            # must not kill the pool
            pw.error = e
        pw.ready.set()
        pooled = False
        with self._lock:
            inflight = self._provisioning.get(pw.fn)
            if inflight is not None and pw in inflight:
                inflight.remove(pw)
                pooled = pw.error is None          # unadopted and healthy
            if pooled:
                self.stats["prewarms_pooled"] += 1
        if pooled:
            cluster.platform.checkin_prewarmed(pw.fn, pw.instance)
            cluster.bus.publish("fleet.prewarmed", {
                "function": pw.fn, "node": pw.instance.node.name,
                "t": cluster.clock.now()})

    # -------------------------------------------------------------- stats
    def stats_snapshot(self) -> dict:
        with self._lock:
            snap = dict(self.stats)
            snap["provisioning"] = {fn: len(v)
                                    for fn, v in self._provisioning.items()
                                    if v}
            return snap
