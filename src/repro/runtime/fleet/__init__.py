"""Fleet-scale multi-tenant serving.

Turns the single-run runtime into a serving fleet (ROADMAP: "Fleet-scale
multi-tenant serving"): an admission gate ordering arrivals by the
compiled plan's predicted total (the paper's Eq. 5 plan orderings,
applied to the queue), warm container pools with plan-aware pre-warming
(the SDP/CSP cold-start window absorbed entirely by the pool), and
cross-tenant CAS sharing with per-tenant accounting, quotas, and an
isolation switch. See each submodule's docstring for its locking
discipline — every fleet lock is a leaf; nothing publishes or sleeps
under one.
"""
from repro.runtime.fleet.admission import (AdmissionRejected, FleetGate,
                                           TenantQuota, Ticket)
from repro.runtime.fleet.pools import PoolPolicy, WarmPools
from repro.runtime.fleet.serving import Fleet, FleetRun
from repro.runtime.fleet.sharing import CasSharing, TenantLedger

__all__ = ["AdmissionRejected", "CasSharing", "Fleet", "FleetGate",
           "FleetRun", "PoolPolicy", "TenantLedger", "TenantQuota",
           "Ticket", "WarmPools"]
