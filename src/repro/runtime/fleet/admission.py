"""Fleet admission gate: per-tenant quotas, arrival queueing, and
predicted-total ordering.

The paper's Eq. 5 defines plan-total time orderings over candidate
execution plans; at fleet scale the same quantity —
``ExecutionPlan.predicted_total`` — orders ARRIVALS: among queued
workflow instances, shortest-predicted-first minimizes mean sojourn
(SJF), weighted per tenant so one tenant's flood of short jobs cannot
monopolize the admitted slots, and aged so a long job's rank improves
the longer it waits (no starvation: waited time grows without bound,
every queued ticket's rank eventually dominates).

Rank (lower admits first)::

    rank = predicted_s * (running[tenant] + 1) / weight  -  aging * waited_s

``ordering="fifo"`` disables the policy term and admits in arrival
order — the benchmark baseline.

Locking: ``FleetGate._lock`` is a leaf. Bus publishes
(``fleet.queued`` / ``fleet.admitted`` / ``fleet.shed``) and
``Ticket.admitted_evt.set()`` happen strictly OUTSIDE the lock, so a
bus subscriber or an awakened submitter can re-enter the gate freely.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional


class AdmissionRejected(RuntimeError):
    """The fleet gate shed a submission instead of queueing it: the
    tenant's ``max_queued`` quota is already full. Carries
    ``tenant`` / ``reason`` / ``depth`` / ``limit`` so callers can
    implement backpressure (retry later, divert, or surface upstream)."""

    def __init__(self, tenant: str, reason: str, depth: int = 0,
                 limit: int = 0):
        self.tenant = tenant
        self.reason = reason
        self.depth = depth
        self.limit = limit
        super().__init__(f"tenant {tenant!r} shed ({reason}): "
                         f"queue depth {depth} >= limit {limit}")


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant resource envelope the gate (and the sharing layer)
    enforce. ``weight`` scales fairness: a weight-2 tenant's jobs rank as
    if the tenant ran half as much. ``cas_bytes`` caps the tenant's
    charged share of resident CAS bytes (None = uncapped);
    ``share_cas=False`` salts the tenant's digests into a private
    namespace — full isolation, no cross-tenant aliasing either way."""
    max_concurrent: int = 4
    max_queued: int = 64
    cas_bytes: Optional[int] = None
    warm_slots: int = 8
    weight: float = 1.0
    share_cas: bool = True

    def __post_init__(self):
        if self.max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        if self.max_queued < 0:
            raise ValueError("max_queued must be >= 0")
        if self.weight <= 0:
            raise ValueError("weight must be > 0")
        if self.warm_slots < 0:
            raise ValueError("warm_slots must be >= 0")


class Ticket:
    """One submitted workflow instance's admission lifecycle:
    queued -> admitted -> done (or shed at submit). The submitter's run
    thread blocks on ``admitted_evt``; the gate sets it (outside its
    lock) when the instance wins a slot."""

    __slots__ = ("tenant", "predicted_s", "tag", "seq", "enqueued_at",
                 "admitted_at", "state", "admitted_evt")

    def __init__(self, tenant: str, predicted_s: float, tag: str, seq: int,
                 enqueued_at: float):
        self.tenant = tenant
        self.predicted_s = predicted_s
        self.tag = tag
        self.seq = seq                   # arrival order (FIFO tiebreak)
        self.enqueued_at = enqueued_at
        self.admitted_at: Optional[float] = None
        self.state = "queued"
        self.admitted_evt = threading.Event()


class FleetGate:
    #: predicted total assumed for a submission with no compiled plan
    DEFAULT_PREDICTED_S = 10.0

    def __init__(self, *, fleet_max: int = 8, ordering: str = "predicted",
                 aging_weight: float = 1.0,
                 now_fn: Optional[Callable[[], float]] = None,
                 bus=None, default_quota: Optional[TenantQuota] = None):
        if ordering not in ("predicted", "fifo"):
            raise ValueError(f"unknown ordering {ordering!r} "
                             "(want 'predicted' or 'fifo')")
        if fleet_max < 1:
            raise ValueError("fleet_max must be >= 1")
        self.fleet_max = fleet_max
        self.ordering = ordering
        self.aging_weight = aging_weight
        self._now = now_fn if now_fn is not None else self._zero
        self._bus = bus
        self.default_quota = default_quota or TenantQuota()
        self._lock = threading.Lock()
        self._quotas: Dict[str, TenantQuota] = {}
        self._queue: List[Ticket] = []
        self._running: Dict[str, int] = {}
        self._total_running = 0
        self._seq = 0
        self._stats: Dict[str, Dict[str, int]] = {}

    @staticmethod
    def _zero() -> float:
        return 0.0

    # ------------------------------------------------------------- wiring
    def register(self, tenant: str, quota: TenantQuota) -> None:
        with self._lock:
            self._quotas[tenant] = quota

    def quota(self, tenant: str) -> TenantQuota:
        with self._lock:
            return self._quotas.get(tenant, self.default_quota)

    # ---------------------------------------------------------- lifecycle
    def submit(self, tenant: str, predicted_s: Optional[float] = None,
               tag: str = "") -> Ticket:
        """Queue one workflow instance; raises :class:`AdmissionRejected`
        when the tenant's queue quota is full. The returned ticket's
        ``admitted_evt`` fires when the instance may run."""
        now = self._now()
        p = predicted_s if predicted_s is not None else self.DEFAULT_PREDICTED_S
        shed = None
        admitted: List[Ticket] = []
        with self._lock:
            q = self._quotas.get(tenant, self.default_quota)
            depth = sum(1 for t in self._queue if t.tenant == tenant)
            st = self._stats.setdefault(
                tenant, {"submitted": 0, "admitted": 0, "shed": 0,
                         "completed": 0})
            st["submitted"] += 1
            self._seq += 1
            ticket = Ticket(tenant, p, tag, self._seq, now)
            self._queue.append(ticket)
            admitted = self._pump_locked(now)
            # shed AFTER the pump: max_queued caps WAITING instances — an
            # arrival that admits immediately never counts against it
            if ticket.state == "queued" and depth >= q.max_queued:
                self._queue.remove(ticket)
                ticket.state = "shed"
                st["shed"] += 1
                shed = (depth, q.max_queued)
        if shed is not None:
            if self._bus is not None:
                self._bus.publish("fleet.shed", {
                    "tenant": tenant, "tag": tag, "depth": shed[0],
                    "limit": shed[1], "t": now})
            raise AdmissionRejected(tenant, "queue-full", depth=shed[0],
                                    limit=shed[1])
        self._deliver(admitted)
        if ticket.state == "queued" and self._bus is not None:
            self._bus.publish("fleet.queued", {
                "tenant": tenant, "tag": tag, "predicted_s": p, "t": now})
        return ticket

    def complete(self, ticket: Ticket) -> None:
        """A run finished (or failed): release its slot and pump the queue.
        Idempotent per ticket."""
        now = self._now()
        with self._lock:
            if ticket.state != "admitted":
                return
            ticket.state = "done"
            self._running[ticket.tenant] = max(
                self._running.get(ticket.tenant, 1) - 1, 0)
            self._total_running = max(self._total_running - 1, 0)
            self._stats.setdefault(
                ticket.tenant, {"submitted": 0, "admitted": 0, "shed": 0,
                                "completed": 0})["completed"] += 1
            admitted = self._pump_locked(now)
        self._deliver(admitted)

    def pump(self) -> None:
        """Re-evaluate the queue (aging has advanced even with no
        completion — callers with a real clock may tick this)."""
        with self._lock:
            admitted = self._pump_locked(self._now())
        self._deliver(admitted)

    # ----------------------------------------------------------- ordering
    def _rank_locked(self, t: Ticket, now: float) -> tuple:
        if self.ordering == "fifo":
            return (t.seq,)
        q = self._quotas.get(t.tenant, self.default_quota)
        running = self._running.get(t.tenant, 0)
        rank = (t.predicted_s * (running + 1) / q.weight
                - self.aging_weight * max(now - t.enqueued_at, 0.0))
        return (rank, t.seq)

    def _pump_locked(self, now: float) -> List[Ticket]:
        """Admit while fleet capacity and per-tenant quotas allow, picking
        the best-ranked eligible ticket each step (running counts change
        per admission, so the rank is re-evaluated every iteration)."""
        admitted: List[Ticket] = []
        while self._total_running < self.fleet_max:
            eligible = [
                t for t in self._queue
                if self._running.get(t.tenant, 0)
                < self._quotas.get(t.tenant, self.default_quota).max_concurrent]
            if not eligible:
                break
            best = min(eligible, key=lambda t: self._rank_locked(t, now))
            self._queue.remove(best)
            best.state = "admitted"
            best.admitted_at = now
            self._running[best.tenant] = self._running.get(best.tenant, 0) + 1
            self._total_running += 1
            self._stats.setdefault(
                best.tenant, {"submitted": 0, "admitted": 0, "shed": 0,
                              "completed": 0})["admitted"] += 1
            admitted.append(best)
        return admitted

    def _deliver(self, admitted: List[Ticket]) -> None:
        """Wake admitted submitters and mirror onto the bus — outside the
        gate lock (subscribers and awakened threads may re-enter)."""
        for t in admitted:
            t.admitted_evt.set()
            if self._bus is not None:
                self._bus.publish("fleet.admitted", {
                    "tenant": t.tenant, "tag": t.tag,
                    "predicted_s": t.predicted_s,
                    "waited_s": max((t.admitted_at or 0.0) - t.enqueued_at,
                                    0.0),
                    "t": t.admitted_at})

    # -------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Dict[str, int]]:
        """Per-tenant snapshot: submitted/admitted/shed/completed counters
        plus current ``running`` and ``queue_depth``."""
        with self._lock:
            tenants = (set(self._stats) | set(self._running)
                       | {t.tenant for t in self._queue})
            out = {}
            for tenant in tenants:
                st = dict(self._stats.get(tenant, {}))
                st["running"] = self._running.get(tenant, 0)
                st["queue_depth"] = sum(
                    1 for t in self._queue if t.tenant == tenant)
                out[tenant] = st
            return out

    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)

    def running(self) -> int:
        with self._lock:
            return self._total_running
