"""Cross-workflow / cross-tenant CAS sharing with per-tenant accounting.

The data plane is already content-addressed (Buffer dedup + the
cluster-wide DigestRegistry), so two tenants uploading the SAME bytes
alias to one resident copy per node for free — what is missing at fleet
scale is WHO pays for those bytes and what happens at a tenant's quota.
This module adds both, without touching the data path:

* :class:`TenantLedger` — hangs off ``DigestRegistry.add_ledger``:
  tracks per-digest replica counts from residency events and per-tenant
  claims from the runner's ``_seed_output``. A tenant's ``charged``
  bytes are its *share* of the physical bytes:
  ``size x replicas / claimants`` per claimed digest — summed over all
  tenants this equals the physically resident bytes (conservation).
  ``saved`` counts bytes a claim aliased instead of re-shipping.
* :class:`CasSharing` — the policy layer: per-tenant ``cas_bytes``
  quotas drive eviction pressure (oldest tenant-PRIVATE digests are
  dropped from every holder node until the charge fits — shared digests
  are never evicted on one tenant's account), and the isolation switch:
  ``share_cas=False`` gives the tenant a digest *salt*, so its content
  hashes into a private namespace and can neither alias to nor be
  aliased by other tenants' bytes.

Locking: ``TenantLedger._lock`` and ``CasSharing._lock`` are leaves.
Eviction victims are computed under the ledger lock, but the buffer
drops (which re-enter the registry -> ledger via the residency chain)
run with NO fleet lock held.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set

from repro.runtime.fleet.admission import TenantQuota


class TenantLedger:
    """Per-tenant byte accounting over the shared digest index."""

    def __init__(self):
        self._lock = threading.Lock()
        self._claims: Dict[str, Set[str]] = {}    # digest -> {tenant}
        self._sizes: Dict[str, int] = {}          # digest -> logical bytes
        self._replicas: Dict[str, int] = {}       # digest -> resident nodes
        self._saved: Dict[str, int] = {}          # tenant -> aliased bytes
        self._order: Dict[str, List[str]] = {}    # tenant -> claim order

    # ------------------------------------------------- registry callback
    def on_residency(self, event: str, node: str, digest: str,
                     size: int) -> None:
        """``DigestRegistry`` ledger callback (invoked outside the
        registry lock): keeps the physical replica count per digest."""
        with self._lock:
            if event == "added":
                self._replicas[digest] = self._replicas.get(digest, 0) + 1
                self._sizes.setdefault(digest, size)
            elif event == "removed":
                n = self._replicas.get(digest, 0) - 1
                if n <= 0:
                    self._replicas.pop(digest, None)
                else:
                    self._replicas[digest] = n

    # ------------------------------------------------------------ claims
    def claim(self, tenant: str, digest: str, size: int) -> bool:
        """Record that ``tenant``'s workflow produced/needs ``digest``.
        Returns True when the bytes were ALREADY resident on account of
        another tenant — the cross-tenant alias the fleet's shared-CAS
        saving counts."""
        with self._lock:
            owners = self._claims.setdefault(digest, set())
            shared = bool(self._replicas.get(digest)) and bool(
                owners - {tenant})
            if tenant not in owners:
                owners.add(tenant)
                self._order.setdefault(tenant, []).append(digest)
            if size > self._sizes.get(digest, 0):
                self._sizes[digest] = size
            if shared:
                self._saved[tenant] = self._saved.get(tenant, 0) + size
            return shared

    def release(self, tenant: str, digest: str) -> None:
        with self._lock:
            owners = self._claims.get(digest)
            if owners is not None:
                owners.discard(tenant)
                if not owners:
                    self._claims.pop(digest, None)
            order = self._order.get(tenant)
            if order is not None and digest in order:
                order.remove(digest)

    # ------------------------------------------------------------ queries
    def charged(self, tenant: str) -> float:
        """Tenant's share of the physical resident bytes of its claimed
        digests: ``size x replicas / claimants`` per digest. Summing this
        over every tenant yields exactly :meth:`physical_bytes` —
        conservation, asserted by the benchmark."""
        with self._lock:
            total = 0.0
            for digest in self._order.get(tenant, ()):
                owners = self._claims.get(digest)
                reps = self._replicas.get(digest, 0)
                if owners and tenant in owners and reps:
                    total += self._sizes.get(digest, 0) * reps / len(owners)
            return total

    def saved(self, tenant: str) -> int:
        with self._lock:
            return self._saved.get(tenant, 0)

    def physical_bytes(self) -> int:
        """Resident bytes across all CLAIMED digests, each node copy
        counted once (the quantity tenant charges partition)."""
        with self._lock:
            return sum(self._sizes.get(d, 0) * reps
                       for d, reps in self._replicas.items()
                       if self._claims.get(d))

    def private_digests(self, tenant: str) -> List[str]:
        """Eviction candidates for quota pressure: resident digests
        claimed ONLY by this tenant, oldest claim first. Digests other
        tenants also claim are never victims of one tenant's quota."""
        with self._lock:
            return [d for d in self._order.get(tenant, ())
                    if self._claims.get(d) == {tenant}
                    and self._replicas.get(d)]

    def snapshot(self) -> Dict[str, dict]:
        with self._lock:
            tenants = set(self._order) | set(self._saved)
            out = {}
            for t in tenants:
                charged = 0.0
                for digest in self._order.get(t, ()):
                    owners = self._claims.get(digest)
                    reps = self._replicas.get(digest, 0)
                    if owners and t in owners and reps:
                        charged += (self._sizes.get(digest, 0) * reps
                                    / len(owners))
                out[t] = {"charged": charged,
                          "saved": self._saved.get(t, 0),
                          "claims": len(self._order.get(t, ()))}
            return out


class CasSharing:
    def __init__(self, cluster, *, share_default: bool = True):
        self.cluster = cluster
        self.share_default = share_default
        self.ledger = TenantLedger()
        self._lock = threading.Lock()
        self._quotas: Dict[str, Optional[int]] = {}
        self._salts: Dict[str, Optional[bytes]] = {}
        self.stats = {"pressure_evictions": 0, "shared_claims": 0}
        cluster.digests.add_ledger(self.ledger.on_residency)

    # ------------------------------------------------------------- wiring
    def register(self, tenant: str, quota: TenantQuota) -> None:
        isolated = not (quota.share_cas and self.share_default)
        with self._lock:
            self._quotas[tenant] = quota.cas_bytes
            # salting the digest is the WHOLE isolation mechanism: the
            # content hashes into a tenant-private namespace, so neither
            # the buffer alias check nor the registry can ever match it
            # against another tenant's bytes
            self._salts[tenant] = (f"cas-ns:{tenant}:".encode()
                                   if isolated else None)

    def salt_for(self, tenant: Optional[str]) -> Optional[bytes]:
        if tenant is None:
            return None
        with self._lock:
            return self._salts.get(tenant)

    # ------------------------------------------------------------- policy
    def claim(self, tenant: str, digest: str, size: int) -> bool:
        """Runner hook: a stage of ``tenant``'s workflow seeded
        ``digest``. Returns whether the claim aliased cross-tenant
        resident bytes."""
        shared = self.ledger.claim(tenant, digest, size)
        if shared:
            with self._lock:
                self.stats["shared_claims"] += 1
        return shared

    def pressure(self, tenant: str) -> int:
        """Quota-driven eviction: while the tenant's charged bytes exceed
        its ``cas_bytes`` quota, drop its oldest tenant-private digests
        from every holder node (the buffer drop flows back through
        residency -> registry -> ledger, so the charge falls as replicas
        disappear). Called between runs, never on the data path — an
        active run's inputs are not yanked out from under a waiting
        consumer. Returns digests evicted."""
        with self._lock:
            quota = self._quotas.get(tenant)
        if quota is None:
            return 0
        evicted = 0
        for digest in self.ledger.private_digests(tenant):
            if self.ledger.charged(tenant) <= quota:
                break
            self._drop_digest(tenant, digest)
            evicted += 1
        return evicted

    def _drop_digest(self, tenant: str, digest: str) -> None:
        """Evict every node replica of a tenant-private digest. Runs with
        no fleet lock held: each ``buffer.drop`` re-enters the registry
        and the ledger through the residency chain."""
        for node_name in list(self.cluster.digests.nodes_for(digest)):
            node = self.cluster.nodes.get(node_name)
            if node is None:
                continue
            key = node.buffer.find_digest(digest)
            if key is not None:
                node.buffer.drop(key)
        self.ledger.release(tenant, digest)
        with self._lock:
            self.stats["pressure_evictions"] += 1

    # -------------------------------------------------------------- stats
    def stats_snapshot(self) -> dict:
        with self._lock:
            snap = dict(self.stats)
        snap["physical_bytes"] = self.ledger.physical_bytes()
        snap["tenants"] = self.ledger.snapshot()
        return snap
