"""Cluster: nodes (edge/cloud tiers), network fabric, storage services,
event bus, scheduler, platform, and one Truffle instance per node
(the DaemonSet deployment model of the paper §V).

The cluster also owns the two cluster-wide data-locality structures:
``digests`` (a :class:`~repro.runtime.registry.DigestRegistry` fed by every
node buffer's residency callback — what the scheduler scores placements
against) and ``relays`` (a :class:`~repro.core.transfer.RelayTable` that
collapses concurrent fan-out passes of one content to one node into a
single relay stream). ``locality_weight`` tunes how many load units a fully
resident input is worth to the scheduler (0 = pure least-loaded)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.buffer import Buffer
from repro.runtime.clock import Clock, DEFAULT_CLOCK
from repro.runtime.events import EventBus
from repro.runtime.netsim import LinkTelemetry, NetworkFabric
from repro.runtime.registry import DigestRegistry
from repro.storage.base import StorageService, make_kvs, make_object_store


@dataclass
class Node:
    name: str
    tier: str = "edge"            # edge | cloud
    buffer: Buffer = None
    truffle: object = None        # TruffleInstance, attached by Cluster

    def __post_init__(self):
        if self.buffer is None:
            self.buffer = Buffer(name=f"{self.name}.buffer")


class Cluster:
    def __init__(self, node_specs: Optional[List[tuple]] = None, *,
                 clock: Optional[Clock] = None, with_truffle: bool = True,
                 scheduling_s: float = 0.15,
                 locality_weight: Optional[float] = None):
        from repro.core.transfer import Prefetcher, RelayTable
        from repro.core.truffle import TruffleInstance
        from repro.runtime.platform import Platform
        from repro.runtime.scheduler import Scheduler

        self.clock = clock or DEFAULT_CLOCK
        node_specs = node_specs or [("edge-0", "edge"), ("edge-1", "edge"),
                                    ("cloud-0", "cloud")]
        self.nodes: Dict[str, Node] = {
            name: Node(name, tier) for name, tier in node_specs}
        # passive link telemetry: channels report every grant; the adaptive
        # planner reads EWMA estimates instead of the configured constants
        self.telemetry = LinkTelemetry()
        self.network = NetworkFabric(clock=self.clock,
                                     telemetry=self.telemetry)
        self.reseed_telemetry()
        self.bus = EventBus()
        self.storage: Dict[str, StorageService] = {
            "kvs": make_kvs(self.clock),
            "s3": make_object_store(self.clock),
        }
        # cluster-wide digest residency (locality-aware placement) + the
        # in-flight relay table (fan-out passes share one relay stream)
        self.digests = DigestRegistry(bus=self.bus)
        self.relays = RelayTable()
        # registry-driven prefetch: the scheduler kicks it when an edge's
        # DataPolicy.prefetch is set and placement lands off the data
        self.prefetcher = Prefetcher(self)
        for node in self.nodes.values():
            node.buffer.on_residency = self.digests.listener(node.name)
            # residency-aware eviction: under capacity pressure a buffer
            # sheds replicas that still resolve elsewhere before touching
            # the cluster's LAST copy of a digest (ROADMAP follow-up)
            node.buffer.replica_oracle = self._replica_elsewhere(node.name)
        sched_kw = {} if locality_weight is None else {
            "locality_weight": locality_weight}
        self.scheduler = Scheduler(self, scheduling_s=scheduling_s,
                                   **sched_kw)
        self.platform = Platform(self)
        if with_truffle:
            for node in self.nodes.values():
                node.truffle = TruffleInstance(node, self)

    def reseed_telemetry(self) -> None:
        """Seed per-tier link priors from the fabric's configured links so
        the planner has estimates before any traffic. Call again after
        mutating ``network.tier_links`` (benchmarks that reshape the
        continuum): already-materialized channels are re-calibrated too,
        so the new configuration actually applies — not just the prior.

        Both steps are tear-proof against concurrent traffic: the priors
        are replaced in one telemetry lock hold (a racing snapshot or
        compile sees the old OR the new continuum, never half of each) and
        each channel is reconfigured under its own grant lock (a racing
        grant never prices bytes at a bandwidth/latency mix that was never
        configured)."""
        self.telemetry.reseed(self.network.tier_links)
        for ch in self.network._channels.values():
            if ch.tier_key is not None:      # loopbacks keep their own rate
                bw, lat = self.network.tier_links[ch.tier_key]
                ch.reconfigure(bandwidth=bw, latency=lat)

    def _replica_elsewhere(self, node_name: str):
        """Oracle for one node's Buffer: does ``digest`` still resolve on
        some OTHER node? (Registry reads only — safe under the buffer lock:
        the registry never calls back into a buffer.)"""
        def elsewhere(digest: str) -> bool:
            return any(n != node_name
                       for n in self.digests.nodes_for(digest))
        return elsewhere

    def tier_of(self, node_name: str) -> str:
        return self.nodes[node_name].tier

    @property
    def node_list(self) -> List[Node]:
        return list(self.nodes.values())

    def node(self, name: str) -> Node:
        return self.nodes[name]

    def transfer(self, src: Node, dst: Node, payload: bytes,
                 wire_ratio: float = 1.0,
                 pace_bps: Optional[float] = None) -> float:
        """Move bytes between nodes over the fabric (blocking, whole-blob).
        ``wire_ratio < 1`` grants only the compressed wire bytes;
        ``pace_bps`` bounds the producer's rate (codec-bound transfers)."""
        return self.network.channel(src, dst).transfer(
            payload, wire_ratio=wire_ratio, pace_bps=pace_bps)

    def stream(self, src: Node, dst: Node, payload: bytes,
               chunk_bytes: Optional[int] = None, wire_ratio: float = 1.0,
               pace_bps: Optional[float] = None):
        """Chunk-granularity fabric transfer: yields chunks as they arrive
        (per-chunk bandwidth grants — see netsim.Channel.stream)."""
        from repro.runtime.netsim import DEFAULT_CHUNK_BYTES
        return self.network.channel(src, dst).stream(
            payload, chunk_bytes or DEFAULT_CHUNK_BYTES,
            wire_ratio=wire_ratio, pace_bps=pace_bps)
