"""Cluster: nodes (edge/cloud tiers), network fabric, storage services,
event bus, scheduler, platform, and one Truffle instance per node
(the DaemonSet deployment model of the paper §V).

The cluster also owns the two cluster-wide data-locality structures:
``digests`` (a :class:`~repro.runtime.registry.DigestRegistry` fed by every
node buffer's residency callback — what the scheduler scores placements
against) and ``relays`` (a :class:`~repro.core.transfer.RelayTable` that
collapses concurrent fan-out passes of one content to one node into a
single relay stream). ``locality_weight`` tunes how many load units a fully
resident input is worth to the scheduler (0 = pure least-loaded)."""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.buffer import Buffer
from repro.core.errors import DATA_PLANE_FAULTS
from repro.runtime.clock import Clock, DEFAULT_CLOCK
from repro.runtime.events import EventBus
from repro.runtime.executor import EXECUTOR
from repro.runtime.health import DEGRADED, DEAD, NodeHealthMonitor
from repro.runtime.netsim import LinkTelemetry, NetworkFabric
from repro.runtime.registry import DigestRegistry
from repro.storage.base import StorageService, make_kvs, make_object_store


@dataclass
class Node:
    name: str
    tier: str = "edge"            # edge | cloud
    buffer: Buffer = None
    truffle: object = None        # TruffleInstance, attached by Cluster
    alive: bool = True            # False: crashed (kill_node/restart_node)
    cpu_factor: float = 1.0       # >1: sick CPU, stretches ν/η/γ sleeps

    def __post_init__(self):
        if self.buffer is None:
            self.buffer = Buffer(name=f"{self.name}.buffer")


class Cluster:
    def __init__(self, node_specs: Optional[List[tuple]] = None, *,
                 clock: Optional[Clock] = None, with_truffle: bool = True,
                 scheduling_s: float = 0.15,
                 locality_weight: Optional[float] = None):
        from repro.core.transfer import Prefetcher, RelayTable
        from repro.core.truffle import TruffleInstance
        from repro.runtime.platform import Platform
        from repro.runtime.scheduler import Scheduler

        self.clock = clock or DEFAULT_CLOCK
        node_specs = node_specs or [("edge-0", "edge"), ("edge-1", "edge"),
                                    ("cloud-0", "cloud")]
        self.nodes: Dict[str, Node] = {
            name: Node(name, tier) for name, tier in node_specs}
        # passive link telemetry: channels report every grant; the adaptive
        # planner reads EWMA estimates instead of the configured constants
        self.telemetry = LinkTelemetry()
        self.network = NetworkFabric(clock=self.clock,
                                     telemetry=self.telemetry)
        self.reseed_telemetry()
        self.bus = EventBus()
        self.storage: Dict[str, StorageService] = {
            "kvs": make_kvs(self.clock),
            "s3": make_object_store(self.clock),
        }
        # cluster-wide digest residency (locality-aware placement) + the
        # in-flight relay table (fan-out passes share one relay stream)
        self.digests = DigestRegistry(bus=self.bus)
        self.relays = RelayTable()
        # registry-driven prefetch: the scheduler kicks it when an edge's
        # DataPolicy.prefetch is set and placement lands off the data
        self.prefetcher = Prefetcher(self)
        # node health scoring (the node-level twin of LinkTelemetry): fed
        # from the same bus + the runner's per-stage inflation reports; the
        # scheduler penalizes suspect/degraded nodes, the ReplanController
        # watches its generation, and degradation triggers CAS evacuation
        self.health = NodeHealthMonitor(self)
        self.health.on_degraded = self._on_node_degraded
        for node in self.nodes.values():
            node.buffer.on_residency = self.digests.listener(node.name)
            # residency-aware eviction: under capacity pressure a buffer
            # sheds replicas that still resolve elsewhere before touching
            # the cluster's LAST copy of a digest (ROADMAP follow-up)
            node.buffer.replica_oracle = self._replica_elsewhere(node.name)
        sched_kw = {} if locality_weight is None else {
            "locality_weight": locality_weight}
        self.scheduler = Scheduler(self, scheduling_s=scheduling_s,
                                   **sched_kw)
        self.platform = Platform(self)
        if with_truffle:
            for node in self.nodes.values():
                node.truffle = TruffleInstance(node, self)

    def reseed_telemetry(self) -> None:
        """Seed per-tier link priors from the fabric's configured links so
        the planner has estimates before any traffic. Call again after
        mutating ``network.tier_links`` (benchmarks that reshape the
        continuum): already-materialized channels are re-calibrated too,
        so the new configuration actually applies — not just the prior.

        Both steps are tear-proof against concurrent traffic: the priors
        are replaced in one telemetry lock hold (a racing snapshot or
        compile sees the old OR the new continuum, never half of each) and
        each channel is reconfigured under its own grant lock (a racing
        grant never prices bytes at a bandwidth/latency mix that was never
        configured)."""
        self.telemetry.reseed(self.network.tier_links)
        for ch in self.network._channels.values():
            if ch.tier_key is not None:      # loopbacks keep their own rate
                bw, lat = self.network.tier_links[ch.tier_key]
                ch.reconfigure(bandwidth=bw, latency=lat)

    def _replica_elsewhere(self, node_name: str):
        """Oracle for one node's Buffer: does ``digest`` still resolve on
        some OTHER node? (Registry reads only — safe under the buffer lock:
        the registry never calls back into a buffer.)"""
        def elsewhere(digest: str) -> bool:
            return any(n != node_name
                       for n in self.digests.nodes_for(digest))
        return elsewhere

    # ------------------------------------------------- node fault lifecycle
    def kill_node(self, name: str) -> None:
        """Crash ``name``: CAS wiped, links down, warm pool purged, health
        forced DEAD. Everything a real node loss loses is lost — recovery
        must come from surviving replicas (or upstream re-execution)."""
        node = self.nodes[name]
        if not node.alive:
            return
        node.alive = False
        self.network.set_node_down(name, True)
        self.platform.purge_node(name)
        # wipe the buffer: residency withdrawals flow to the registry; the
        # explicit drop_node is the safety net for entries whose residency
        # callback never fired (e.g. incomplete streams)
        node.buffer.clear(offline=True)
        self.digests.drop_node(name)
        self.health.mark_dead(name)
        self.bus.publish("node.crashed", {"node": name,
                                          "t": self.clock.now()})

    def restart_node(self, name: str) -> None:
        """Bring a crashed node back EMPTY (cold warm-pool, empty CAS) —
        the crash-restart model: state died with the node."""
        node = self.nodes[name]
        node.alive = True
        node.cpu_factor = 1.0
        node.buffer.revive()
        self.network.set_node_down(name, False)
        self.health.mark_alive(name)
        self.bus.publish("node.restarted", {"node": name,
                                            "t": self.clock.now()})

    def drain_node(self, name: str) -> list:
        """Administrative drain: evacuate sole-replica CAS content
        synchronously, then mark degraded (scheduler steers away,
        ReplanController revises undispatched placements). Evacuating
        first keeps the degraded-hook's async evacuation a no-op sweep —
        everything sole is already replicated. Returns evacuated digests."""
        moved = self.evacuate_node(name)
        self.health.mark_degraded(name)
        return moved

    def evacuate_node(self, name: str, *, sole_only: bool = True) -> list:
        """Copy this node's CAS content to a healthy peer before the node
        is lost. ``sole_only`` (default) moves only LAST replicas — content
        that still resolves elsewhere needs no rescue."""
        from repro.core.transfer import ship_payload
        from repro.runtime.netsim import DEFAULT_CHUNK_BYTES
        node = self.nodes[name]
        moved = []
        for digest, size in self.digests.holdings(name).items():
            if sole_only and any(n != name
                                 for n in self.digests.nodes_for(digest)):
                continue
            key = node.buffer.find_digest(digest)
            if key is None:
                continue
            data = node.buffer.get(key)
            if data is None:
                continue
            target = self._evacuation_target(name, len(data))
            if target is None:
                continue
            try:
                # through the relay machinery, not a raw ship: alias-first
                # if the target already holds the content, and the relay
                # lead makes the in-flight evacuation visible so a racing
                # CSP/SDP pass of the same digest follows instead of
                # double-shipping
                ship_payload(self, node, target, f"cas/{digest}", data,
                             stream=True, digest=digest,
                             chunk_bytes=DEFAULT_CHUNK_BYTES)
                moved.append(digest)
            except DATA_PLANE_FAULTS:
                continue                    # node may die mid-evacuation;
                #                             anything else is a bug and
                #                             propagates
        self.bus.publish("node.evacuated", {"node": name,
                                            "digests": len(moved),
                                            "t": self.clock.now()})
        return moved

    def _evacuation_target(self, avoid: str, size: int) -> Optional[Node]:
        """Least-loaded live node that isn't degraded/dead (falls back to
        any live node when the whole cluster is sick)."""
        live = [n for n in self.nodes.values()
                if n.alive and n.name != avoid]
        good = [n for n in live
                if self.health.state(n.name) not in (DEGRADED, DEAD)]
        pool = good or live
        if not pool:
            return None
        return min(pool, key=lambda n: self.scheduler.load_of(n.name))

    def _on_node_degraded(self, name: str) -> None:
        """Health-triggered evacuation runs off-thread: the monitor fires
        this from inside a bus publish / stage report — evacuating inline
        would ship bytes (and take buffer locks) under the caller."""
        EXECUTOR.submit(self.evacuate_node, args=(name,),
                        name=f"evac-{name}")

    def tier_of(self, node_name: str) -> str:
        return self.nodes[node_name].tier

    @property
    def node_list(self) -> List[Node]:
        return list(self.nodes.values())

    def node(self, name: str) -> Node:
        return self.nodes[name]

    def transfer(self, src: Node, dst: Node, payload: bytes,
                 wire_ratio: float = 1.0,
                 pace_bps: Optional[float] = None) -> float:
        """Move bytes between nodes over the fabric (blocking, whole-blob).
        ``wire_ratio < 1`` grants only the compressed wire bytes;
        ``pace_bps`` bounds the producer's rate (codec-bound transfers)."""
        return self.network.channel(src, dst).transfer(
            payload, wire_ratio=wire_ratio, pace_bps=pace_bps)

    def stream(self, src: Node, dst: Node, payload: bytes,
               chunk_bytes: Optional[int] = None, wire_ratio: float = 1.0,
               pace_bps: Optional[float] = None):
        """Chunk-granularity fabric transfer: yields chunks as they arrive
        (per-chunk bandwidth grants — see netsim.Channel.stream)."""
        from repro.runtime.netsim import DEFAULT_CHUNK_BYTES
        return self.network.channel(src, dst).stream(
            payload, chunk_bytes or DEFAULT_CHUNK_BYTES,
            wire_ratio=wire_ratio, pace_bps=pace_bps)
