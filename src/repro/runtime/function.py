"""Serverless function model: spec, instance lifecycle, timing records.

Lifecycle (paper Fig. 2): scheduling (α) → infrastructure setup (ν) →
runtime startup (η) → [input fetch (δ)] → execution (γ). The whole point of
Truffle is reordering δ to overlap ν+η; every instance keeps a
``LifecycleRecord`` so benchmarks can reconstruct each phase exactly.

Streaming input (chunked data plane): a handler (``FunctionSpec.streaming``)
drives its own input consumption via ``Invocation.get_input_stream`` —
chunks are yielded at arrival, so per-chunk compute overlaps the remaining
transfer. The record then carries the *measured* blocked-wait time
(``io_blocked_s``), which is what ``io_visible`` reports: I/O the function
actually stalled on, after cold start AND execution overlap."""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

from repro.core.buffer import IncrementalDigest
from repro.core.errors import NodeCrashError


@dataclass
class ContentRef:
    storage_type: str            # kvs | s3 | direct | truffle
    key: str
    size: int = 0
    digest: Optional[str] = None  # content address (enables dedup downstream)
    #: per-dep content hints for a fan-in input: ((digest, size), ...) — one
    #: entry per upstream edge, so the locality-aware scheduler can score
    #: placement on the SUM of resident inputs instead of a joined-blob hash
    inputs: Optional[Tuple[Tuple[str, int], ...]] = None


@dataclass
class Request:
    fn: str
    payload: Optional[bytes] = None          # direct-passing body
    content_ref: Optional[ContentRef] = None
    source_node: Optional[str] = None        # originating node name
    meta: Dict[str, Any] = field(default_factory=dict)


@dataclass
class FunctionSpec:
    name: str
    handler: Callable[[bytes, "Invocation"], bytes]
    provision_s: float = 1.4      # ν: infrastructure setup (sandbox, image)
    startup_s: float = 0.2        # η: language runtime startup...
    startup_fn: Optional[Callable[[], None]] = None  # ...or REAL work (XLA compile)
    exec_s: float = 0.05          # γ floor (simulated compute)
    input_storage: str = "direct"
    affinity: Optional[str] = None
    extra_cold_start_s: float = 0.0  # Fig. 11 sweep: added cold-start delay
    streaming: bool = False       # handler consumes input via get_input_stream
    streaming_output: bool = False  # handler emits output via put_stream, so
    #                                 downstream pipelined edges get chunks
    #                                 mid-execution (planner pipeline="auto"
    #                                 requires this on the producer)
    retry: Optional[object] = None  # RetryPolicy: crash-restart recovery
    #                                 (edge DataPolicy.retry overrides)


@dataclass
class LifecycleRecord:
    fn: str
    node: str = ""
    mode: str = "baseline"        # baseline | truffle
    cold: bool = True
    t_request: float = 0.0
    t_placed: float = 0.0         # end of scheduling (host known!)
    t_prov_end: float = 0.0       # ν done
    t_startup_end: float = 0.0    # η done — Fn start
    t_transfer_start: float = 0.0
    t_transfer_end: float = 0.0   # input data landed (wherever it lands)
    t_first_chunk: float = 0.0    # first input chunk consumed (streaming)
    t_input_ready: float = 0.0    # function actually holds its input
    t_exec_start: float = 0.0
    t_exec_end: float = 0.0
    streamed: bool = False        # input arrived chunk-pipelined
    pipelined: bool = False       # input flowed from the producer MID-execution
    #                               (function-to-function direct streaming:
    #                               trigger fired at producer dispatch)
    dedup_hit: bool = False       # input served from the content-addressed cache
    locality_hit: bool = False    # placed on a node already holding the input
    relay_shared: bool = False    # transfer piggybacked on an in-flight relay
    transfer_stalled: bool = False  # data-path thread outlived its join budget
    prefetched: bool = False      # scheduler kicked the relay at placement
    warm_hit: bool = False        # served by a pooled warm instance (no ν+η)
    prewarmed: bool = False       # instance was pool-provisioned ahead of the
    #                               trigger (plan-aware pre-warm / adoption)
    compress_ratio: Optional[float] = None  # wire bytes / payload bytes
    io_blocked_s: Optional[float] = None  # measured blocked wait (streaming)
    predicted_s: Optional[float] = None  # Eq. 4 compile-time stage time (sim
    #                                      seconds; stamped from the plan IN
    #                                      FORCE at dispatch — post-replan
    #                                      stages carry the replanned plan's
    #                                      prediction — compare to
    #                                      clock.elapsed_sim(record.total))
    replan_count: int = 0         # plan generation at dispatch (0 = original
    #                               compile; N = dispatched after N replans)
    speculation_budget_s: Optional[float] = None  # straggler budget (sim s)
    #                               this dispatch armed, None = no speculation
    output_digest: Optional[str] = None  # content address folded chunk-by-
    #                               chunk during put_stream (unsalted) — the
    #                               runner's output seeding reuses it instead
    #                               of re-hashing the joined blob
    output_digest_bytes: int = 0  # bytes the fold covered (staleness guard)
    calibrated_budget_s: Optional[float] = None  # budget actually armed after
    #                               mid-run inflation calibration (sim s);
    #                               None = no calibration applied
    attempt: int = 1              # which retry attempt produced this record

    # --- derived phases (seconds) ---
    @property
    def scheduling(self) -> float:
        return max(self.t_placed - self.t_request, 0.0)

    @property
    def cold_start(self) -> float:
        return max(self.t_startup_end - self.t_placed, 0.0) if self.cold else 0.0

    @property
    def io_visible(self) -> float:
        """I/O time the function actually waits for (not hidden in cold start
        — nor, when streaming, in execution)."""
        if self.io_blocked_s is not None:
            return self.io_blocked_s
        return max(self.t_input_ready - max(self.t_startup_end, self.t_request), 0.0)

    @property
    def execution(self) -> float:
        return max(self.t_exec_end - self.t_exec_start, 0.0)

    @property
    def total(self) -> float:
        return max(self.t_exec_end - self.t_request, 0.0)

    def phases(self) -> Dict[str, float]:
        return {"scheduling": self.scheduling, "cold_start": self.cold_start,
                "io": self.io_visible, "execution": self.execution,
                "total": self.total}


class Invocation:
    """Handed to the handler: where to get input / put output."""

    def __init__(self, request: Request, node, cluster, record: LifecycleRecord):
        self.request = request
        self.node = node
        self.cluster = cluster
        self.record = record

    def get_input(self, timeout: float = 120.0) -> bytes:
        """Resolve the input: truffle buffer, storage fetch, or inline body.
        Called by the handler at execution time — in baseline mode this is
        where the (visible) I/O happens."""
        ref = self.request.content_ref
        if ref is None:
            self.record.t_input_ready = self.cluster.clock.now()
            return self.request.payload or b""
        if ref.storage_type == "truffle":
            data = self.node.buffer.wait_for(ref.key, timeout=timeout)
            if data is None:
                raise TimeoutError(f"{self.request.fn}: input {ref.key} never arrived")
            self.record.t_input_ready = self.cluster.clock.now()
            return data
        svc = self.cluster.storage[ref.storage_type]
        data, _ = svc.get(ref.key)
        self.record.t_input_ready = self.cluster.clock.now()
        return data

    def get_input_stream(self, timeout: float = 120.0) -> Iterator[bytes]:
        """Chunk-granular input: yields chunks at arrival so the handler can
        compute while the rest of the transfer is still in flight. Blocked
        time (waiting on a chunk that hasn't landed) is measured into
        ``record.io_blocked_s`` — the streaming path's visible I/O."""
        ref = self.request.content_ref
        if ref is None:
            it = iter((self.request.payload or b"",))
        elif ref.storage_type == "truffle":
            it = iter(self.node.buffer.open_reader(ref.key, timeout=timeout))
        else:
            it = self.cluster.storage[ref.storage_type].get_stream(ref.key)
        return self._timed(it)

    def put_stream(self, chunks) -> bytes:
        """Producer chunk egress (function-to-function direct streaming):
        emit output chunk-by-chunk so any pipelined downstream edges (the
        ``pipes`` the runner attached to this invocation) carry each chunk
        to the consumer's in-flight buffer entry WHILE this function is
        still executing. Writes block when a consumer's in-flight bytes hit
        its high-water mark (backpressure propagates to the producer); a
        mid-stream failure aborts every pipe (consumers wake with the
        error) and re-raises. Returns the joined bytes — the handler's
        return value, so the whole-blob paths (retries, non-pipelined
        consumers, output seeding) see the same output as ever."""
        pipes = tuple((self.request.meta or {}).get("pipes") or ())
        for p in pipes:
            p.bind_source(self.node)
        parts = []
        hasher = IncrementalDigest()
        try:
            for chunk in chunks:
                chunk = bytes(chunk)
                parts.append(chunk)
                hasher.update(chunk)
                for p in pipes:
                    p.write(chunk)
            for p in pipes:
                p.close()
        except BaseException as exc:
            for p in pipes:
                p.abort(exc)
            raise
        # content address folded per chunk above: downstream output seeding
        # reuses it instead of re-hashing the joined blob
        self.record.output_digest = hasher.hexdigest()
        self.record.output_digest_bytes = hasher.n_bytes
        return b"".join(parts)

    def _timed(self, it: Iterator[bytes]) -> Iterator[bytes]:
        clock = self.cluster.clock
        rec = self.record
        rec.streamed = True
        rec.io_blocked_s = 0.0
        first = True
        while True:
            t0 = clock.now()
            try:
                chunk = next(it)
            except StopIteration:
                break
            rec.io_blocked_s += clock.now() - t0
            if first:
                rec.t_first_chunk = clock.now()
                first = False
            yield chunk
        rec.t_input_ready = clock.now()


class FunctionInstance:
    COLD, PROVISIONING, WARM, EXECUTING = range(4)

    def __init__(self, spec: FunctionSpec, node, cluster):
        self.spec = spec
        self.node = node
        self.cluster = cluster
        self.state = self.COLD
        self._lock = threading.Lock()
        #: pool bookkeeping (stamped by the platform / fleet pools, read
        #: without the instance lock: plain floats/bools, monotonic writers)
        self.prewarmed = False        # provisioned ahead of any trigger
        self.idle_since = 0.0         # clock.now() at last pool checkin

    def _require_alive(self) -> None:
        if not getattr(self.node, "alive", True):
            raise NodeCrashError(self.node.name,
                                 f"{self.spec.name}: node "
                                 f"{self.node.name} crashed")

    def _cpu(self) -> float:
        """Sick-CPU inflation: >1 stretches every modeled sleep (ν, η, γ) —
        the stage-time inflation the health monitor EWMAs."""
        return max(getattr(self.node, "cpu_factor", 1.0), 0.0)

    def provision(self, record: LifecycleRecord) -> None:
        """ν + η (+ any Fig.11 extra delay). Real startup_fn runs unscaled."""
        clock = self.cluster.clock
        with self._lock:
            self._require_alive()
            self.state = self.PROVISIONING
        clock.sleep((self.spec.provision_s + self.spec.extra_cold_start_s)
                    * self._cpu())
        record.t_prov_end = clock.now()
        if self.spec.startup_fn is not None:
            self.spec.startup_fn()          # real work: e.g. jit compile
        clock.sleep(self.spec.startup_s * self._cpu())
        record.t_startup_end = clock.now()
        with self._lock:
            self._require_alive()           # node died during cold start
            self.state = self.WARM

    def invoke(self, request: Request, record: LifecycleRecord) -> bytes:
        # The lock covers ONLY the state transitions. An instance is
        # exclusively owned while invoking (cold instances are fresh; warm
        # ones are popped from the pool under the platform lock), so the
        # execution itself — which blocks on the input wait and the modeled
        # compute sleep — must not pin the instance lock: a concurrent
        # observer (health probe, purge sweep) reading state would otherwise
        # stall behind an entire function execution.
        clock = self.cluster.clock
        with self._lock:
            self._require_alive()
            self.state = self.EXECUTING
        inv = Invocation(request, self.node, self.cluster, record)
        if self.spec.streaming:
            # handler drives chunk consumption (and models its own
            # per-chunk compute) via inv.get_input_stream()
            record.t_exec_start = clock.now()
            out = self.spec.handler(b"", inv)
        else:
            data = inv.get_input()
            record.t_exec_start = clock.now()
            clock.sleep(self.spec.exec_s * self._cpu())
            out = self.spec.handler(data, inv)
        record.t_exec_end = clock.now()
        with self._lock:
            self._require_alive()           # node died mid-execution
            self.state = self.WARM
        return out
