"""Shared worker pool — the runtime's thread substrate.

The pre-refactor runtime spawned a fresh OS thread for every invocation,
CSP transfer path, pipe placement wait, SDP data path, and prefetch relay
(~60 µs + a stack each, nothing amortized). At fleet scale that is tens of
thousands of thread creations per wave, and thread churn — not the
network — dominates the control plane. The pool reuses idle workers:
``submit`` hands the task to a parked worker (LIFO, warm stacks first) or
spawns one when none is idle.

Deliberately UNCAPPED: runtime tasks block on each other (an invocation
waits on a transfer that waits on a placement that waits on a provision),
so a bounded pool deadlocks under load — concurrency is bounded upstream
by admission control (FleetGate), not here. Idle workers expire after
``idle_ttl_s`` (:data:`IDLE_TTL_S`, env ``TRUFFLE_POOL_IDLE_S``), so soak
runs drain back to the baseline thread count.

Workers take their task's ``name`` while running and restore the pool
name when parked — thread-name-based diagnostics (and wind-down
assertions) see exactly what they saw with dedicated threads. A task that
raises records the error on its :class:`Task` handle, counts it in
``stats["errors"]``, and prints the traceback (same visibility as a
dedicated thread's excepthook) — errors never vanish silently.
"""
from __future__ import annotations

import _thread
import os
import threading
import traceback
from queue import Empty, SimpleQueue
from typing import Callable, List, Optional, Tuple

#: seconds an idle worker waits for its next task before exiting
IDLE_TTL_S = float(os.environ.get("TRUFFLE_POOL_IDLE_S", "5.0"))


class Task:
    """Handle for a pool-run task. Thread-shaped (``join``/``is_alive``)
    so call sites that kept their ``Thread`` object keep working, plus a
    result box (``result`` re-raises the task's error)."""

    __slots__ = ("name", "_done", "_result", "_error")

    def __init__(self, name: Optional[str]) -> None:
        self.name = name
        self._done = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None

    def join(self, timeout: Optional[float] = None) -> None:
        self._done.wait(timeout)

    def is_alive(self) -> bool:
        return not self._done.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError(f"task {self.name or '<unnamed>'} "
                               f"still running")
        if self._error is not None:
            raise self._error
        return self._result


class _Worker:
    """One pooled thread: tasks arrive through its private handoff box,
    so a submit wakes exactly the worker it reserved (no thundering
    herd on a shared queue)."""

    __slots__ = ("box",)

    def __init__(self) -> None:
        self.box: "SimpleQueue[Tuple[Task, Callable, tuple]]" = SimpleQueue()


class WorkerPool:
    def __init__(self, idle_ttl_s: float = IDLE_TTL_S,
                 name: str = "truffle-worker") -> None:
        self._idle_ttl_s = idle_ttl_s
        self._name = name
        self._lock = threading.Lock()
        self._idle: List[_Worker] = []      # parked workers, LIFO
        self._seq = 0
        self.stats = {"spawned": 0, "reused": 0, "active": 0, "errors": 0}

    def submit(self, fn: Callable, args: tuple = (),
               name: Optional[str] = None) -> Task:
        """Run ``fn(*args)`` on a pooled worker; returns its :class:`Task`.
        Reuses a parked worker when one exists, else spawns."""
        task = Task(name)
        item = (task, fn, tuple(args))
        with self._lock:
            self.stats["active"] += 1
            w = self._idle.pop() if self._idle else None
            if w is not None:
                self.stats["reused"] += 1
            else:
                self.stats["spawned"] += 1
                self._seq += 1
                seq = self._seq
        if w is None:
            w = _Worker()
            w.box.put(item)
            # raw spawn, no bootstrap handshake: Thread.start() parks the
            # submitter until the new thread has bootstrapped and taken
            # the GIL (milliseconds under load), which serializes pool
            # growth behind the very burst that demanded it
            _thread.start_new_thread(self._run, (w, f"{self._name}-{seq}"))
        else:
            w.box.put(item)
        return task

    def _run(self, w: _Worker, idle_name: Optional[str] = None) -> None:
        me = threading.current_thread()
        if idle_name is not None:
            me.name = idle_name      # raw-spawned: adopt the pool name
        else:
            idle_name = me.name
        while True:
            try:
                item = w.box.get(timeout=self._idle_ttl_s)
            except Empty:
                with self._lock:
                    if w in self._idle:
                        self._idle.remove(w)
                        return          # expired: deregistered, exit
                # a racing submit reserved us (popped from _idle) but its
                # handoff hadn't landed yet — it is in flight NOW
                item = w.box.get()
            task, fn, args = item
            if task.name:
                me.name = task.name
            try:
                task._result = fn(*args)
            except BaseException as e:  # noqa: BLE001 — recorded + printed
                task._error = e
                with self._lock:
                    self.stats["errors"] += 1
                traceback.print_exc()
            finally:
                me.name = idle_name
                with self._lock:
                    self.stats["active"] -= 1
                    self._idle.append(w)
            task._done.set()

    def idle_workers(self) -> int:
        with self._lock:
            return len(self._idle)


#: process-wide pool shared by every cluster (threads are a process
#: resource; per-cluster pools would defeat reuse across test/bench runs)
EXECUTOR = WorkerPool()
