"""Node health scoring: the node-level twin of ``LinkTelemetry``.

The edge-cloud continuum loses *nodes* more often than links — they slow
down (thermal throttling, noisy neighbors), their disks stall, they crash
and come back empty. :class:`NodeHealthMonitor` folds the signals the
system already produces into a per-node health state machine:

  * stage-time inflation — EWMA of measured/predicted stage time
    (``core.model.stage_inflation`` / ``fold_inflation``), reported by the
    runner after every completed stage: a node consistently running 2.5×
    its Eq. 4 predictions is sick even though nothing ever *failed*;
  * transfer stalls and infrastructure failures (crashes, dead links,
    offline buffers, per-attempt timeouts), reported by the retry layer;
  * heartbeats — last-seen timestamps from the same event bus feeding
    ``LinkTelemetry`` (``scheduling.placed``, ``workflow.stage_done``).

States escalate healthy → suspect → degraded → dead and publish
``node.health`` bus events on every transition. Consumers:

  * the :class:`~repro.runtime.scheduler.Scheduler` adds
    :meth:`penalty` to its placement score — a suspect node needs a real
    locality/load advantage to win a placement, a degraded one is avoided
    outright (same magnitude as the speculative-backup AVOID penalty);
  * the :class:`~repro.runtime.workflow.ReplanController` watches
    :attr:`generation` (bumped on every state change) and forces a
    recompile of the remaining subgraph when health moved — placement
    revision, not just transport revision;
  * the cluster's ``on_degraded`` hook triggers CAS evacuation of
    sole-replica content before the node goes fully dark.

A streak of clean stages heals suspect back to healthy (counters reset),
mirroring how the EWMA itself decays; ``dead`` and forced ``degraded``
(drain) are sticky until :meth:`mark_alive`.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional

from repro.core.model import fold_inflation, stage_inflation

HEALTHY = "healthy"
SUSPECT = "suspect"
DEGRADED = "degraded"
DEAD = "dead"

# a suspect node must be beaten by this much locality/load advantage;
# degraded matches the scheduler's AVOID_PENALTY scale (placed only when
# literally nothing else is alive)
SUSPECT_PENALTY = 2.0
DEGRADED_PENALTY = 1e6


class _NodeStats:
    __slots__ = ("inflation", "samples", "stalls", "failures",
                 "clean_streak", "forced", "state", "last_seen")

    def __init__(self):
        self.inflation: Optional[float] = None   # EWMA measured/predicted
        self.samples = 0
        self.stalls = 0
        self.failures = 0
        self.clean_streak = 0
        self.forced: Optional[str] = None        # sticky dead/degraded
        self.state = HEALTHY
        self.last_seen: Optional[float] = None


class NodeHealthMonitor:
    def __init__(self, cluster, *, alpha: float = 0.3,
                 suspect_inflation: float = 1.5,
                 degraded_inflation: float = 2.5,
                 min_samples: int = 2,
                 suspect_failures: int = 1,
                 degraded_failures: int = 3,
                 clean_streak: int = 3):
        self.cluster = cluster
        self.alpha = alpha
        self.suspect_inflation = suspect_inflation
        self.degraded_inflation = degraded_inflation
        self.min_samples = min_samples
        self.suspect_failures = suspect_failures
        self.degraded_failures = degraded_failures
        self.clean_streak = clean_streak
        self.generation = 0                       # bumped on state change
        self.on_degraded: Optional[Callable[[str], None]] = None
        self._lock = threading.Lock()
        self._nodes: Dict[str, _NodeStats] = {}
        bus = getattr(cluster, "bus", None)
        if bus is not None:
            bus.subscribe("scheduling.placed", self._heartbeat)
            bus.subscribe("workflow.stage_done", self._heartbeat)

    # ------------------------------------------------------------- signals
    def _heartbeat(self, event: dict) -> None:
        node = event.get("node")
        if node is None:
            return
        with self._lock:
            self._stats_locked(node).last_seen = event.get("t")

    def report_stage(self, node: Optional[str], measured_s: float,
                     predicted_s: Optional[float]) -> None:
        """Fold one completed stage's measured/predicted inflation."""
        if node is None:
            return
        ratio = stage_inflation(measured_s, predicted_s)
        with self._lock:
            st = self._stats_locked(node)
            if ratio is not None:
                st.inflation = fold_inflation(st.inflation, ratio,
                                              self.alpha)
                st.samples += 1
            if ratio is None or ratio < self.suspect_inflation:
                st.clean_streak += 1
                if st.clean_streak >= self.clean_streak:
                    st.stalls = st.failures = 0
            else:
                st.clean_streak = 0
        self._reclassify(node)

    def report_stall(self, node: Optional[str]) -> None:
        if node is None:
            return
        with self._lock:
            st = self._stats_locked(node)
            st.stalls += 1
            st.clean_streak = 0
        self._reclassify(node)

    def report_failure(self, node: Optional[str]) -> None:
        """An infrastructure failure (crash, dead link, offline buffer,
        attempt timeout) was attributed to this node."""
        if node is None:
            return
        with self._lock:
            st = self._stats_locked(node)
            st.failures += 1
            st.clean_streak = 0
        self._reclassify(node)

    # ------------------------------------------------------- forced states
    def mark_dead(self, node: str) -> None:
        with self._lock:
            self._stats_locked(node).forced = DEAD
        self._reclassify(node)

    def mark_degraded(self, node: str) -> None:
        """Operator/drain override: stop placing here, evacuate."""
        with self._lock:
            self._stats_locked(node).forced = DEGRADED
        self._reclassify(node)

    def mark_alive(self, node: str) -> None:
        """Restart: the node returns with fresh stats (its sandboxes and
        CAS are gone, so is its history)."""
        with self._lock:
            self._nodes[node] = _NodeStats()
        self._reclassify(node)

    # ------------------------------------------------------------ consumers
    def state(self, node: str) -> str:
        with self._lock:
            st = self._nodes.get(node)
            return st.state if st is not None else HEALTHY

    def penalty(self, node: str) -> float:
        s = self.state(node)
        if s in (DEGRADED, DEAD):
            return DEGRADED_PENALTY
        if s == SUSPECT:
            return SUSPECT_PENALTY
        return 0.0

    def snapshot(self) -> Dict[str, dict]:
        with self._lock:
            return {name: {"state": st.state, "inflation": st.inflation,
                           "samples": st.samples, "stalls": st.stalls,
                           "failures": st.failures,
                           "last_seen": st.last_seen}
                    for name, st in self._nodes.items()}

    # ------------------------------------------------------------ internals
    def _stats_locked(self, node: str) -> _NodeStats:
        st = self._nodes.get(node)
        if st is None:
            st = self._nodes[node] = _NodeStats()
        return st

    def _classify(self, st: _NodeStats) -> str:
        if st.forced is not None:
            return st.forced
        inflated = st.samples >= self.min_samples and st.inflation is not None
        if st.failures >= self.degraded_failures \
                or (inflated and st.inflation >= self.degraded_inflation):
            return DEGRADED
        if st.failures >= self.suspect_failures or st.stalls >= 1 \
                or (inflated and st.inflation >= self.suspect_inflation):
            return SUSPECT
        return HEALTHY

    def _reclassify(self, node: str) -> None:
        with self._lock:
            st = self._stats_locked(node)
            new = self._classify(st)
            prev, st.state = st.state, new
            if new == prev:
                return
            self.generation += 1
            snap = {"node": node, "state": new, "prev": prev,
                    "inflation": st.inflation, "failures": st.failures,
                    "stalls": st.stalls}
        bus = getattr(self.cluster, "bus", None)
        clock = getattr(self.cluster, "clock", None)
        if clock is not None:
            snap["t"] = clock.now()
        if bus is not None:
            bus.publish("node.health", snap)
        if new == DEGRADED and prev != DEAD and self.on_degraded is not None:
            self.on_degraded(node)


__all__ = ["NodeHealthMonitor", "HEALTHY", "SUSPECT", "DEGRADED", "DEAD",
           "SUSPECT_PENALTY", "DEGRADED_PENALTY"]
