"""Per-edge data-passing policy + fluent workflow builder.

Truffle's gains come from matching the data-passing mechanism to each hop
of the workflow: SDP on cold starts, CSP between functions, direct/kvs/s3
per tier, dedup on fan-out hops, chunk streaming + compression on WAN hops.
A :class:`DataPolicy` declares that choice at data-flow granularity — it
can be attached to a whole workflow (default), to a stage (all of its
in-edges), or to a single edge — and the
:class:`~repro.runtime.planner.Planner` compiles the result into an
immutable :class:`~repro.runtime.planner.ExecutionPlan` that the runner,
platform, scheduler, SDP, CSP and Data Engine consume instead of reading
runner-global booleans.

:class:`WorkflowBuilder` is the fluent construction surface::

    b = WorkflowBuilder("fire", default_policy=DataPolicy(dedup=True))
    b.stage("decode", decode_spec)
    b.stage("resize", resize_spec).after("decode")
    b.stage("upload", upload_spec).after(
        "resize", policy=DataPolicy(stream=True, compression="lz4-like"))
    wf = b.build()              # cycle-checked Workflow with edge policies

Hand-built ``Stage``/``Workflow`` dicts keep working (the builder produces
exactly those), as do the legacy ``WorkflowRunner(stream=, dedup=,
storage=, straggler_factor=)`` kwargs — they construct a uniform default
policy through the same Planner path.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.errors import WorkflowCycleError

STRATEGIES = ("direct", "kvs", "s3", "auto")
# "lz4-entropy" is the same codec model with the jax byte-histogram
# compressibility probe (repro.kernels.ops) instead of a deflate sample —
# opt-in per edge; the planner's auto search stays on the measured probe
COMPRESSIONS = ("none", "lz4-like", "lz4-entropy")


@dataclass(frozen=True)
class RetryPolicy:
    """Crash-restart recovery knobs for a stage (or an edge feeding it).

    Attributes
    ----------
    max_attempts:
        Total tries including the first (1 = today's fail-fast behavior).
        Every retry is steered to a different, health-scored node than the
        failed attempt, and its inputs are re-shipped from surviving CAS
        replicas (upstream stages only re-execute when the last replica
        died with the node).
    backoff_s:
        Simulated seconds slept before attempt k+1, scaled linearly by the
        attempt number (k * backoff_s) — cheap damping so a flapping node
        doesn't absorb the whole retry budget instantly.
    timeout_s:
        Per-attempt bound in simulated seconds (None = unbounded). An
        attempt exceeding it is abandoned and counted as a failure — how
        a stage wedged on a sick-but-not-dead node gets unstuck.
    """

    max_attempts: int = 2
    backoff_s: float = 0.0
    timeout_s: Optional[float] = None

    def __post_init__(self):
        if not isinstance(self.max_attempts, int) or self.max_attempts < 1:
            raise ValueError(f"max_attempts must be an int >= 1, "
                             f"got {self.max_attempts!r}")
        if self.backoff_s < 0:
            raise ValueError(f"backoff_s must be >= 0 sim-seconds, "
                             f"got {self.backoff_s!r}")
        if self.timeout_s is not None and self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be positive sim-seconds or "
                             f"None, got {self.timeout_s!r}")


@dataclass(frozen=True)
class DataPolicy:
    """How one hop of the workflow passes its data.

    Attributes
    ----------
    strategy:
        Where the bytes live in flight: ``direct`` (CSP node-to-node pass),
        ``kvs`` or ``s3`` (producer writes to the storage service, consumer
        fetches — SDP prefetches it during the cold start), or ``auto`` —
        the :class:`~repro.runtime.planner.Planner` picks ``stream``/
        ``compression``/``chunk_bytes`` per edge at compile time by
        evaluating the Eq. 4 per-edge model over telemetry-backed link
        estimates (the other fields of an ``auto`` policy — ``dedup``,
        ``prefetch``, ``locality_weight``, ``speculation`` — are kept).
        ``auto`` only ever exists pre-compile; plans carry the resolved
        concrete policy.
    stream:
        Pipeline the transfer at chunk granularity so the consumer starts
        at first-chunk arrival (vs. whole-blob last-byte).
    dedup:
        Content-address the edge's bytes (BLAKE2b). Fan-out inputs alias
        the already-resident chunks, the digest feeds the locality-aware
        scheduler, and fan-in stages carry one digest hint per dep.
    compression:
        ``lz4-like`` compresses chunks on the wire (WAN edges are
        bandwidth-bound; a LAN edge usually shouldn't pay the codec).
    locality_weight:
        Override of the scheduler's locality weight for placements this
        edge hints (None = scheduler default; 0 disables locality).
    prefetch:
        Registry-driven prefetch: when the scheduler places *off* the data
        (load skew), it kicks the relay at placement-decision time instead
        of waiting for the data path to react to the trigger.
    speculation:
        Straggler factor: re-dispatch the stage when it exceeds this
        multiple of its predicted time (0 = off). The backup attempt is
        steered to a different node than the straggler. ``"auto"`` hands
        the factor to the planner: it is resolved per edge at compile time
        from the link's observed telemetry variability — a flappy link
        speculates early, a steady link never pays the backup (resolves to
        0). Like ``strategy="auto"``, the string only ever exists
        pre-compile; plans carry the resolved float.
    chunk_bytes:
        Streaming grant size for this edge (None = the fabric default,
        ``DEFAULT_CHUNK_BYTES``). Small chunks start the pipeline earlier
        and overlap more per-chunk compute; big chunks pay less per-chunk
        grant overhead. The adaptive planner picks this per edge from its
        chunk grid; hand-written policies may pin it too.
    retry:
        Crash-restart recovery for the stage this edge feeds (see
        :class:`RetryPolicy`). None = single attempt. When several
        in-edges of one stage disagree, the planner merges toward the
        most resilient (max attempts, max backoff, tightest timeout).
    pipeline:
        Function-to-function direct streaming: the producer's output
        chunks flow to the consumer WHILE the producer is still
        executing. The runner fires the consumer's lightweight trigger
        at *producer dispatch* (its cold start overlaps producer
        execution — CSP taken to its limit), and the producer's
        ``Invocation.put_stream`` chunks relay into the consumer's
        in-flight buffer entry with bounded in-flight bytes
        (``pipeline_highwater``; the producer blocks past the mark
        until the consumer drains). ``True`` forces it, ``False``
        forbids it, ``"auto"`` (with ``strategy="auto"``) lets the
        planner enable it per edge when both producer and consumer are
        streaming-capable. Requires ``stream=True`` when forced (chunks
        are the transport unit). A mid-stream producer crash poisons
        the consumer's input, composing with ``retry``.
    pipeline_highwater:
        Backpressure bound for a pipelined edge: maximum unconsumed
        in-flight bytes buffered at the consumer before the producer's
        ``put_stream`` blocks (None = 4 x the edge's chunk size).
    """

    strategy: str = "direct"
    stream: bool = False
    dedup: bool = False
    compression: str = "none"
    locality_weight: Optional[float] = None
    prefetch: bool = False
    speculation: float = 0.0
    chunk_bytes: Optional[int] = None
    retry: Optional[RetryPolicy] = None
    pipeline: object = False
    pipeline_highwater: Optional[int] = None

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}, "
                             f"got {self.strategy!r}")
        if self.compression not in COMPRESSIONS:
            raise ValueError(f"compression must be one of {COMPRESSIONS}, "
                             f"got {self.compression!r}")
        if isinstance(self.speculation, str):
            if self.speculation != "auto":
                raise ValueError(f"speculation must be a factor >= 0 or "
                                 f"'auto', got {self.speculation!r}")
        elif self.speculation < 0:
            raise ValueError(f"speculation must be >= 0, "
                             f"got {self.speculation!r}")
        if self.locality_weight is not None and self.locality_weight < 0:
            raise ValueError(f"locality_weight must be >= 0 or None, "
                             f"got {self.locality_weight!r}")
        if self.prefetch and not self.dedup:
            raise ValueError(
                "prefetch is registry-driven: it relays content the "
                "DigestRegistry can resolve, so it requires dedup=True "
                "(without a digest the hint is empty and the kick would "
                "silently never fire)")
        if self.chunk_bytes is not None and self.chunk_bytes <= 0:
            raise ValueError(f"chunk_bytes must be positive or None, "
                             f"got {self.chunk_bytes!r}")
        if self.retry is not None and not isinstance(self.retry,
                                                     RetryPolicy):
            raise ValueError(f"retry must be a RetryPolicy or None, "
                             f"got {self.retry!r}")
        if isinstance(self.pipeline, str):
            if self.pipeline != "auto":
                raise ValueError(f"pipeline must be True, False or 'auto', "
                                 f"got {self.pipeline!r}")
        elif not isinstance(self.pipeline, bool):
            raise ValueError(f"pipeline must be True, False or 'auto', "
                             f"got {self.pipeline!r}")
        if self.pipeline is True and not self.stream:
            raise ValueError(
                "pipeline=True streams producer chunks mid-execution, so "
                "the edge must be chunked: set stream=True (or use "
                "strategy='auto' with pipeline='auto')")
        if self.pipeline_highwater is not None \
                and self.pipeline_highwater <= 0:
            raise ValueError(f"pipeline_highwater must be positive bytes or "
                             f"None, got {self.pipeline_highwater!r}")

    def but(self, **changes) -> "DataPolicy":
        """A copy with ``changes`` applied — derive an edge policy from a
        stage/workflow default: ``pol.but(compression="lz4-like")``."""
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ReplanPolicy:
    """When may the runner recompile a workflow mid-flight?

    Between stage waves the runner re-predicts the Eq. 4 time of every
    not-yet-dispatched stage against CURRENT telemetry and compares it to
    the prediction the active plan was compiled from. The remaining
    subgraph is recompiled when the ratio between the two (either
    direction — a degraded link slows the plan, a recovered one strands it
    on a too-conservative policy) reaches ``drift_ratio``. In-flight
    stages always keep the plan they were dispatched under.

    Attributes
    ----------
    drift_ratio:
        Replan when ``max(fresh/frozen, frozen/fresh) >= drift_ratio``
        over the remaining stages' predicted time. Must be > 1 (at 1.0
        every telemetry wiggle would trigger a recompile).
    min_interval:
        Simulated seconds that must elapse between replans (flap damping:
        a link oscillating faster than this can flip the plan at most once
        per interval).
    max_replans:
        Hard cap on recompiles per ``run`` (0 freezes the plan — useful as
        the control arm of an experiment).
    """

    drift_ratio: float = 1.3
    min_interval: float = 0.0
    max_replans: int = 3

    def __post_init__(self):
        if self.drift_ratio <= 1.0:
            raise ValueError(f"drift_ratio must be > 1 (a ratio of 1 means "
                             f"ANY drift replans), got {self.drift_ratio!r}")
        if self.min_interval < 0:
            raise ValueError(f"min_interval must be >= 0 sim-seconds, "
                             f"got {self.min_interval!r}")
        if not isinstance(self.max_replans, int) or self.max_replans < 0:
            raise ValueError(f"max_replans must be an int >= 0, "
                             f"got {self.max_replans!r}")


class _StageBuilder:
    """Fluent handle returned by :meth:`WorkflowBuilder.stage`."""

    def __init__(self, builder: "WorkflowBuilder", name: str):
        self._builder = builder
        self.name = name

    def after(self, *deps: str,
              policy: Optional[DataPolicy] = None) -> "_StageBuilder":
        """Declare dependencies; ``policy`` applies to each (dep -> this)
        edge and overrides the stage/workflow defaults for those edges."""
        for dep in deps:
            self._builder._add_edge(dep, self.name, policy)
        return self

    def policy(self, policy: DataPolicy) -> "_StageBuilder":
        """Set this stage's default policy (all in-edges without their own
        edge policy)."""
        self._builder._stage_policies[self.name] = policy
        return self


class WorkflowBuilder:
    def __init__(self, name: str,
                 default_policy: Optional[DataPolicy] = None):
        self.name = name
        self.default_policy = default_policy
        self._specs: Dict[str, object] = {}           # name -> FunctionSpec
        self._deps: Dict[str, List[str]] = {}
        self._edge_policies: Dict[Tuple[str, str], DataPolicy] = {}
        self._stage_policies: Dict[str, DataPolicy] = {}

    # ------------------------------------------------------------ declaring
    def stage(self, name: str, spec,
              policy: Optional[DataPolicy] = None) -> _StageBuilder:
        if name in self._specs:
            raise ValueError(f"duplicate stage {name!r} in workflow "
                             f"{self.name!r}")
        self._specs[name] = spec
        self._deps[name] = []
        if policy is not None:
            self._stage_policies[name] = policy
        return _StageBuilder(self, name)

    def edge(self, src: str, dst: str,
             policy: Optional[DataPolicy] = None) -> "WorkflowBuilder":
        """Non-fluent spelling of ``stage(dst).after(src, policy=...)`` for
        programmatic DAG construction."""
        self._add_edge(src, dst, policy)
        return self

    def _add_edge(self, src: str, dst: str,
                  policy: Optional[DataPolicy]) -> None:
        if dst not in self._deps:
            raise KeyError(f"stage {dst!r} not declared")
        if src in self._deps[dst]:
            raise ValueError(f"duplicate edge {src!r} -> {dst!r}")
        self._deps[dst].append(src)
        if policy is not None:
            self._edge_policies[(src, dst)] = policy

    # ------------------------------------------------------------- building
    def build(self):
        """Validate (unknown deps, cycles) and produce a
        :class:`~repro.runtime.workflow.Workflow` carrying the per-stage /
        per-edge policies. Raises :class:`WorkflowCycleError` on a cycle."""
        from repro.runtime.workflow import Stage, Workflow

        unknown = sorted({d for deps in self._deps.values() for d in deps
                          if d not in self._specs})
        if unknown:
            raise KeyError(f"workflow {self.name!r}: stages depend on "
                           f"undeclared stage(s) {unknown}")
        stages = {
            name: Stage(spec, deps=list(self._deps[name]),
                        policy=self._stage_policies.get(name),
                        dep_policies={src: pol for (src, dst), pol
                                      in self._edge_policies.items()
                                      if dst == name})
            for name, spec in self._specs.items()}
        wf = Workflow(self.name, stages, default_policy=self.default_policy)
        wf.topo_order()                 # raises WorkflowCycleError on cycles
        return wf

    def plan(self, default: Optional[DataPolicy] = None):
        """Build and compile in one step (convenience)."""
        from repro.runtime.planner import Planner
        return Planner(default=default or self.default_policy).compile(
            self.build())


__all__ = ["DataPolicy", "ReplanPolicy", "RetryPolicy", "WorkflowBuilder",
           "WorkflowCycleError", "STRATEGIES", "COMPRESSIONS"]
