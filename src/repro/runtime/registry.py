"""Cluster-wide digest registry: which node currently holds which content.

PR 1's content-addressed Buffer made duplicate *transfers* cheap (alias on
arrival); this registry makes the residency visible to the *scheduler*, so
placement can follow the data instead of shipping the data to wherever the
function lands ("Following the Data, Not the Function" — the dominant win
for data-intensive fan-out workflows).

Each node's :class:`~repro.core.buffer.Buffer` reports residency changes via
its ``on_residency`` callback (wired by ``Cluster``): a complete entry whose
digest resolves on that node publishes ``digest → node`` here; eviction or
displacement withdraws it. Every change is mirrored onto the event bus as
``registry.digest_added`` / ``registry.digest_removed`` events (payload:
``{"digest", "node", "bytes"}``) so external observers — dashboards, the
benchmarks — can watch residency without polling.

Thread-safe; all methods are O(1) in the number of nodes holding a digest.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

#: event-bus topics mirrored on every residency change
EVENT_DIGEST_ADDED = "registry.digest_added"
EVENT_DIGEST_REMOVED = "registry.digest_removed"


class DigestRegistry:
    def __init__(self, bus=None):
        self._bus = bus
        self._lock = threading.Lock()
        # digest -> {node_name: resident_bytes}
        self._where: Dict[str, Dict[str, int]] = {}
        self.stats = {"publishes": 0, "withdrawals": 0}
        # fleet accounting callbacks: cb(event, node, digest, size) with
        # event in {"added", "removed"}, invoked OUTSIDE the registry lock
        # at exactly the points the bus events fire — a ledger may re-enter
        # the registry (or take its own lock) from the callback.
        # Append-only at wiring time, so iteration needs no lock.
        self._ledgers: list = []

    # ------------------------------------------------------------- wiring
    def add_ledger(self, cb) -> None:
        """Register a residency-accounting callback (e.g. the fleet's
        TenantLedger): called as ``cb("added"|"removed", node, digest,
        size)`` after each residency change is applied."""
        self._ledgers.append(cb)

    def listener(self, node_name: str):
        """Residency callback for one node's Buffer (``on_residency``)."""
        def on_residency(digest: str, size: int, resident: bool) -> None:
            if resident:
                self.publish(node_name, digest, size)
            else:
                self.withdraw(node_name, digest)
        return on_residency

    # ------------------------------------------------------------ updates
    def publish(self, node: str, digest: str, size: int) -> None:
        """Record that ``node``'s buffer holds ``digest`` (idempotent)."""
        if digest is None:
            return
        with self._lock:
            fresh = node not in self._where.setdefault(digest, {})
            self._where[digest][node] = size
            self.stats["publishes"] += 1
        if fresh:
            for cb in self._ledgers:
                cb("added", node, digest, size)
            if self._bus is not None:
                self._bus.publish(EVENT_DIGEST_ADDED,
                                  {"digest": digest, "node": node,
                                   "bytes": size})

    def withdraw(self, node: str, digest: str) -> None:
        """Record that ``node`` no longer resolves ``digest`` (evicted or
        displaced). Unknown pairs are ignored (idempotent)."""
        if digest is None:
            return
        size = None
        with self._lock:
            nodes = self._where.get(digest)
            if nodes is not None and node in nodes:
                size = nodes.pop(node)
                if not nodes:
                    del self._where[digest]
                self.stats["withdrawals"] += 1
        if size is not None:
            for cb in self._ledgers:
                cb("removed", node, digest, size)
            if self._bus is not None:
                self._bus.publish(EVENT_DIGEST_REMOVED,
                                  {"digest": digest, "node": node,
                                   "bytes": size})

    def drop_node(self, node: str) -> Dict[str, int]:
        """Forget EVERY residency entry for ``node`` (death or removal):
        locality scoring, the Prefetcher, and retry re-ship must stop
        steering at phantom replicas the moment the node is gone. Fires
        ``registry.digest_removed`` per dropped digest — the same event a
        normal eviction produces — so bus observers stay consistent.
        Returns what was dropped (``{digest: bytes}``)."""
        dropped: Dict[str, int] = {}
        with self._lock:
            for digest in list(self._where):
                nodes = self._where[digest]
                if node in nodes:
                    dropped[digest] = nodes.pop(node)
                    if not nodes:
                        del self._where[digest]
                    self.stats["withdrawals"] += 1
        for digest, size in dropped.items():
            for cb in self._ledgers:
                cb("removed", node, digest, size)
            if self._bus is not None:
                self._bus.publish(EVENT_DIGEST_REMOVED,
                                  {"digest": digest, "node": node,
                                   "bytes": size})
        return dropped

    # ------------------------------------------------------------ queries
    def holdings(self, node: str) -> Dict[str, int]:
        """``{digest: resident_bytes}`` currently attributed to ``node``
        (copy) — what evacuation walks to find sole replicas."""
        with self._lock:
            return {digest: nodes[node]
                    for digest, nodes in self._where.items()
                    if node in nodes}

    def nodes_for(self, digest: Optional[str]) -> Dict[str, int]:
        """``{node_name: resident_bytes}`` for a digest (copy; may be empty)."""
        if digest is None:
            return {}
        with self._lock:
            return dict(self._where.get(digest, {}))

    def resident_bytes(self, node: str, digest: Optional[str]) -> int:
        """Bytes of ``digest`` currently resident on ``node`` (0 if absent)."""
        if digest is None:
            return 0
        with self._lock:
            return self._where.get(digest, {}).get(node, 0)

    @staticmethod
    def fraction(resident_bytes: int, size: int) -> float:
        """Resident fraction of an input of ``size`` bytes, in [0, 1] — the
        ONE definition both the scheduler's scoring and ``resident_fraction``
        use. A zero-size hint counts as fully resident when any bytes
        resolve (the scheduler still prefers the holder)."""
        if resident_bytes <= 0:
            return 0.0
        if size <= 0:
            return 1.0
        return min(resident_bytes, size) / size

    def resident_fraction(self, node: str, digest: Optional[str],
                          size: int) -> float:
        """Fraction of an input of ``size`` bytes already on ``node``."""
        return self.fraction(self.resident_bytes(node, digest), size)
