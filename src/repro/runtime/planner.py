"""Planner: compile a Workflow + DataPolicies into an immutable ExecutionPlan.

The plan is the single source of truth the execution stack consumes —
``WorkflowRunner`` dispatches from it, ``Platform``/``Scheduler`` receive
its placement hints, SDP/CSP/DataEngine receive its per-edge policies —
instead of each layer re-reading runner-global ``stream``/``dedup`` knobs.

Resolution order for the edge ``src -> dst`` (most specific wins, whole
policy at a time):

    edge policy (``after(src, policy=...)``)
      > dst stage policy (``stage(..., policy=...)``)
      > workflow default (``WorkflowBuilder(default_policy=...)``)
      > planner default (the legacy runner kwargs shim lands here)

Per stage the planner derives:
  * ``transport`` — the merged in-edge policy actually used to move the
    stage's (joined) input: strategies must agree (:class:`PlanError`
    otherwise), ``stream``/``dedup``/``prefetch`` are OR-ed, compression
    engages if any in-edge asks, ``speculation`` takes the max,
    ``chunk_bytes`` takes the finest declared grant.
  * ``hint_deps`` — deps whose edge has ``dedup``: the stage's placement
    hint carries one digest per such dep (fan-in stages are scored on the
    SUM of resident inputs, not a joined-blob hash that resolves nowhere).
  * ``seed_output`` — True when any consumer edge has ``dedup``: the
    runner content-addresses the stage's output and seeds it on the node
    that produced it, so downstream placement can follow the bytes.

Adaptive planning (``DataPolicy(strategy="auto")``): an auto edge is
resolved at compile time by evaluating the Eq. 4 per-edge model
(:func:`repro.core.model.edge_time`) over the candidate grid
{whole-blob, stream} × {none, lz4-like} × ``chunk_grid``, with link
bandwidth/RTT taken from :class:`~repro.runtime.netsim.LinkTelemetry`
(node-pair estimate if traffic has been seen, tier prior otherwise) and
codec wire ratios from the edge's :class:`EdgeProfile` or telemetry's
observed codec EWMA. The argmin candidate replaces the auto policy; every
profiled ``direct``-strategy edge (auto or hand-set) additionally gets a
compile-time prediction (``EdgePlan.predicted_s``) that the runner stamps
onto the stage's ``LifecycleRecord`` — predicted-vs-measured Eq. 4 error
is an assertable quantity. (``kvs``/``s3`` edges move through the storage
service's own channels, which the fabric-link model doesn't cover — they
get no prediction rather than a wrong one.) Candidate evaluation is
deterministic given frozen telemetry: fixed candidate order,
strict-improvement argmin.

Mid-flight re-planning: a compiled plan keeps the EdgeProfiles it was
built from (``ExecutionPlan.profiles``). Between stage waves the runner
calls :meth:`Planner.predict_remaining` — the SAME per-edge Eq. 4 model
re-evaluated against current telemetry over the not-yet-dispatched
subgraph — and, when the fresh/frozen ratio crosses its
:class:`~repro.runtime.policy.ReplanPolicy` threshold,
:meth:`Planner.recompile_remaining` splices a fresh compile over the
remaining stages only (dispatched stages keep their plan; ``generation``
increments). ``DataPolicy(speculation="auto")`` rides the same telemetry:
the straggler factor is resolved per edge from the link's observed
variability (EWMA variance — steady links resolve to 0 and never pay a
backup; flappy links re-dispatch earlier) and refreshes on every replan.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from types import MappingProxyType
from typing import Mapping, Optional, Tuple

from repro.core.errors import PlanError, WorkflowCycleError  # noqa: F401
from repro.core.model import (PhaseEstimate, edge_time,
                              pipelined_chain_finish_times)
from repro.runtime.netsim import (DEFAULT_CHUNK_BYTES,
                                  FABRIC_CHUNK_OVERHEAD_S)
from repro.runtime.policy import DataPolicy, RetryPolicy

#: chunk-size grid an auto edge is evaluated over (the uniform-extreme
#: candidates of the property tests — whole-blob and stream at the
#: default chunk — are both members of the full candidate set)
CHUNK_GRID = (256 * 1024, DEFAULT_CHUNK_BYTES, 4 * DEFAULT_CHUNK_BYTES)

#: scheduler + lightweight-trigger path, matching Scheduler.scheduling_s
#: and Platform.REF_TRIGGER_OVERHEAD_S (kept literal here to avoid a
#: planner -> platform import; AdaptivePlanner reads the live values)
DEFAULT_SCHEDULING_S = 0.15
DEFAULT_TRIGGER_S = 0.05

#: link variability (LinkEstimate.variability, a coefficient of variation)
#: below which ``speculation="auto"`` resolves to 0 — a steady link never
#: pays for a backup dispatch
SPECULATION_CV_TRIGGER = 0.20
#: resolved auto-speculation factor bounds: a barely-variable link
#: re-dispatches late (factor near MAX), a wildly variable one earliest
#: (factor floors at MIN — below that every routine wobble would fork a
#: backup)
SPECULATION_MAX_FACTOR = 3.0
SPECULATION_MIN_FACTOR = 1.5


@dataclass(frozen=True)
class EdgeProfile:
    """What the planner knows about one edge's traffic, for auto selection
    and Eq. 4 prediction.

    ``size`` is the expected payload; ``src_node``/``dst_node`` name where
    the bytes will originate/land when known (affinity pins — they select
    the telemetry link estimate; ``tiers`` is the fallback estimate key);
    ``compress_ratio`` is the expected codec wire ratio for THIS payload
    (e.g. sampled from a probe run) — when None the planner falls back to
    telemetry's observed codec EWMA, then to 1.0 (compression never looks
    free until evidence says so)."""
    size: int
    src_node: Optional[str] = None
    dst_node: Optional[str] = None
    tiers: Optional[Tuple[str, str]] = None
    compress_ratio: Optional[float] = None


@dataclass(frozen=True)
class EdgePlan:
    """One resolved hop: ``src is None`` marks the workflow ingress.
    ``predicted_s`` is the compile-time Eq. 4 edge time under the resolved
    policy (None when the edge had no profile to predict from)."""
    src: Optional[str]
    dst: str
    policy: DataPolicy
    predicted_s: Optional[float] = None


@dataclass(frozen=True)
class StagePlan:
    name: str
    deps: Tuple[str, ...]
    transport: DataPolicy                  # merged in-edge policy
    in_edges: Tuple[EdgePlan, ...]         # one per dep (ingress for roots)
    hint_deps: Tuple[str, ...] = ()        # deps contributing digest hints
    seed_output: bool = False              # content-address + seed the output
    predicted_s: Optional[float] = None    # Eq. 4 stage time (slowest in-edge)
    #: straggler budget in sim-seconds (speculation factor × predicted_s):
    #: the runner re-dispatches once the stage exceeds it. None when
    #: speculation is off or the stage has no prediction — speculation then
    #: needs a caller-provided PhaseEstimate, as before.
    speculation_budget_s: Optional[float] = None
    #: crash-restart recovery policy for this stage (merged from in-edge
    #: DataPolicy.retry overrides, falling back to the spec's); None = fail
    #: fast on the first error, exactly the pre-retry behavior
    retry: Optional[object] = None

    def edge_policy(self, src: Optional[str]) -> DataPolicy:
        for e in self.in_edges:
            if e.src == src:
                return e.policy
        raise KeyError(f"no edge {src!r} -> {self.name!r} in plan")


@dataclass(frozen=True)
class ExecutionPlan:
    """Immutable compiled form of a workflow: per-edge resolved policies,
    per-stage multi-input digest-hint structure, prefetch/speculation
    directives, and the (cycle-checked) topological order.

    ``profiles`` preserves the EdgeProfiles the plan was compiled from (the
    re-planning hook re-predicts the remaining subgraph against them under
    fresh telemetry); ``generation`` counts mid-flight recompiles — 0 for
    an original compile, +1 per replan splice (``replanned`` is the
    boolean spelling). Each replan produces a NEW plan object; the trail
    of flips lives in the runner's ``plan.replanned`` bus events and
    ``WorkflowTrace.replans``."""
    workflow: str
    order: Tuple[str, ...]
    stages: Mapping[str, StagePlan]
    default: DataPolicy = field(default_factory=DataPolicy)
    profiles: Mapping[Tuple[Optional[str], str], EdgeProfile] = \
        field(default_factory=dict)
    generation: int = 0

    def __post_init__(self):
        object.__setattr__(self, "stages", MappingProxyType(dict(self.stages)))
        object.__setattr__(self, "profiles",
                           MappingProxyType(dict(self.profiles)))

    @property
    def replanned(self) -> bool:
        """True iff this plan came out of a mid-flight recompile."""
        return self.generation > 0

    def edge_policy(self, src: Optional[str], dst: str) -> DataPolicy:
        return self.stages[dst].edge_policy(src)

    def uniform(self) -> Optional[DataPolicy]:
        """The single policy every edge resolves to, or None if mixed.
        (The legacy-kwargs shim compiles to a uniform plan by construction —
        the back-compat tests assert exactly this.)"""
        policies = {e.policy for sp in self.stages.values()
                    for e in sp.in_edges}
        return policies.pop() if len(policies) == 1 else None

    def label(self) -> str:
        """Storage label for traces: the uniform strategy, or ``mixed``."""
        strategies = {e.policy.strategy for sp in self.stages.values()
                      for e in sp.in_edges}
        return strategies.pop() if len(strategies) == 1 else "mixed"

    @property
    def predicted_total(self) -> Optional[float]:
        """Eq. 5 over the plan's predicted stage times (serialized-chain
        upper bound — exact for pinned chains, conservative for DAGs whose
        branches overlap). Stages without a prediction are skipped; None
        when nothing was profiled."""
        preds = [sp.predicted_s for sp in self.stages.values()
                 if sp.predicted_s is not None]
        return sum(preds) if preds else None

    def describe(self) -> str:
        lines = [f"plan {self.workflow!r} ({len(self.stages)} stages, "
                 f"label={self.label()})"]
        for name in self.order:
            sp = self.stages[name]
            t = sp.transport
            pred = (f" predicted={sp.predicted_s:.3f}s"
                    if sp.predicted_s is not None else "")
            lines.append(
                f"  {name}: deps={list(sp.deps)} strategy={t.strategy} "
                f"stream={t.stream} dedup={t.dedup} "
                f"compression={t.compression} chunk={t.chunk_bytes} "
                f"prefetch={t.prefetch} "
                f"speculation={t.speculation} hint_deps={list(sp.hint_deps)} "
                f"seed_output={sp.seed_output}{pred}")
        return "\n".join(lines)


class Planner:
    def __init__(self, default: Optional[DataPolicy] = None, *,
                 telemetry=None,
                 chunk_grid: Tuple[int, ...] = CHUNK_GRID,
                 scheduling_s: float = DEFAULT_SCHEDULING_S,
                 trigger_s: float = DEFAULT_TRIGGER_S,
                 chunk_overhead_s: float = FABRIC_CHUNK_OVERHEAD_S):
        self.default = default or DataPolicy()
        self.telemetry = telemetry
        self.chunk_grid = tuple(sorted(chunk_grid))
        self.scheduling_s = scheduling_s
        self.trigger_s = trigger_s
        self.chunk_overhead_s = chunk_overhead_s

    def compile(self, wf, profiles: Optional[Mapping[Tuple[Optional[str],
                                                           str],
                                                     EdgeProfile]] = None
                ) -> ExecutionPlan:
        """Compile ``wf`` (a :class:`~repro.runtime.workflow.Workflow`,
        hand-built or from :class:`WorkflowBuilder`). Raises
        :class:`WorkflowCycleError` on cyclic deps, :class:`PlanError` on
        incoherent policies.

        ``profiles`` maps ``(src, dst)`` edges (``src=None`` for ingress)
        to :class:`EdgeProfile`s. A profiled edge gets a compile-time
        Eq. 4 prediction; an ``auto`` edge additionally gets its
        ``stream``/``compression``/``chunk_bytes`` chosen by argmin over
        the candidate grid (an unprofiled or telemetry-blind auto edge
        conservatively resolves to whole-blob/uncompressed)."""
        order = tuple(wf.topo_order())          # raises on cycles
        wf_default = getattr(wf, "default_policy", None) or self.default
        profiles = profiles or {}

        def edge_pol(src: Optional[str], dst: str) -> DataPolicy:
            st = wf.stages[dst]
            if src is not None:
                pol = getattr(st, "dep_policies", None) or {}
                if src in pol:
                    return pol[src]
            stage_pol = getattr(st, "policy", None)
            return stage_pol if stage_pol is not None else wf_default

        stages = {}
        for name in order:
            st = wf.stages[name]
            deps = tuple(st.deps)
            edge_srcs = deps if deps else (None,)
            in_edges = tuple(
                self._finalize_edge(src, name, edge_pol(src, name),
                                    profiles.get((src, name)), st.spec,
                                    src_spec=(wf.stages[src].spec
                                              if src is not None else None))
                for src in edge_srcs)
            preds = [e.predicted_s for e in in_edges]
            transport = self._merge(name, in_edges)
            predicted = (max(p for p in preds if p is not None)
                         if any(p is not None for p in preds) else None)
            stages[name] = StagePlan(
                name=name, deps=deps,
                transport=transport,
                in_edges=in_edges,
                hint_deps=tuple(e.src for e in in_edges
                                if e.src is not None and e.policy.dedup),
                predicted_s=predicted,
                # straggler budget: factor × Eq. 4 stage prediction (the
                # runner converts to wall seconds at dispatch)
                speculation_budget_s=(transport.speculation * predicted
                                      if transport.speculation and
                                      predicted is not None else None),
                # edge-level retry overrides the spec's (most specific wins,
                # like every other policy knob)
                retry=(transport.retry if transport.retry is not None
                       else getattr(st.spec, "retry", None)))
        # second pass: a stage seeds its output iff some consumer edge dedups
        for name in order:
            consumers = [e for sp in stages.values() for e in sp.in_edges
                         if e.src == name]
            if any(e.policy.dedup for e in consumers):
                stages[name] = dataclasses.replace(stages[name],
                                                   seed_output=True)
        # third pass: fold the pipelined-chain overlap term into the
        # predictions, so predicted_s/predicted_total stay honest for
        # stages whose input flows mid-execution (Eq. 5 would double-count
        # the overlapped transfer+execution)
        self._overlap_predictions(wf, order, stages, profiles)
        return ExecutionPlan(workflow=wf.name, order=order, stages=stages,
                             default=wf_default, profiles=profiles)

    def _overlap_predictions(self, wf, order, stages, profiles) -> None:
        """Replace each pipelined consumer's ``predicted_s`` with its
        MARGINAL completion time in the chain's tandem-queue model
        (:func:`repro.core.model.pipelined_chain_finish_times`): the sum
        over the chain then telescopes to the chain makespan instead of
        Eq. 5's Σ(stage). A chain is followed head-down while every hop is
        predictable (profiled + telemetry link) and dispatchable as a pipe
        at runtime (single-dep consumer, no speculation armed — the runner
        applies the same gate); it stops at the first hop that is not."""
        piped = {}              # producer -> pipelined single-dep consumers
        for name in order:
            sp = stages[name]
            if len(sp.deps) == 1 and sp.in_edges[0].policy.pipeline is True:
                piped.setdefault(sp.deps[0], []).append(name)

        def walk(head: str) -> None:
            head_sp = stages[head]
            if head_sp.predicted_s is None:
                return
            gamma0 = wf.stages[head].spec.exec_s
            head_ready = max(head_sp.predicted_s - gamma0, 0.0)
            for first in piped.get(head, ()):    # fan-out: branch per pipe
                edges = []
                chain = []
                n_chunks = None
                cur = first
                while cur is not None:
                    sp = stages[cur]
                    if sp.speculation_budget_s is not None:
                        break               # runner won't pipe this hop
                    e = sp.in_edges[0]
                    prof = profiles.get((e.src, cur))
                    link = self._link_estimate(prof) if prof else None
                    if link is None:
                        break               # unpredictable hop: stop here
                    spec = wf.stages[cur].spec
                    size = max(prof.size, 0)
                    chunk = e.policy.chunk_bytes or DEFAULT_CHUNK_BYTES
                    n = max(1, math.ceil(size / chunk))
                    n_chunks = n if n_chunks is None else min(n_chunks, n)
                    wire = (size / link.bandwidth + link.rtt
                            + n * self.chunk_overhead_s)
                    ready = (self.scheduling_s + self.trigger_s
                             + spec.provision_s + spec.extra_cold_start_s
                             + spec.startup_s)
                    edges.append((ready, wire, spec.exec_s))
                    chain.append(cur)
                    nxt = piped.get(cur, ())
                    cur = nxt[0] if len(nxt) == 1 else None
                if not edges:
                    continue
                finishes = pipelined_chain_finish_times(
                    head_ready, gamma0, edges, n_chunks=n_chunks)
                for i, cname in enumerate(chain):
                    marginal = finishes[i + 1] - finishes[i]
                    stages[cname] = dataclasses.replace(
                        stages[cname], predicted_s=marginal,
                        in_edges=(dataclasses.replace(
                            stages[cname].in_edges[0],
                            predicted_s=marginal),))

        for name in order:
            # heads: stages with pipelined consumers that are not
            # themselves pipelined consumers (chain interiors are covered
            # by their head's walk)
            sp = stages[name]
            is_piped_consumer = (len(sp.deps) == 1 and
                                 sp.in_edges[0].policy.pipeline is True)
            if not is_piped_consumer and name in piped:
                walk(name)

    # --------------------------------------------------- adaptive selection
    def _link_estimate(self, profile: EdgeProfile):
        if self.telemetry is None:
            return None
        return self.telemetry.link(profile.src_node, profile.dst_node,
                                   tiers=profile.tiers)

    def _codec_ratio(self, codec_name: str,
                     profile: EdgeProfile) -> float:
        """Expected wire ratio: edge profile (payload-specific evidence) >
        telemetry's observed codec EWMA > 1.0 (no evidence: compression is
        never assumed free)."""
        if profile.compress_ratio is not None:
            return profile.compress_ratio
        if self.telemetry is not None:
            obs = self.telemetry.codec_ratio(codec_name)
            if obs is not None:
                return obs
        return 1.0

    def _candidate_time(self, spec, profile: EdgeProfile, link, *,
                        stream: bool, compression: str,
                        chunk_bytes: Optional[int]) -> float:
        """Eq. 4 edge time for one candidate configuration — the ONE model
        both auto selection and prediction use, mirroring the measured
        CSP/SDP direct path: α = trigger + scheduling; β from the dst spec;
        δ = size/bandwidth shaped by the effective wire ratio (codec-bound
        links stretch, see ``edge_delta``); RTT, per-grant overhead and
        codec startup ride the un-compressible ``overhead_s`` term; a
        streamed edge into a streaming handler overlaps (n−1)/n of γ."""
        size = max(profile.size, 0)
        gamma = spec.exec_s
        p = PhaseEstimate(
            alpha=self.scheduling_s + self.trigger_s,
            nu=spec.provision_s + spec.extra_cold_start_s,
            eta=spec.startup_s,
            delta=size / link.bandwidth,
            gamma=gamma)
        chunk = chunk_bytes or DEFAULT_CHUNK_BYTES
        n = max(1, math.ceil(size / chunk)) if stream else 1
        overhead = link.rtt + n * self.chunk_overhead_s
        ratio = 1.0
        if compression != "none":
            from repro.distributed.compression import chunk_codec
            codec = chunk_codec(compression)
            est = self._codec_ratio(compression, profile)
            # codec-bound links stretch: effective rate = min(wire, codec)
            ratio = max(est, link.bandwidth / codec.compress_bps)
            overhead += codec.compress_s(min(size, chunk))
        overlap = None
        if stream:
            overlap = gamma * (n - 1) / n if getattr(spec, "streaming",
                                                     False) else 0.0
        return edge_time(p, stream_exec_overlap=overlap, wire_ratio=ratio,
                         overhead_s=overhead)

    def _auto_speculation(self, link) -> float:
        """Resolve ``speculation="auto"`` from the link's observed
        variability (telemetry EWMA variance, netsim.LinkEstimate): a
        seed-only or steady link resolves to 0 — no backup is ever paid —
        and past the trigger the factor shrinks monotonically with the
        coefficient of variation, so flappier links re-dispatch earlier."""
        if link is None or link.samples == 0:
            return 0.0
        cv = link.variability
        if cv < SPECULATION_CV_TRIGGER:
            return 0.0
        return min(SPECULATION_MAX_FACTOR,
                   max(SPECULATION_MIN_FACTOR,
                       SPECULATION_MAX_FACTOR / (1.0 + cv)))

    def _finalize_edge(self, src: Optional[str], dst: str, pol: DataPolicy,
                       profile: Optional[EdgeProfile], spec,
                       src_spec=None) -> EdgePlan:
        """Resolve an ``auto`` policy (argmin over the candidate grid) and
        attach the Eq. 4 prediction for any profiled edge. ``src_spec`` is
        the producer's FunctionSpec (None for ingress edges) — a
        ``pipeline="auto"`` edge turns direct streaming on iff the producer
        can emit chunks mid-execution (``streaming_output``) and the
        consumer can eat them (``streaming``) over a direct-strategy hop."""
        link = self._link_estimate(profile) if profile is not None else None
        if pol.speculation == "auto":
            pol = pol.but(speculation=self._auto_speculation(link))
        if pol.strategy == "auto":
            if link is None:
                # no profile / no telemetry: conservative whole-blob default
                pol = pol.but(strategy="direct", stream=False,
                              compression="none", chunk_bytes=None)
            else:
                best = None
                best_t = math.inf
                for stream, comp, chunk in self._candidates():
                    t = self._candidate_time(spec, profile, link,
                                             stream=stream, compression=comp,
                                             chunk_bytes=chunk)
                    if t < best_t:          # strict: first-listed wins ties
                        best, best_t = (stream, comp, chunk), t
                stream, comp, chunk = best
                pol = pol.but(strategy="direct", stream=stream,
                              compression=comp, chunk_bytes=chunk)
        if pol.pipeline == "auto":
            enable = (src_spec is not None
                      and getattr(src_spec, "streaming_output", False)
                      and getattr(spec, "streaming", False)
                      and pol.strategy == "direct")
            # a pipelined edge is chunked by definition
            pol = pol.but(pipeline=enable, stream=pol.stream or enable)
        predicted = None
        if link is not None and pol.strategy == "direct":
            predicted = self._candidate_time(
                spec, profile, link, stream=pol.stream,
                compression=pol.compression, chunk_bytes=pol.chunk_bytes)
        return EdgePlan(src=src, dst=dst, policy=pol, predicted_s=predicted)

    def _candidates(self):
        """Deterministic candidate order: whole-blob first (ties keep the
        simpler mechanism), then streams over the chunk grid."""
        yield False, "none", None
        yield False, "lz4-like", None
        for comp in ("none", "lz4-like"):
            for chunk in self.chunk_grid:
                yield True, comp, chunk

    # ---------------------------------------------------------- re-planning
    def predict_remaining(self, wf, plan: ExecutionPlan,
                          remaining) -> Optional[Tuple[float, float]]:
        """Eq. 5 over the not-yet-dispatched subgraph, twice: ``(fresh,
        frozen)`` — the same per-edge Eq. 4 model under the plan's RESOLVED
        policies, evaluated against current telemetry (fresh) and as
        stamped at compile time (frozen). The ratio between the two is the
        drift signal (:func:`repro.core.model.drift`).

        Only edges that are comparable on both sides count — profiled at
        compile AND resolvable in telemetry now — so the ratio never mixes
        a stage into one sum but not the other. None when no remaining
        edge is comparable (no drift signal exists)."""
        fresh_total = frozen_total = 0.0
        comparable = False
        for name in remaining:
            sp = plan.stages.get(name)
            if sp is None:
                continue
            spec = wf.stages[name].spec
            fresh_preds, frozen_preds = [], []
            for e in sp.in_edges:
                prof = plan.profiles.get((e.src, e.dst))
                if e.predicted_s is None or prof is None:
                    continue
                link = self._link_estimate(prof)
                if link is None:
                    continue
                t = self._candidate_time(
                    spec, prof, link, stream=e.policy.stream,
                    compression=e.policy.compression,
                    chunk_bytes=e.policy.chunk_bytes)
                fresh_preds.append(t)
                frozen_preds.append(e.predicted_s)
            if fresh_preds:       # stage time = slowest in-edge (as compile)
                fresh_total += max(fresh_preds)
                frozen_total += max(frozen_preds)
                comparable = True
        if not comparable:
            return None
        return fresh_total, frozen_total

    def recompile_remaining(self, wf, plan: ExecutionPlan, dispatched,
                            profiles=None) -> ExecutionPlan:
        """Mid-flight recompile of ONLY the not-yet-dispatched subgraph:
        compile the whole workflow fresh (compile is pure and cheap —
        telemetry has folded the measured transfers in the meantime, auto
        edges re-run their argmin, ``speculation="auto"`` budgets refresh)
        and splice — every stage in ``dispatched`` keeps its CURRENT
        StagePlan untouched (in-flight transfers are never re-routed), the
        rest adopt the fresh one. The spliced plan's ``generation``
        increments; its predictions are the ones the runner stamps on
        records dispatched from here on."""
        profiles = dict(profiles) if profiles else dict(plan.profiles)
        fresh = self.compile(wf, profiles=profiles)
        stages = {name: (plan.stages[name] if name in dispatched
                         else fresh.stages[name])
                  for name in plan.order}
        return ExecutionPlan(workflow=plan.workflow, order=plan.order,
                             stages=stages, default=plan.default,
                             profiles=profiles,
                             generation=plan.generation + 1)

    @staticmethod
    def _merge(name: str, in_edges: Tuple[EdgePlan, ...]) -> DataPolicy:
        """Merge a stage's in-edge policies into the transport policy for
        its (joined) input. Strategies must agree — the stage's input has
        exactly one home in flight."""
        pols = [e.policy for e in in_edges]
        strategies = sorted({p.strategy for p in pols})
        if len(strategies) > 1:
            raise PlanError(
                f"stage {name!r}: in-edges declare conflicting strategies "
                f"{strategies}; a stage's input has one transport — set a "
                f"stage-level policy or align the edge policies")
        codecs = sorted({p.compression for p in pols} - {"none"})
        if len(codecs) > 1:
            raise PlanError(
                f"stage {name!r}: in-edges declare conflicting compression "
                f"codecs {codecs}; the stage's transport uses one wire "
                f"codec — align the edge policies")
        # locality_weight: None means "no opinion — scheduler default".
        # Positive overrides win by max; an explicit 0 (disable) only
        # sticks when EVERY edge says 0 — one edge opting out must not
        # silently strip the default credit the other edges rely on.
        weights = [p.locality_weight for p in pols
                   if p.locality_weight is not None]
        if any(w > 0 for w in weights):
            weight = max(weights)
        elif weights and len(weights) == len(pols):
            weight = 0.0
        else:
            weight = None
        # chunk_bytes: the stage's joined input moves once — the finest
        # declared grant wins (fair-share safety; a coarse edge never
        # degrades a fine one's pipelining)
        chunks = [p.chunk_bytes for p in pols if p.chunk_bytes is not None]
        # retry: merge toward the most resilient — most attempts, longest
        # backoff, tightest per-attempt timeout (the edge that needs a
        # deadline keeps it)
        retries = [p.retry for p in pols if p.retry is not None]
        retry = None
        if retries:
            timeouts = [r.timeout_s for r in retries
                        if r.timeout_s is not None]
            retry = RetryPolicy(
                max_attempts=max(r.max_attempts for r in retries),
                backoff_s=max(r.backoff_s for r in retries),
                timeout_s=min(timeouts) if timeouts else None)
        # pipeline: informational on the merged transport (pipelining is
        # enacted per EDGE by the runner — only single-dep consumers have
        # a pipe); the tightest declared high-water mark wins
        highwaters = [p.pipeline_highwater for p in pols
                      if p.pipeline_highwater is not None]
        merged = DataPolicy(
            strategy=strategies[0],
            stream=any(p.stream for p in pols),
            dedup=any(p.dedup for p in pols),
            compression=codecs[0] if codecs else "none",
            locality_weight=weight,
            speculation=max(p.speculation for p in pols),
            chunk_bytes=min(chunks) if chunks else None,
            retry=retry,
            pipeline=any(p.pipeline is True for p in pols),
            pipeline_highwater=min(highwaters) if highwaters else None)
        if any(p.prefetch for p in pols):
            # after the merge: prefetch requires dedup (DataPolicy enforces
            # it per edge, so the OR-ed transport has dedup=True here)
            merged = merged.but(prefetch=True)
        return merged


class AdaptivePlanner(Planner):
    """Planner wired to a live cluster: telemetry, scheduler α, and fabric
    grant overhead are read from the cluster, and profiles get their tier
    fallback filled from node names — the ROADMAP's "pick stream/compression
    per edge from the Eq. 4 per-edge terms + measured link state".

    Re-planning: compile is cheap and pure, so replanning between stages is
    just calling :meth:`compile` again — telemetry has folded the measured
    transfers in the meantime, and an auto edge's argmin follows."""

    def __init__(self, cluster, default: Optional[DataPolicy] = None, **kw):
        from repro.runtime.platform import Platform
        kw.setdefault("telemetry", cluster.telemetry)
        kw.setdefault("scheduling_s", cluster.scheduler.scheduling_s)
        kw.setdefault("trigger_s", Platform.REF_TRIGGER_OVERHEAD_S)
        kw.setdefault("chunk_overhead_s", cluster.network.chunk_overhead_s)
        super().__init__(default, **kw)
        self.cluster = cluster

    def compile(self, wf, profiles=None) -> ExecutionPlan:
        if profiles:
            filled = {}
            for key, prof in profiles.items():
                if prof.tiers is None and prof.src_node and prof.dst_node:
                    prof = dataclasses.replace(
                        prof, tiers=(self.cluster.tier_of(prof.src_node),
                                     self.cluster.tier_of(prof.dst_node)))
                filled[key] = prof
            profiles = filled
        return super().compile(wf, profiles)


__all__ = ["AdaptivePlanner", "CHUNK_GRID", "EdgePlan", "EdgeProfile",
           "ExecutionPlan", "Planner", "PlanError",
           "SPECULATION_CV_TRIGGER", "SPECULATION_MAX_FACTOR",
           "SPECULATION_MIN_FACTOR", "StagePlan", "WorkflowCycleError"]
