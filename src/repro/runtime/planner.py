"""Planner: compile a Workflow + DataPolicies into an immutable ExecutionPlan.

The plan is the single source of truth the execution stack consumes —
``WorkflowRunner`` dispatches from it, ``Platform``/``Scheduler`` receive
its placement hints, SDP/CSP/DataEngine receive its per-edge policies —
instead of each layer re-reading runner-global ``stream``/``dedup`` knobs.

Resolution order for the edge ``src -> dst`` (most specific wins, whole
policy at a time):

    edge policy (``after(src, policy=...)``)
      > dst stage policy (``stage(..., policy=...)``)
      > workflow default (``WorkflowBuilder(default_policy=...)``)
      > planner default (the legacy runner kwargs shim lands here)

Per stage the planner derives:
  * ``transport`` — the merged in-edge policy actually used to move the
    stage's (joined) input: strategies must agree (:class:`PlanError`
    otherwise), ``stream``/``dedup``/``prefetch`` are OR-ed, compression
    engages if any in-edge asks, ``speculation`` takes the max.
  * ``hint_deps`` — deps whose edge has ``dedup``: the stage's placement
    hint carries one digest per such dep (fan-in stages are scored on the
    SUM of resident inputs, not a joined-blob hash that resolves nowhere).
  * ``seed_output`` — True when any consumer edge has ``dedup``: the
    runner content-addresses the stage's output and seeds it on the node
    that produced it, so downstream placement can follow the bytes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from types import MappingProxyType
from typing import Mapping, Optional, Tuple

from repro.core.errors import PlanError, WorkflowCycleError  # noqa: F401
from repro.runtime.policy import DataPolicy


@dataclass(frozen=True)
class EdgePlan:
    """One resolved hop: ``src is None`` marks the workflow ingress."""
    src: Optional[str]
    dst: str
    policy: DataPolicy


@dataclass(frozen=True)
class StagePlan:
    name: str
    deps: Tuple[str, ...]
    transport: DataPolicy                  # merged in-edge policy
    in_edges: Tuple[EdgePlan, ...]         # one per dep (ingress for roots)
    hint_deps: Tuple[str, ...] = ()        # deps contributing digest hints
    seed_output: bool = False              # content-address + seed the output

    def edge_policy(self, src: Optional[str]) -> DataPolicy:
        for e in self.in_edges:
            if e.src == src:
                return e.policy
        raise KeyError(f"no edge {src!r} -> {self.name!r} in plan")


@dataclass(frozen=True)
class ExecutionPlan:
    """Immutable compiled form of a workflow: per-edge resolved policies,
    per-stage multi-input digest-hint structure, prefetch/speculation
    directives, and the (cycle-checked) topological order."""
    workflow: str
    order: Tuple[str, ...]
    stages: Mapping[str, StagePlan]
    default: DataPolicy = field(default_factory=DataPolicy)

    def __post_init__(self):
        object.__setattr__(self, "stages", MappingProxyType(dict(self.stages)))

    def edge_policy(self, src: Optional[str], dst: str) -> DataPolicy:
        return self.stages[dst].edge_policy(src)

    def uniform(self) -> Optional[DataPolicy]:
        """The single policy every edge resolves to, or None if mixed.
        (The legacy-kwargs shim compiles to a uniform plan by construction —
        the back-compat tests assert exactly this.)"""
        policies = {e.policy for sp in self.stages.values()
                    for e in sp.in_edges}
        return policies.pop() if len(policies) == 1 else None

    def label(self) -> str:
        """Storage label for traces: the uniform strategy, or ``mixed``."""
        strategies = {e.policy.strategy for sp in self.stages.values()
                      for e in sp.in_edges}
        return strategies.pop() if len(strategies) == 1 else "mixed"

    def describe(self) -> str:
        lines = [f"plan {self.workflow!r} ({len(self.stages)} stages, "
                 f"label={self.label()})"]
        for name in self.order:
            sp = self.stages[name]
            t = sp.transport
            lines.append(
                f"  {name}: deps={list(sp.deps)} strategy={t.strategy} "
                f"stream={t.stream} dedup={t.dedup} "
                f"compression={t.compression} prefetch={t.prefetch} "
                f"speculation={t.speculation} hint_deps={list(sp.hint_deps)} "
                f"seed_output={sp.seed_output}")
        return "\n".join(lines)


class Planner:
    def __init__(self, default: Optional[DataPolicy] = None):
        self.default = default or DataPolicy()

    def compile(self, wf) -> ExecutionPlan:
        """Compile ``wf`` (a :class:`~repro.runtime.workflow.Workflow`,
        hand-built or from :class:`WorkflowBuilder`). Raises
        :class:`WorkflowCycleError` on cyclic deps, :class:`PlanError` on
        incoherent policies."""
        order = tuple(wf.topo_order())          # raises on cycles
        wf_default = getattr(wf, "default_policy", None) or self.default

        def edge_pol(src: Optional[str], dst: str) -> DataPolicy:
            st = wf.stages[dst]
            if src is not None:
                pol = getattr(st, "dep_policies", None) or {}
                if src in pol:
                    return pol[src]
            stage_pol = getattr(st, "policy", None)
            return stage_pol if stage_pol is not None else wf_default

        stages = {}
        for name in order:
            st = wf.stages[name]
            deps = tuple(st.deps)
            if deps:
                in_edges = tuple(EdgePlan(d, name, edge_pol(d, name))
                                 for d in deps)
            else:
                in_edges = (EdgePlan(None, name, edge_pol(None, name)),)
            stages[name] = StagePlan(
                name=name, deps=deps,
                transport=self._merge(name, in_edges),
                in_edges=in_edges,
                hint_deps=tuple(e.src for e in in_edges
                                if e.src is not None and e.policy.dedup))
        # second pass: a stage seeds its output iff some consumer edge dedups
        for name in order:
            consumers = [e for sp in stages.values() for e in sp.in_edges
                         if e.src == name]
            if any(e.policy.dedup for e in consumers):
                sp = stages[name]
                stages[name] = StagePlan(
                    name=sp.name, deps=sp.deps, transport=sp.transport,
                    in_edges=sp.in_edges, hint_deps=sp.hint_deps,
                    seed_output=True)
        return ExecutionPlan(workflow=wf.name, order=order, stages=stages,
                             default=wf_default)

    @staticmethod
    def _merge(name: str, in_edges: Tuple[EdgePlan, ...]) -> DataPolicy:
        """Merge a stage's in-edge policies into the transport policy for
        its (joined) input. Strategies must agree — the stage's input has
        exactly one home in flight."""
        pols = [e.policy for e in in_edges]
        strategies = sorted({p.strategy for p in pols})
        if len(strategies) > 1:
            raise PlanError(
                f"stage {name!r}: in-edges declare conflicting strategies "
                f"{strategies}; a stage's input has one transport — set a "
                f"stage-level policy or align the edge policies")
        codecs = sorted({p.compression for p in pols} - {"none"})
        if len(codecs) > 1:
            raise PlanError(
                f"stage {name!r}: in-edges declare conflicting compression "
                f"codecs {codecs}; the stage's transport uses one wire "
                f"codec — align the edge policies")
        # locality_weight: None means "no opinion — scheduler default".
        # Positive overrides win by max; an explicit 0 (disable) only
        # sticks when EVERY edge says 0 — one edge opting out must not
        # silently strip the default credit the other edges rely on.
        weights = [p.locality_weight for p in pols
                   if p.locality_weight is not None]
        if any(w > 0 for w in weights):
            weight = max(weights)
        elif weights and len(weights) == len(pols):
            weight = 0.0
        else:
            weight = None
        merged = DataPolicy(
            strategy=strategies[0],
            stream=any(p.stream for p in pols),
            dedup=any(p.dedup for p in pols),
            compression=codecs[0] if codecs else "none",
            locality_weight=weight,
            speculation=max(p.speculation for p in pols))
        if any(p.prefetch for p in pols):
            # after the merge: prefetch requires dedup (DataPolicy enforces
            # it per edge, so the OR-ed transport has dedup=True here)
            merged = merged.but(prefetch=True)
        return merged


__all__ = ["EdgePlan", "ExecutionPlan", "Planner", "PlanError", "StagePlan",
           "WorkflowCycleError"]
