"""Network fabric: latency + token-bucket bandwidth channels.

Real bytes move through these channels (the caller hands over the payload),
so measured wall time = modeled latency + serialization time + actual copy
cost. Channels are thread-safe; concurrent transfers on one channel contend
for bandwidth (serialized grants), matching a shared NIC."""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

from repro.runtime.clock import Clock, DEFAULT_CLOCK

GBPS = 1e9 / 8  # bytes/sec per Gbit/s


@dataclass
class Channel:
    name: str
    bandwidth: float                  # bytes / simulated second
    latency: float                    # simulated seconds, per transfer
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def transfer_time(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bandwidth

    def transfer(self, payload: bytes) -> float:
        """Blocks for the modeled duration; returns simulated seconds."""
        t = self.transfer_time(len(payload))
        self.clock.sleep(self.latency)
        with self._lock:                      # bandwidth contention
            self.clock.sleep(t - self.latency)
        return t


@dataclass
class NetworkFabric:
    """Tiered edge/cloud links (per DESIGN §2: Edge-Cloud Continuum)."""
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)
    # Calibrated to the paper's testbed (4-core Xeon VMs on a MicroK8s LAN):
    # effective VM-to-VM goodput ~0.45 Gbit/s (Fig. 9a slope), WAN to cloud.
    tier_links: dict = field(default_factory=lambda: {
        ("edge", "edge"): (0.45 * GBPS, 0.0005),
        ("edge", "cloud"): (0.2 * GBPS, 0.0200),
        ("cloud", "edge"): (0.2 * GBPS, 0.0200),
        ("cloud", "cloud"): (10.0 * GBPS, 0.0002),
    })
    _channels: dict = field(default_factory=dict)

    def channel(self, src_node, dst_node) -> Channel:
        key = (src_node.name, dst_node.name)
        if key not in self._channels:
            bw, lat = self.tier_links[(src_node.tier, dst_node.tier)]
            if src_node.name == dst_node.name:
                bw, lat = 40.0 * GBPS, 0.00001       # loopback
            self._channels[key] = Channel(f"{key}", bw, lat, self.clock)
        return self._channels[key]
