"""Network fabric: latency + token-bucket bandwidth channels.

Real bytes move through these channels (the caller hands over the payload),
so measured wall time = modeled latency + serialization time + actual copy
cost. Channels are thread-safe; concurrent transfers on one channel contend
for bandwidth (serialized grants), matching a shared NIC.

Two grant granularities:
  * ``transfer``  — whole-blob: the bandwidth lock is held for the entire
    payload (head-of-line blocking; the pre-streaming baseline).
  * ``stream``    — chunk-granularity: the lock is held one chunk at a time
    (``chunk_bytes``, default ``DEFAULT_CHUNK_BYTES`` = 1 MiB), so concurrent
    transfers fair-share the link and a small transfer is never stuck behind
    a large one. Chunks are yielded as they "arrive", which is what lets the
    Truffle data plane pipeline storage-get -> relay -> buffer-append.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Iterator

from repro.runtime.clock import Clock, DEFAULT_CLOCK

GBPS = 1e9 / 8  # bytes/sec per Gbit/s

#: Streaming grant size. Large enough that per-chunk locking overhead is
#: negligible, small enough that time-to-first-chunk ~ chunk/bandwidth.
DEFAULT_CHUNK_BYTES = 1 << 20



@dataclass
class Channel:
    name: str
    bandwidth: float                  # bytes / simulated second
    latency: float                    # simulated seconds, per transfer
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    _busy_until: float = field(default=0.0, repr=False)  # wall, last grant end

    @staticmethod
    def wire_bytes(nbytes: int, wire_ratio: float = 1.0) -> int:
        """Bytes that actually cross the link for an ``nbytes`` payload.
        ``wire_ratio < 1`` models chunk compression (lz4-like on WAN
        tiers): the grant shrinks, the consumer still receives the
        original chunk (decompressed at arrival)."""
        if nbytes <= 0 or wire_ratio >= 1.0:
            return nbytes
        return max(1, int(nbytes * wire_ratio))

    def transfer_time(self, nbytes: int, wire_ratio: float = 1.0) -> float:
        return self.latency + self.wire_bytes(nbytes, wire_ratio) / self.bandwidth

    def _grant(self, nbytes: int, after: float = None) -> float:
        """Reserve serialized link time for ``nbytes``; returns the wall
        deadline when those bytes have arrived. Grants queue back-to-back
        (``_busy_until``), so concurrent transfers contend for bandwidth.

        ``after`` chains grants within one stream: the next chunk starts at
        the previous chunk's deadline even if the requester woke up late —
        the wire kept sending (kernel/NIC buffering). Deadline-chained sleeps
        self-correct OS sleep overshoot; without this a 128-chunk stream
        accumulates ~a timer quantum of drift per chunk. A fresh transfer
        (``after=None``) can never start in the past."""
        wall = (nbytes / self.bandwidth) * self.clock.scale
        with self._lock:
            floor = time.monotonic() if after is None else after
            start = max(floor, self._busy_until)
            self._busy_until = start + wall
            return self._busy_until

    def transfer(self, payload: bytes, wire_ratio: float = 1.0) -> float:
        """Whole-blob: blocks for the modeled duration holding the bandwidth
        grant for the full payload. Returns simulated seconds."""
        t = self.transfer_time(len(payload), wire_ratio)
        self.clock.sleep(self.latency)
        self.clock.sleep_until(self._grant(self.wire_bytes(len(payload),
                                                           wire_ratio)))
        return t

    def transfer_chunk(self, nbytes: int, *, pay_latency: bool = False,
                       after: float = None) -> float:
        """Grant bandwidth for one chunk only (fair-share building block).
        Returns the wall deadline — pass it back as ``after`` on the next
        chunk to chain a stream's grants."""
        if pay_latency:
            self.clock.sleep(self.latency)
        deadline = self._grant(nbytes, after=after)
        self.clock.sleep_until(deadline)
        return deadline

    def stream(self, payload: bytes,
               chunk_bytes: int = DEFAULT_CHUNK_BYTES,
               wire_ratio: float = 1.0) -> Iterator[memoryview]:
        """Chunk-granularity transfer: yields each chunk after its modeled
        arrival. Bandwidth is granted per chunk, so concurrent streams
        interleave instead of head-of-line blocking. Chunks are zero-copy
        ``memoryview`` slices (the blob path hands over the payload object
        unchanged — same semantics, measured time stays modeled time).
        ``wire_ratio < 1`` grants only the compressed size per chunk (WAN
        chunk compression); the consumer still receives the full chunk."""
        self.clock.sleep(self.latency)
        view = memoryview(payload)
        deadline = None
        for off in range(0, len(payload), chunk_bytes):
            chunk = view[off:off + chunk_bytes]
            deadline = self.transfer_chunk(
                self.wire_bytes(len(chunk), wire_ratio), after=deadline)
            yield chunk
        if deadline is None:                  # empty payload: one empty chunk
            yield b""


@dataclass
class NetworkFabric:
    """Tiered edge/cloud links (per DESIGN §2: Edge-Cloud Continuum)."""
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)
    # Calibrated to the paper's testbed (4-core Xeon VMs on a MicroK8s LAN):
    # effective VM-to-VM goodput ~0.45 Gbit/s (Fig. 9a slope), WAN to cloud.
    tier_links: dict = field(default_factory=lambda: {
        ("edge", "edge"): (0.45 * GBPS, 0.0005),
        ("edge", "cloud"): (0.2 * GBPS, 0.0200),
        ("cloud", "edge"): (0.2 * GBPS, 0.0200),
        ("cloud", "cloud"): (10.0 * GBPS, 0.0002),
    })
    _channels: dict = field(default_factory=dict)

    def channel(self, src_node, dst_node) -> Channel:
        key = (src_node.name, dst_node.name)
        if key not in self._channels:
            bw, lat = self.tier_links[(src_node.tier, dst_node.tier)]
            if src_node.name == dst_node.name:
                bw, lat = 40.0 * GBPS, 0.00001       # loopback
            self._channels[key] = Channel(f"{key}", bw, lat, self.clock)
        return self._channels[key]
