"""Network fabric: latency + token-bucket bandwidth channels.

Real bytes move through these channels (the caller hands over the payload),
so measured wall time = modeled latency + serialization time + actual copy
cost. Channels are thread-safe; concurrent transfers on one channel contend
for bandwidth (serialized grants), matching a shared NIC.

Two grant granularities:
  * ``transfer``  — whole-blob: the bandwidth lock is held for the entire
    payload (head-of-line blocking; the pre-streaming baseline).
  * ``stream``    — chunk-granularity: the lock is held one chunk at a time
    (``chunk_bytes``, default ``DEFAULT_CHUNK_BYTES`` = 1 MiB), so concurrent
    transfers fair-share the link and a small transfer is never stuck behind
    a large one. Chunks are yielded as they "arrive", which is what lets the
    Truffle data plane pipeline storage-get -> relay -> buffer-append.

Per-grant overhead (``chunk_overhead_s``): each bandwidth grant pays a small
fixed cost (framing, syscall, per-chunk buffer handling). A whole-blob
transfer pays it once; a stream pays it per chunk — which is exactly the
cost that makes the adaptive planner's chunk-size grid a real trade-off
(small chunks start the pipeline earlier but pay more per-chunk overhead).

Producer pacing (``pace_bps``): an upstream stage that can only produce
bytes at a bounded rate — in practice the chunk codec's compression
throughput — caps the stream's effective rate at ``min(bandwidth_rate,
pace_bps)``. The wire idles during codec stalls instead of the grant
pretending the link was the bottleneck.

Link telemetry (:class:`LinkTelemetry`): every grant is reported to an
optional telemetry sink, which keeps seeded-deterministic EWMA estimates of
each channel's *effective* bandwidth and RTT (plus observed codec ratios,
fed by the data plane). The adaptive planner reads these estimates instead
of the fabric's configured constants, so a degraded link (fault injection,
congestion) steers future plans. Estimates derive from the modeled grant
arithmetic, not wall-clock jitter — deterministic under tests by
construction. Queue wait is excluded on purpose: queuing is load, not link
capacity.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from itertools import accumulate
from typing import Dict, Iterator, Optional, Tuple

from repro.core.errors import LinkDownError
from repro.runtime.clock import Clock, DEFAULT_CLOCK

GBPS = 1e9 / 8  # bytes/sec per Gbit/s

#: Streaming grant size. Large enough that per-chunk locking overhead is
#: negligible, small enough that time-to-first-chunk ~ chunk/bandwidth.
DEFAULT_CHUNK_BYTES = 1 << 20

#: Default per-grant overhead on fabric channels (framing + per-chunk
#: buffer handling); individual ``Channel``s default to 0 so raw-channel
#: math stays exact unless a fabric opts in.
FABRIC_CHUNK_OVERHEAD_S = 2e-4

#: How many chunk grants a stream reserves per bandwidth-lock hold.
#: Total modeled time is unchanged (grants are back-to-back either way);
#: what changes is lock traffic (÷16) and the granularity at which a racing
#: reconfigure or a competing stream can slot in (16 chunks, not 1 — small
#: enough that fair-sharing and mid-stream fault injection still work).
STREAM_GRANT_BATCH = 16


@dataclass(frozen=True)
class LinkEstimate:
    """Telemetry's current belief about one link (sim-seconds domain).

    ``bandwidth_var``/``rtt_var`` are EWMA variances of the observations
    around the running mean — a link that keeps its modeled grant rate has
    ~0 variance; a flapping or congested one does not. ``variability`` is
    the dimensionless coefficient of variation the adaptive speculation
    budget keys on (max over bandwidth and RTT, so either kind of
    instability counts)."""
    bandwidth: float              # bytes / simulated second (EWMA)
    rtt: float                    # simulated seconds per transfer (EWMA)
    samples: int = 0              # observations folded in (0 = seed only)
    bandwidth_var: float = 0.0    # EWMA variance of bandwidth observations
    rtt_var: float = 0.0          # EWMA variance of RTT observations

    @property
    def variability(self) -> float:
        """Coefficient of variation, max over bandwidth and RTT (0 for a
        seed-only or perfectly steady link)."""
        cvs = []
        if self.bandwidth > 0:
            cvs.append(self.bandwidth_var ** 0.5 / self.bandwidth)
        if self.rtt > 0:
            cvs.append(self.rtt_var ** 0.5 / self.rtt)
        return max(cvs) if cvs else 0.0


class LinkTelemetry:
    """Passive per-link measurement: EWMA effective bandwidth + RTT per
    channel (node pair) and per tier pair, plus observed codec wire ratios.

    Channels report each grant (``observe_transfer``); the data plane
    reports each codec engagement (``observe_codec``). ``seed`` installs
    priors (the fabric's configured tier links) so the planner has
    estimates before any traffic. All updates are EWMA with a fixed
    ``alpha`` — deterministic given the observation sequence, which is
    itself derived from modeled grant arithmetic, so plans compiled against
    frozen telemetry are reproducible.
    """

    def __init__(self, alpha: float = 0.25):
        self.alpha = alpha
        self._lock = threading.Lock()
        # key -> [bw_ewma, rtt_ewma, samples, bw_var_ewma, rtt_var_ewma]
        self._links: Dict[Tuple[str, str], list] = {}
        self._tiers: Dict[Tuple[str, str], list] = {}
        self._codecs: Dict[str, list] = {}          # name -> [ratio, samples]
        self.stats = {"observations": 0, "codec_observations": 0}

    # ------------------------------------------------------------- updates
    def seed(self, *, link_key: Optional[Tuple[str, str]] = None,
             tier_key: Optional[Tuple[str, str]] = None,
             bandwidth: float, rtt: float) -> None:
        """Install a prior (samples=0, zero variance). Reseeding resets the
        estimate — used after reconfiguring fabric links."""
        with self._lock:
            if link_key is not None:
                self._links[link_key] = [bandwidth, rtt, 0, 0.0, 0.0]
            if tier_key is not None:
                self._tiers[tier_key] = [bandwidth, rtt, 0, 0.0, 0.0]

    def reseed(self, tier_links: Dict[Tuple[str, str],
                                      Tuple[float, float]]) -> None:
        """Atomically replace every tier prior in ONE lock hold. A
        concurrent :meth:`snapshot` (or planner compile) sees either the
        old configuration or the new one for ALL tiers — never a torn mix
        of half-reseeded priors."""
        with self._lock:
            for tiers, (bw, lat) in tier_links.items():
                self._tiers[tuple(tiers)] = [bw, lat, 0, 0.0, 0.0]

    def _fold(self, table: dict, key, bandwidth: Optional[float],
              rtt: Optional[float]) -> None:
        ent = table.get(key)
        if ent is None:      # first evidence for an unseeded link: adopt it
            ent = table[key] = [bandwidth or 0.0, rtt or 0.0, 0, 0.0, 0.0]
        a = self.alpha
        # EWMA mean + EWMA variance (West's recursion): a steady link decays
        # toward zero variance; a flapping one keeps a spread — which is the
        # signal the adaptive speculation budget keys on
        if bandwidth is not None:
            diff = bandwidth - ent[0]
            ent[0] += a * diff
            ent[3] = (1 - a) * (ent[3] + a * diff * diff)
        if rtt is not None:
            diff = rtt - ent[1]
            ent[1] += a * diff
            ent[4] = (1 - a) * (ent[4] + a * diff * diff)
        ent[2] += 1

    def observe_transfer(self, link_key: Optional[Tuple[str, str]],
                         tier_key: Optional[Tuple[str, str]],
                         nbytes: int, seconds: float,
                         rtt: Optional[float] = None) -> None:
        """One grant's worth of evidence: ``nbytes`` crossed in ``seconds``
        (sim). ``rtt`` is reported once per transfer/stream, not per chunk."""
        if nbytes <= 0 or seconds <= 0:
            return
        bw = nbytes / seconds
        with self._lock:
            if link_key is not None:
                self._fold(self._links, link_key, bw, rtt)
            if tier_key is not None:
                self._fold(self._tiers, tier_key, bw, rtt)
            self.stats["observations"] += 1

    def _fold_n(self, table: dict, key, bandwidth: float,
                count: int) -> None:
        """Fold ``count`` IDENTICAL bandwidth observations in O(1) via the
        EWMA recursion's closed form. With e_{i+1} = e_i + a(bw - e_i) and
        v_{i+1} = (1-a)(v_i + a d_i^2), identical observations give
        d_i = r^i d_0 (r = 1-a), hence e_k = bw - r^k d_0 and
        v_k = r^k v_0 + d_0^2 r^k (1 - r^k) — equal to the sequential fold
        to float epsilon (verified against the recursion), sample count
        exact."""
        ent = table.get(key)
        if ent is None:
            # fresh entry adopts the evidence (same as _fold's seeding:
            # every fold of bw into a mean already AT bw is a no-op)
            table[key] = [bandwidth, 0.0, count, 0.0, 0.0]
            return
        r = 1.0 - self.alpha
        rk = r ** count
        d0 = bandwidth - ent[0]
        ent[0] = bandwidth - rk * d0
        ent[3] = rk * ent[3] + d0 * d0 * rk * (1.0 - rk)
        ent[2] += count

    def observe_transfer_n(self, link_key: Optional[Tuple[str, str]],
                           tier_key: Optional[Tuple[str, str]],
                           nbytes: int, seconds: float, count: int,
                           rtt: Optional[float] = None) -> None:
        """Fold ``count`` identical grants in ONE lock hold (a batch of
        same-size stream chunks). With no ``rtt`` the whole batch collapses
        through the closed-form :meth:`_fold_n`; when the batch carries the
        stream's once-per-transfer ``rtt`` the first observation folds
        normally and the remaining ``count - 1`` collapse. Counts stay
        exact; means/variances match the sequential fold to float
        epsilon."""
        if nbytes <= 0 or seconds <= 0 or count <= 0:
            return
        bw = nbytes / seconds
        with self._lock:
            for table, key in ((self._links, link_key),
                               (self._tiers, tier_key)):
                if key is None:
                    continue
                if rtt is None:
                    self._fold_n(table, key, bw, count)
                else:
                    self._fold(table, key, bw, rtt)
                    if count > 1:
                        self._fold_n(table, key, bw, count - 1)
            self.stats["observations"] += count

    def observe_codec(self, name: str, ratio: float) -> None:
        """Observed wire/payload ratio of one codec engagement."""
        with self._lock:
            ent = self._codecs.get(name)
            if ent is None:
                self._codecs[name] = [ratio, 1]
            else:
                ent[0] = (1 - self.alpha) * ent[0] + self.alpha * ratio
                ent[1] += 1
            self.stats["codec_observations"] += 1

    # ------------------------------------------------------------- queries
    def link(self, src: Optional[str] = None, dst: Optional[str] = None,
             tiers: Optional[Tuple[str, str]] = None
             ) -> Optional[LinkEstimate]:
        """Best available estimate for a hop: node pair > tier pair. None
        when telemetry has neither seen nor been seeded with the link."""
        with self._lock:
            ent = None
            if src is not None and dst is not None:
                ent = self._links.get((src, dst))
            if ent is None and tiers is not None:
                ent = self._tiers.get(tuple(tiers))
            if ent is None:
                return None
            return LinkEstimate(bandwidth=ent[0], rtt=ent[1], samples=ent[2],
                                bandwidth_var=ent[3], rtt_var=ent[4])

    def codec_ratio(self, name: str,
                    default: Optional[float] = None) -> Optional[float]:
        with self._lock:
            ent = self._codecs.get(name)
            return ent[0] if ent is not None else default

    def snapshot(self) -> dict:
        """Frozen copy of every estimate (tests / dashboards)."""
        with self._lock:
            return {
                "links": {k: LinkEstimate(*v) for k, v in self._links.items()},
                "tiers": {k: LinkEstimate(*v) for k, v in self._tiers.items()},
                "codecs": {k: tuple(v) for k, v in self._codecs.items()},
            }


@dataclass
class Channel:
    name: str
    bandwidth: float                  # bytes / simulated second
    latency: float                    # simulated seconds, per transfer
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)
    chunk_overhead_s: float = 0.0     # per-grant framing/handling cost
    link_key: Optional[Tuple[str, str]] = None     # telemetry: node pair
    tier_key: Optional[Tuple[str, str]] = None     # telemetry: tier pair
    telemetry: Optional[LinkTelemetry] = None
    down: bool = False                # endpoint node dark: transfers fail fast
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    _busy_until: float = field(default=0.0, repr=False)  # wall, last grant end

    @staticmethod
    def wire_bytes(nbytes: int, wire_ratio: float = 1.0) -> int:
        """Bytes that actually cross the link for an ``nbytes`` payload.
        ``wire_ratio < 1`` models chunk compression (lz4-like on WAN
        tiers): the grant shrinks, the consumer still receives the
        original chunk (decompressed at arrival)."""
        if nbytes <= 0 or wire_ratio >= 1.0:
            return nbytes
        return max(1, int(nbytes * wire_ratio))

    def transfer_time(self, nbytes: int, wire_ratio: float = 1.0) -> float:
        return self.latency + self.chunk_overhead_s \
            + self.wire_bytes(nbytes, wire_ratio) / self.bandwidth

    def _link_params(self) -> Tuple[float, float]:
        """One consistent (bandwidth, latency) read."""
        with self._lock:
            return self.bandwidth, self.latency

    def reconfigure(self, bandwidth: Optional[float] = None,
                    latency: Optional[float] = None) -> None:
        """Atomically change the link (fault injection, fabric reseed): a
        concurrent grant sees either the old or the new configuration —
        never the bandwidth of one and the latency of the other, and never
        a grant deadline computed from a bandwidth that changed under it."""
        with self._lock:
            if bandwidth is not None:
                self.bandwidth = bandwidth
            if latency is not None:
                self.latency = latency

    def set_down(self, down: bool = True) -> None:
        """Mark/unmark an endpoint node as dark (crash semantics)."""
        with self._lock:
            self.down = down

    def _check_up(self) -> None:
        if self.down:
            raise LinkDownError(f"link {self.name} is down "
                                f"(endpoint node crashed)")

    def _observe(self, nbytes: int, seconds: float,
                 rtt: Optional[float] = None) -> None:
        if self.telemetry is not None:
            self.telemetry.observe_transfer(self.link_key, self.tier_key,
                                            nbytes, seconds, rtt=rtt)

    def _observe_n(self, nbytes: int, seconds: float, count: int,
                   rtt: Optional[float] = None) -> None:
        if self.telemetry is not None:
            self.telemetry.observe_transfer_n(self.link_key, self.tier_key,
                                              nbytes, seconds, count,
                                              rtt=rtt)

    def _grant(self, nbytes: int, after: float = None,
               bw: Optional[float] = None) -> Tuple[float, float]:
        """Reserve serialized link time for ``nbytes`` (+ the per-grant
        overhead); returns ``(deadline, bandwidth)`` — the wall deadline
        when those bytes have arrived plus the bandwidth the grant was
        priced at, so the caller's telemetry observation cannot tear
        against a concurrent :meth:`reconfigure`. ``bw`` pins the price to
        a configuration the caller already committed to (a whole-blob
        transfer that has slept that configuration's latency); by default
        the current configuration is read under the lock. Grants queue
        back-to-back (``_busy_until``), so concurrent transfers contend
        for bandwidth.

        ``after`` chains grants within one stream: the next chunk starts at
        the previous chunk's deadline even if the requester woke up late —
        the wire kept sending (kernel/NIC buffering). Deadline-chained sleeps
        self-correct OS sleep overshoot; without this a 128-chunk stream
        accumulates ~a timer quantum of drift per chunk. A fresh transfer
        (``after=None``) can never start in the past."""
        with self._lock:
            if bw is None:
                bw = self.bandwidth
            wall = (nbytes / bw + self.chunk_overhead_s) * self.clock.scale
            floor = time.monotonic() if after is None else after
            start = max(floor, self._busy_until)
            self._busy_until = start + wall
            return self._busy_until, bw

    def grant_chunks(self, sizes, after: float = None
                     ) -> Tuple[list, float]:
        """Reserve serialized link time for a RUN of chunks in ONE lock
        hold: returns ``(deadlines, bandwidth)`` — one wall deadline per
        chunk, back-to-back from ``after`` (or now), all priced at the
        configuration current when the batch was reserved. N chunks cost
        one lock acquisition instead of N; the trade is that a racing
        :meth:`reconfigure` applies from the NEXT batch instead of the
        next chunk (streams bound batches to ``STREAM_GRANT_BATCH`` so a
        fault is still felt within a handful of chunks)."""
        with self._lock:
            bw = self.bandwidth
            if not sizes:
                return [], bw
            floor = time.monotonic() if after is None else after
            start = max(floor, self._busy_until)
            oh = self.chunk_overhead_s
            scale = self.clock.scale
            n0 = sizes[0]
            if sizes.count(n0) == len(sizes):
                # equal-size run (every batch but a stream's tail): one
                # per-chunk wall, C-speed cumulative sum — float-identical
                # to the sequential loop (same adds, same order)
                per = (n0 / bw + oh) * scale
                deadlines = list(accumulate([per] * len(sizes),
                                            initial=start))[1:]
                start = deadlines[-1]
            else:
                deadlines = []
                for n in sizes:
                    start += (n / bw + oh) * scale
                    deadlines.append(start)
            self._busy_until = start
            return deadlines, bw

    def transfer(self, payload: bytes, wire_ratio: float = 1.0,
                 pace_bps: Optional[float] = None) -> float:
        """Whole-blob: blocks for the modeled duration holding the bandwidth
        grant for the full payload. Returns simulated seconds. ``pace_bps``
        bounds the producer's rate (codec-bound transfers finish at the
        codec's throughput, not the wire's). The (bandwidth, latency) pair
        is read in ONE lock hold and used throughout: a reconfigure racing
        this transfer applies to the next one, and telemetry never sees
        the latency of one configuration paired with the bandwidth of
        another."""
        self._check_up()
        bw, lat = self._link_params()
        wire = self.wire_bytes(len(payload), wire_ratio)
        self.clock.sleep(lat)
        pace_wall = None
        if pace_bps:
            pace_wall = time.monotonic() \
                + (len(payload) / pace_bps) * self.clock.scale
        deadline, bw = self._grant(wire, bw=bw)
        wire_time = wire / bw + self.chunk_overhead_s
        t = lat + wire_time
        surplus = 0.0
        if pace_wall is not None and pace_wall > deadline:
            deadline = pace_wall          # producer (codec) is the bottleneck
            surplus = max(0.0, len(payload) / pace_bps - wire_time)
        self.clock.sleep_until(deadline)
        # report pure wire seconds (no grant overhead): the planner models
        # chunk_overhead_s as its own additive term — folding it into the
        # bandwidth estimate would double-count it per candidate chunk size.
        # The observation uses the bandwidth the grant was PRICED at, so a
        # racing reconfigure cannot make telemetry record a rate that never
        # carried these bytes.
        self._observe(wire, wire / bw, rtt=lat)
        return t + surplus

    def transfer_chunk(self, nbytes: int, *, pay_latency: bool = False,
                       after: float = None) -> float:
        """Grant bandwidth for one chunk only (fair-share building block).
        Returns the wall deadline — pass it back as ``after`` on the next
        chunk to chain a stream's grants."""
        self._check_up()
        if pay_latency:
            _, lat = self._link_params()
            self.clock.sleep(lat)
        deadline, _ = self._grant(nbytes, after=after)
        self.clock.sleep_until(deadline)
        return deadline

    def transfer_chunk_timed(self, nbytes: int, *, pay_latency: bool = False,
                             after: float = None) -> Tuple[float, float]:
        """Like :meth:`transfer_chunk`, but also returns the chunk's
        CHANNEL-DERIVED simulated seconds: queue wait (grant contention)
        + service time + per-grant overhead + the latency if paid. Chained
        per-chunk elapsed sums to the stream's true wall time — unlike a
        hand-summed ``Σ nbytes/bandwidth``, which ignores contention. At
        clock scale 0 deadlines carry no wall information, so the modeled
        uncontended service time is reported instead."""
        self._check_up()
        t = 0.0
        if pay_latency:
            _, lat = self._link_params()
            self.clock.sleep(lat)
            t += lat
        floor = time.monotonic() if after is None else after
        deadline, bw = self._grant(nbytes, after=after)
        if self.clock.scale:
            t += max(0.0, deadline - floor) / self.clock.scale
        else:
            t += nbytes / bw + self.chunk_overhead_s
        self.clock.sleep_until(deadline)
        return deadline, t

    def stream(self, payload: bytes,
               chunk_bytes: int = DEFAULT_CHUNK_BYTES,
               wire_ratio: float = 1.0,
               pace_bps: Optional[float] = None) -> Iterator[memoryview]:
        """Chunk-granularity transfer: yields each chunk after its modeled
        arrival. Bandwidth is granted per chunk, so concurrent streams
        interleave instead of head-of-line blocking. Chunks are zero-copy
        ``memoryview`` slices (the blob path hands over the payload object
        unchanged — same semantics, measured time stays modeled time).
        ``wire_ratio < 1`` grants only the compressed size per chunk (WAN
        chunk compression); the consumer still receives the full chunk.
        ``pace_bps`` bounds the producer's chunk rate (the codec): when the
        codec is slower than the wire, arrivals pace at the codec and the
        wire idles between grants. Pacing uses absolute wall deadlines
        (like the grants themselves) so OS sleep overshoot does not
        accumulate across chunks."""
        self._check_up()
        _, lat = self._link_params()
        self.clock.sleep(lat)
        view = memoryview(payload)
        deadline = None
        pace_wall = time.monotonic() if pace_bps else None
        first = True
        offsets = range(0, len(payload), chunk_bytes)
        for base in range(0, len(offsets), STREAM_GRANT_BATCH):
            # a node crash mid-stream fails the remaining chunks fast
            # instead of pricing bytes against a dead endpoint
            self._check_up()
            chunks = [view[off:off + chunk_bytes]
                      for off in offsets[base:base + STREAM_GRANT_BATCH]]
            wires = [self.wire_bytes(len(c), wire_ratio) for c in chunks]
            # batched grants: one lock hold reserves the whole run of
            # chunks. Unlike transfer(), a mid-stream reconfigure (fault
            # injection) DOES still apply — from the next batch on — and
            # each observation reports the bandwidth ITS OWN batch was
            # priced at (no torn estimates; the once-per-stream RTT was
            # genuinely slept at stream start).
            deadlines, bw = self.grant_chunks(wires, after=deadline)
            deadline = deadlines[-1]
            # fold the batch's telemetry in one lock hold per run of
            # equal-size chunks (at most two runs: full chunks + the tail).
            # Pure wire seconds — see transfer(): overhead is the planner's
            # own additive term, not part of the bandwidth estimate.
            run_start = 0
            for i in range(1, len(wires) + 1):
                if i == len(wires) or wires[i] != wires[run_start]:
                    w = wires[run_start]
                    self._observe_n(w, w / bw, i - run_start,
                                    rtt=lat if first else None)
                    first = False
                    run_start = i
            for chunk, dl in zip(chunks, deadlines):
                self._check_up()
                self.clock.sleep_until(dl)
                if pace_wall is not None:
                    # codec finishes chunk k at start + Σ chunk/pace
                    # (absolute)
                    pace_wall += (len(chunk) / pace_bps) * self.clock.scale
                    self.clock.sleep_until(pace_wall)
                yield chunk
        if deadline is None:                  # empty payload: one empty chunk
            yield b""


@dataclass
class NetworkFabric:
    """Tiered edge/cloud links (per DESIGN §2: Edge-Cloud Continuum)."""
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)
    # Calibrated to the paper's testbed (4-core Xeon VMs on a MicroK8s LAN):
    # effective VM-to-VM goodput ~0.45 Gbit/s (Fig. 9a slope), WAN to cloud.
    tier_links: dict = field(default_factory=lambda: {
        ("edge", "edge"): (0.45 * GBPS, 0.0005),
        ("edge", "cloud"): (0.2 * GBPS, 0.0200),
        ("cloud", "edge"): (0.2 * GBPS, 0.0200),
        ("cloud", "cloud"): (10.0 * GBPS, 0.0002),
    })
    telemetry: Optional[LinkTelemetry] = None
    chunk_overhead_s: float = FABRIC_CHUNK_OVERHEAD_S
    _channels: dict = field(default_factory=dict)
    _down_nodes: set = field(default_factory=set)

    def channel(self, src_node, dst_node) -> Channel:
        key = (src_node.name, dst_node.name)
        if key not in self._channels:
            tier_key = (src_node.tier, dst_node.tier)
            bw, lat = self.tier_links[tier_key]
            if src_node.name == dst_node.name:
                bw, lat = 40.0 * GBPS, 0.00001       # loopback
                tier_key = None    # don't fold loopback into tier estimates
            self._channels[key] = Channel(
                f"{key}", bw, lat, self.clock,
                chunk_overhead_s=self.chunk_overhead_s,
                link_key=key, tier_key=tier_key, telemetry=self.telemetry,
                down=bool(self._down_nodes & set(key)))
        return self._channels[key]

    def set_node_down(self, node_name: str, down: bool = True) -> None:
        """Flip every channel touching ``node_name`` (existing AND future —
        channels are memoized lazily) to/from the dark state. In-flight
        streams through those channels fail at their next chunk grant."""
        if down:
            self._down_nodes.add(node_name)
        else:
            self._down_nodes.discard(node_name)
        for key, ch in list(self._channels.items()):
            if node_name in key:
                ch.set_down(down)
