"""Placement scheduler (Kubernetes analogue). Emits ``scheduling.placed``
events on the bus — the Truffle Watcher's entire CSP mechanism hangs off
the fact that the host is known HERE, long before the sandbox is up.

Locality-aware placement: a request carrying a :class:`PlacementHint`
(one ``(digest, size)`` per input — fan-in stages hint each dep
separately) is scored against the cluster-wide
:class:`~repro.runtime.registry.DigestRegistry` — a node holding input
bytes gets a load credit of ``weight × resident_fraction``, where the
fraction is the size-weighted SUM over all hinted inputs. Fan-out stages
and repeated inputs land *on the data* and the CSP/SDP transfer
degenerates to a zero-cost local alias; a fan-in stage lands on the node
holding the biggest share of its inputs. Load skew still wins once it
exceeds the credit; affinity pins override everything.

The hint also carries the compiled :class:`~repro.runtime.planner.
ExecutionPlan`'s per-edge directives for this placement:
``weight`` (a per-edge ``DataPolicy.locality_weight`` override),
``prefetch`` (registry-driven: placing OFF the data kicks the relay at
placement-decision time, not at trigger time), and ``avoid`` (speculative
backups steer away from the straggler's node for failure independence).

Knobs: ``scheduling_s`` (α, the activator + kube-scheduler path) and
``locality_weight`` (load units a fully resident input is worth; 0 disables
locality and recovers pure least-loaded placement).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.errors import NodeCrashError
from repro.runtime.function import FunctionSpec, Request


class _PlacementReq:
    """One enqueued placement decision (the combining queue's unit).

    ``holders`` is the caller's registry snapshot — taken BEFORE the
    request enters the queue so the batch leader never reads the registry
    under the scheduler lock."""

    __slots__ = ("spec", "inv_id", "hint", "record", "holders",
                 "node", "error", "locality_hit", "resident",
                 "speculative", "done")

    def __init__(self, spec, inv_id, hint, record, holders, done=None):
        self.spec = spec
        self.inv_id = inv_id
        self.hint = hint
        self.record = record
        self.holders = holders
        self.node = None
        self.error: Optional[BaseException] = None
        self.locality_hit = False
        self.resident = 0
        self.speculative = False
        # the uncontended inline path passes a no-op ``done`` — nobody
        # parks on a request its own thread is about to decide
        self.done = done if done is not None else threading.Event()


class _NoopDone:
    """Stand-in for ``threading.Event`` on the inline placement path:
    allocating a real Event (a Condition + two locks) costs more than the
    placement decision itself, and no other thread ever waits on it."""
    __slots__ = ()

    def set(self) -> None:
        pass


_NOOP_DONE = _NoopDone()


@dataclass(frozen=True)
class PlacementHint:
    """Where-the-bytes-live (and how-to-place) hint for one decision.

    ``digest``/``size`` is the legacy single-input form; ``inputs`` is the
    per-dep form (((digest, size), ...)). ``input_hints()`` canonicalizes.
    """
    digest: Optional[str] = None
    size: int = 0
    inputs: Optional[Tuple[Tuple[str, int], ...]] = None
    weight: Optional[float] = None        # per-edge locality_weight override
    prefetch: bool = False                # kick relay at placement decision
    compression: str = "none"             # wire codec for a prefetch relay
    avoid: Optional[str] = None           # steer off this node (speculation)

    def input_hints(self) -> Tuple[Tuple[str, int], ...]:
        if self.inputs:
            return tuple((d, s) for d, s in self.inputs if d is not None)
        if self.digest is not None:
            return ((self.digest, self.size),)
        return ()

    @classmethod
    def from_policy(cls, policy, digest: Optional[str], size: int,
                    inputs, avoid: Optional[str]) -> Optional["PlacementHint"]:
        """The compiled plan's placement directives for one edge — the ONE
        construction CSP and SDP share (the two paths must not diverge).

        ``digest`` content-addresses the bytes the data path will actually
        ship/land (for a fan-in pass: the JOINED blob, seeded on the source
        node); ``inputs`` are the per-dep hints. Both signals matter: the
        per-dep digests credit nodes holding parts, and the joined digest
        credits the source node where placement degenerates to a
        zero-transfer alias — so the joined pair is appended to ``inputs``
        rather than replaced by them."""
        if inputs is not None and digest is not None \
                and all(d != digest for d, _ in inputs):
            inputs = tuple(inputs) + ((digest, size),)
        elif inputs is None and digest is not None:
            inputs = ((digest, size),)
        if inputs is None and avoid is None and not policy.prefetch \
                and policy.locality_weight is None:
            return None
        return cls(digest=digest, size=size, inputs=inputs,
                   weight=policy.locality_weight, prefetch=policy.prefetch,
                   compression=policy.compression, avoid=avoid)

    @classmethod
    def from_request(cls, request: Request) -> Optional["PlacementHint"]:
        """Hint from the request's content ref + meta; None when there is
        nothing to score or steer on."""
        ref = request.content_ref
        meta = request.meta or {}
        inputs = None
        if ref is not None:
            if ref.inputs:
                inputs = tuple((d, s) for d, s in ref.inputs
                               if d is not None) or None
            elif ref.digest is not None:
                inputs = ((ref.digest, ref.size),)
        avoid = meta.get("avoid_node")
        weight = meta.get("locality_weight")
        prefetch = bool(meta.get("prefetch"))
        if inputs is None and avoid is None and weight is None \
                and not prefetch:
            return None
        first = inputs[0] if inputs else (None, 0)
        return cls(digest=first[0], size=first[1], inputs=inputs,
                   weight=weight, prefetch=prefetch, avoid=avoid)


class Scheduler:
    #: load penalty for a hint's ``avoid`` node — large enough that any
    #: other node wins, finite so a single-node cluster still places
    AVOID_PENALTY = 1e6
    #: max placements decided per scheduler-lock hold by a batch leader —
    #: bounds how long waiters park while one leader drains the queue
    MAX_BATCH = 128

    def __init__(self, cluster, scheduling_s: float = 0.15,
                 locality_weight: float = 2.0):
        self.cluster = cluster
        self.scheduling_s = scheduling_s   # α: activator + kube-scheduler path
        self.locality_weight = locality_weight
        self._lock = threading.Lock()
        self._load: Dict[str, int] = {}
        # flat-combining placement queue: callers enqueue a _PlacementReq,
        # then ONE of them (whoever wins ``_combine``) becomes the batch
        # leader and decides everybody's placement in a single ``_lock``
        # hold — N concurrent schedules cost one lock acquisition, not N
        self._pending: deque = deque()
        self._combine = threading.Lock()
        self.stats = {"placements": 0, "locality_hits": 0,
                      "prefetch_kicks": 0, "speculative_placements": 0,
                      "placement_batches": 0}

    def schedule(self, spec: FunctionSpec, invocation_id: str,
                 hint: Optional[PlacementHint] = None, record=None):
        """Blocks for α, picks a node, publishes the placement event.

        ``hint`` enables digest-aware scoring (plus weight/avoid/prefetch
        directives from the execution plan); ``record`` (a
        LifecycleRecord) gets ``locality_hit``/``prefetched`` stamped.

        Concurrent callers combine: each enqueues its request, then either
        becomes the batch leader (drains the whole queue under one lock
        hold) or parks until a leader has decided its placement. The
        uncontended path places INLINE — leader-of-a-batch-of-one with no
        queue traffic and no Event allocation — so a quiet scheduler costs
        what the old lock-per-placement code did."""
        clock = self.cluster.clock
        clock.sleep(self.scheduling_s)
        holders = self._holders(hint)
        if not self._pending and self._combine.acquire(blocking=False):
            req = _PlacementReq(spec, invocation_id, hint, record,
                                holders, done=_NOOP_DONE)
            try:
                self._place_batch([req])
            finally:
                self._combine.release()
            self._drain_pending()     # anything enqueued while we led
            if req.error is not None:
                raise req.error
            return req.node
        req = _PlacementReq(spec, invocation_id, hint, record, holders)
        self._pending.append(req)
        self._drain_pending()
        while not req.done.wait(timeout=0.05):
            # a leader can check-empty-and-release in the gap between our
            # append and our acquire attempt — retry until someone (likely
            # us, now that the lock is free) places the request
            self._drain_pending()
        if req.error is not None:
            raise req.error
        return req.node

    def _drain_pending(self) -> None:
        """Become the batch leader if nobody else is: drain the placement
        queue in MAX_BATCH bites until it is empty. Non-leaders return
        immediately and park on their request's event.

        The outer loop closes the classic flat-combining race: a request
        appended between the leader's final empty-check and its release
        would otherwise sit until a park timeout — so after releasing we
        re-check the queue and re-elect if anything slipped in."""
        while self._pending:
            if not self._combine.acquire(blocking=False):
                return
            try:
                while True:
                    batch: List[_PlacementReq] = []
                    while len(batch) < self.MAX_BATCH:
                        try:
                            batch.append(self._pending.popleft())
                        except IndexError:
                            break
                    if not batch:
                        break
                    self._place_batch(batch)
            finally:
                self._combine.release()

    def _place_batch(self, batch: List[_PlacementReq]) -> None:
        """Decide a whole batch under ONE scheduler-lock hold, then do the
        slow per-request tail (prefetch kicks, bus publishes, record
        stamps) outside it, in decision order."""
        with self._lock:
            self.stats["placement_batches"] += 1
            for req in batch:
                try:
                    node = self._pick_locked(req.spec, req.hint,
                                             req.holders)
                except BaseException as e:  # noqa: BLE001 — per-request
                    # failure (dead affinity node, empty cluster) must not
                    # sink the rest of the batch; re-raised on the
                    # requester's own thread from schedule()
                    req.error = e
                    continue
                req.node = node
                hint = req.hint
                # report from the SAME snapshot the decision scored — a
                # second registry read could disagree with the placement
                req.resident = sum(
                    req.holders.get(d, {}).get(node.name, 0)
                    for d, _ in (hint.input_hints() if hint else ()))
                # a hit means locality scoring PLACED us on the data —
                # coincidental residency under an affinity pin or with
                # locality disabled is not one (keeps load-only runs honest)
                scored = (hint is not None and hint.input_hints()
                          and not req.spec.affinity
                          and self._weight(hint) > 0)
                req.locality_hit = bool(scored and req.resident > 0)
                # ``avoid`` is only ever set by a speculative backup
                # dispatch (failure independence): count it, and mark the
                # event, so tests and benchmarks can assert WHERE
                # auto-speculation actually fired
                req.speculative = bool(hint is not None
                                       and hint.avoid is not None)
                self._load[node.name] = self._load.get(node.name, 0) + 1
                self.stats["placements"] += 1
                if req.locality_hit:
                    self.stats["locality_hits"] += 1
                if req.speculative:
                    self.stats["speculative_placements"] += 1
        clock = self.cluster.clock
        for req in batch:
            if req.error is not None:
                req.done.set()
                continue
            if req.record is not None:
                req.record.locality_hit = req.locality_hit
            # registry-driven prefetch: placing OFF (part of) the data
            # under load skew kicks the relay NOW, at the placement
            # decision, instead of when the data path reacts to the
            # trigger. Kicked before the event publishes so the prefetch
            # leads the relay table and the CSP/SDP ship becomes its
            # follower (bytes cross the fabric once).
            prefetched = False
            if req.hint is not None and req.hint.prefetch:
                prefetched = self._kick_prefetch(req.hint, req.node.name,
                                                 req.holders)
            if req.record is not None:
                req.record.prefetched = prefetched
            self.cluster.bus.publish("scheduling.placed", {
                "function": req.spec.name, "node": req.node.name,
                "invocation": req.inv_id, "t": clock.now(),
                "locality_hit": req.locality_hit,
                "resident_bytes": req.resident,
                "prefetched": prefetched, "speculative": req.speculative,
            })
            req.done.set()

    def pick_node(self, spec: FunctionSpec,
                  hint: Optional[PlacementHint] = None):
        """Placement decision WITHOUT the α sleep, the load credit, or the
        ``scheduling.placed`` event — the fleet's pre-warm path: pool
        provisioning wants the node a real dispatch would pick (locality,
        health penalties, and ``avoid`` all apply), but must not charge
        load for a sandbox no request occupies yet nor publish a placement
        the CSP watcher would ship data after."""
        return self._pick(spec, hint)

    def _weight(self, hint: Optional[PlacementHint]) -> float:
        if hint is not None and hint.weight is not None:
            return hint.weight
        return self.locality_weight

    def _holders(self, hint: Optional[PlacementHint]
                 ) -> Dict[str, Dict[str, int]]:
        """One registry snapshot per placement:
        {digest: {node: resident_bytes}} over every hinted input."""
        registry = getattr(self.cluster, "digests", None)
        if hint is None or registry is None:
            return {}
        return {d: registry.nodes_for(d) for d, _ in hint.input_hints()}

    @staticmethod
    def _resident_fraction(node_name: str, hint: PlacementHint,
                           holders: Dict[str, Dict[str, int]]) -> float:
        """Size-weighted resident fraction across ALL hinted inputs — the
        ONE definition scoring and reporting share. A fan-in stage is
        scored on the sum of its resident inputs; all-zero-size hints
        count as fully resident when any bytes resolve."""
        pairs = hint.input_hints()
        if not pairs:
            return 0.0
        total = sum(s for _, s in pairs)
        if total <= 0:
            return 1.0 if any(holders.get(d, {}).get(node_name, 0) > 0
                              for d, _ in pairs) else 0.0
        res = sum(min(holders.get(d, {}).get(node_name, 0), max(s, 0))
                  for d, s in pairs)
        return res / total

    def _pick(self, spec: FunctionSpec,
              hint: Optional[PlacementHint] = None,
              holders: Optional[Dict[str, Dict[str, int]]] = None):
        """Standalone pick: registry snapshot OUTSIDE the lock, then one
        lock hold for the scoring pass (the batch leader skips this wrapper
        and calls ``_pick_locked`` for the whole batch under one hold)."""
        if holders is None:
            holders = self._holders(hint)
        with self._lock:
            return self._pick_locked(spec, hint, holders)

    def _pick_locked(self, spec: FunctionSpec,
                     hint: Optional[PlacementHint],
                     holders: Dict[str, Dict[str, int]]):
        nodes = self.cluster.node_list
        live = [n for n in nodes if getattr(n, "alive", True)]
        if not live:
            raise NodeCrashError(None, "no live node in the cluster")
        if spec.affinity:
            for n in nodes:
                if n.name == spec.affinity:
                    if not getattr(n, "alive", True):
                        raise NodeCrashError(
                            n.name, f"{spec.name}: affinity node "
                                    f"{n.name} crashed")
                    return n
            raise KeyError(f"affinity node {spec.affinity!r} not in cluster")
        health = getattr(self.cluster, "health", None)

        def score(n) -> float:
            load = float(self._load.get(n.name, 0))
            if hint is not None:
                w = self._weight(hint)
                if w > 0:
                    load -= w * self._resident_fraction(n.name, hint,
                                                        holders)
                if hint.avoid == n.name:
                    load += self.AVOID_PENALTY
            if health is not None:
                # suspect nodes compete at a handicap; degraded ones
                # effectively never win (finite, so a fully sick
                # cluster still places rather than wedging)
                load += health.penalty(n.name)
            return load
        # min() is stable: ties keep the node_list order, so behavior
        # without hints is exactly the old least-loaded placement
        return min(live, key=score)

    def _kick_prefetch(self, hint: PlacementHint, node_name: str,
                       holders: Dict[str, Dict[str, int]]) -> bool:
        """Relay ONLY ``hint.digest`` — the content the data path will ship
        and alias-check (for a fan-in pass, the joined blob). Relaying
        per-dep parts would be pure extra fabric traffic: the ship is keyed
        on the joined digest and could never follow or alias them."""
        prefetcher = getattr(self.cluster, "prefetcher", None)
        if prefetcher is None or hint.digest is None:
            return False
        nodes = holders.get(hint.digest, {})
        if nodes.get(node_name, 0) >= max(hint.size, 1):
            return False                      # (fully) resident already
        kicked = prefetcher.kick(hint.digest, node_name,
                                 compression=hint.compression)
        if kicked:
            with self._lock:
                self.stats["prefetch_kicks"] += 1
        return kicked

    def release(self, node_name: str) -> None:
        with self._lock:
            self._load[node_name] = max(0, self._load.get(node_name, 0) - 1)

    def load_of(self, node_name: str) -> int:
        """Current in-flight scheduled-invocation count for a node."""
        with self._lock:
            return self._load.get(node_name, 0)
