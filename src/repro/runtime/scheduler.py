"""Placement scheduler (Kubernetes analogue). Emits ``scheduling.placed``
events on the bus — the Truffle Watcher's entire CSP mechanism hangs off
the fact that the host is known HERE, long before the sandbox is up.

Locality-aware placement: a request carrying a :class:`PlacementHint`
(one ``(digest, size)`` per input — fan-in stages hint each dep
separately) is scored against the cluster-wide
:class:`~repro.runtime.registry.DigestRegistry` — a node holding input
bytes gets a load credit of ``weight × resident_fraction``, where the
fraction is the size-weighted SUM over all hinted inputs. Fan-out stages
and repeated inputs land *on the data* and the CSP/SDP transfer
degenerates to a zero-cost local alias; a fan-in stage lands on the node
holding the biggest share of its inputs. Load skew still wins once it
exceeds the credit; affinity pins override everything.

The hint also carries the compiled :class:`~repro.runtime.planner.
ExecutionPlan`'s per-edge directives for this placement:
``weight`` (a per-edge ``DataPolicy.locality_weight`` override),
``prefetch`` (registry-driven: placing OFF the data kicks the relay at
placement-decision time, not at trigger time), and ``avoid`` (speculative
backups steer away from the straggler's node for failure independence).

Knobs: ``scheduling_s`` (α, the activator + kube-scheduler path) and
``locality_weight`` (load units a fully resident input is worth; 0 disables
locality and recovers pure least-loaded placement).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.runtime.function import FunctionSpec, Request


@dataclass(frozen=True)
class PlacementHint:
    """Where-the-bytes-live (and how-to-place) hint for one decision.

    ``digest``/``size`` is the legacy single-input form; ``inputs`` is the
    per-dep form (((digest, size), ...)). ``input_hints()`` canonicalizes.
    """
    digest: Optional[str] = None
    size: int = 0
    inputs: Optional[Tuple[Tuple[str, int], ...]] = None
    weight: Optional[float] = None        # per-edge locality_weight override
    prefetch: bool = False                # kick relay at placement decision
    compression: str = "none"             # wire codec for a prefetch relay
    avoid: Optional[str] = None           # steer off this node (speculation)

    def input_hints(self) -> Tuple[Tuple[str, int], ...]:
        if self.inputs:
            return tuple((d, s) for d, s in self.inputs if d is not None)
        if self.digest is not None:
            return ((self.digest, self.size),)
        return ()

    @classmethod
    def from_policy(cls, policy, digest: Optional[str], size: int,
                    inputs, avoid: Optional[str]) -> Optional["PlacementHint"]:
        """The compiled plan's placement directives for one edge — the ONE
        construction CSP and SDP share (the two paths must not diverge).

        ``digest`` content-addresses the bytes the data path will actually
        ship/land (for a fan-in pass: the JOINED blob, seeded on the source
        node); ``inputs`` are the per-dep hints. Both signals matter: the
        per-dep digests credit nodes holding parts, and the joined digest
        credits the source node where placement degenerates to a
        zero-transfer alias — so the joined pair is appended to ``inputs``
        rather than replaced by them."""
        if inputs is not None and digest is not None \
                and all(d != digest for d, _ in inputs):
            inputs = tuple(inputs) + ((digest, size),)
        elif inputs is None and digest is not None:
            inputs = ((digest, size),)
        if inputs is None and avoid is None and not policy.prefetch \
                and policy.locality_weight is None:
            return None
        return cls(digest=digest, size=size, inputs=inputs,
                   weight=policy.locality_weight, prefetch=policy.prefetch,
                   compression=policy.compression, avoid=avoid)

    @classmethod
    def from_request(cls, request: Request) -> Optional["PlacementHint"]:
        """Hint from the request's content ref + meta; None when there is
        nothing to score or steer on."""
        ref = request.content_ref
        meta = request.meta or {}
        inputs = None
        if ref is not None:
            if ref.inputs:
                inputs = tuple((d, s) for d, s in ref.inputs
                               if d is not None) or None
            elif ref.digest is not None:
                inputs = ((ref.digest, ref.size),)
        avoid = meta.get("avoid_node")
        weight = meta.get("locality_weight")
        prefetch = bool(meta.get("prefetch"))
        if inputs is None and avoid is None and weight is None \
                and not prefetch:
            return None
        first = inputs[0] if inputs else (None, 0)
        return cls(digest=first[0], size=first[1], inputs=inputs,
                   weight=weight, prefetch=prefetch, avoid=avoid)


class Scheduler:
    #: load penalty for a hint's ``avoid`` node — large enough that any
    #: other node wins, finite so a single-node cluster still places
    AVOID_PENALTY = 1e6

    def __init__(self, cluster, scheduling_s: float = 0.15,
                 locality_weight: float = 2.0):
        self.cluster = cluster
        self.scheduling_s = scheduling_s   # α: activator + kube-scheduler path
        self.locality_weight = locality_weight
        self._lock = threading.Lock()
        self._load: Dict[str, int] = {}
        self.stats = {"placements": 0, "locality_hits": 0,
                      "prefetch_kicks": 0, "speculative_placements": 0}

    def schedule(self, spec: FunctionSpec, invocation_id: str,
                 hint: Optional[PlacementHint] = None, record=None):
        """Blocks for α, picks a node, publishes the placement event.

        ``hint`` enables digest-aware scoring (plus weight/avoid/prefetch
        directives from the execution plan); ``record`` (a
        LifecycleRecord) gets ``locality_hit``/``prefetched`` stamped.
        """
        clock = self.cluster.clock
        clock.sleep(self.scheduling_s)
        holders = self._holders(hint)
        node = self._pick(spec, hint, holders)
        # report from the SAME snapshot the decision scored — a second
        # registry read here could disagree with the placement it describes
        resident = sum(holders.get(d, {}).get(node.name, 0)
                       for d, _ in (hint.input_hints() if hint else ()))
        # a hit means locality scoring PLACED us on the data — coincidental
        # residency under an affinity pin or with locality disabled is not
        # one (keeps the load-only control runs honest)
        scored = (hint is not None and hint.input_hints()
                  and not spec.affinity and self._weight(hint) > 0)
        locality_hit = bool(scored and resident > 0)
        # ``avoid`` is only ever set by a speculative backup dispatch
        # (failure independence): count it, and mark the event, so tests
        # and benchmarks can assert WHERE auto-speculation actually fired
        speculative = bool(hint is not None and hint.avoid is not None)
        with self._lock:
            self._load[node.name] = self._load.get(node.name, 0) + 1
            self.stats["placements"] += 1
            if locality_hit:
                self.stats["locality_hits"] += 1
            if speculative:
                self.stats["speculative_placements"] += 1
        if record is not None:
            record.locality_hit = locality_hit
        # registry-driven prefetch: placing OFF (part of) the data under
        # load skew kicks the relay NOW, at the placement decision, instead
        # of when the data path reacts to the trigger. Kicked before the
        # event publishes so the prefetch leads the relay table and the
        # CSP/SDP ship becomes its follower (bytes cross the fabric once).
        prefetched = False
        if hint is not None and hint.prefetch:
            prefetched = self._kick_prefetch(hint, node.name, holders)
        if record is not None:
            record.prefetched = prefetched
        self.cluster.bus.publish("scheduling.placed", {
            "function": spec.name, "node": node.name,
            "invocation": invocation_id, "t": clock.now(),
            "locality_hit": locality_hit, "resident_bytes": resident,
            "prefetched": prefetched, "speculative": speculative,
        })
        return node

    def pick_node(self, spec: FunctionSpec,
                  hint: Optional[PlacementHint] = None):
        """Placement decision WITHOUT the α sleep, the load credit, or the
        ``scheduling.placed`` event — the fleet's pre-warm path: pool
        provisioning wants the node a real dispatch would pick (locality,
        health penalties, and ``avoid`` all apply), but must not charge
        load for a sandbox no request occupies yet nor publish a placement
        the CSP watcher would ship data after."""
        return self._pick(spec, hint)

    def _weight(self, hint: Optional[PlacementHint]) -> float:
        if hint is not None and hint.weight is not None:
            return hint.weight
        return self.locality_weight

    def _holders(self, hint: Optional[PlacementHint]
                 ) -> Dict[str, Dict[str, int]]:
        """One registry snapshot per placement:
        {digest: {node: resident_bytes}} over every hinted input."""
        registry = getattr(self.cluster, "digests", None)
        if hint is None or registry is None:
            return {}
        return {d: registry.nodes_for(d) for d, _ in hint.input_hints()}

    @staticmethod
    def _resident_fraction(node_name: str, hint: PlacementHint,
                           holders: Dict[str, Dict[str, int]]) -> float:
        """Size-weighted resident fraction across ALL hinted inputs — the
        ONE definition scoring and reporting share. A fan-in stage is
        scored on the sum of its resident inputs; all-zero-size hints
        count as fully resident when any bytes resolve."""
        pairs = hint.input_hints()
        if not pairs:
            return 0.0
        total = sum(s for _, s in pairs)
        if total <= 0:
            return 1.0 if any(holders.get(d, {}).get(node_name, 0) > 0
                              for d, _ in pairs) else 0.0
        res = sum(min(holders.get(d, {}).get(node_name, 0), max(s, 0))
                  for d, s in pairs)
        return res / total

    def _pick(self, spec: FunctionSpec,
              hint: Optional[PlacementHint] = None,
              holders: Optional[Dict[str, Dict[str, int]]] = None):
        from repro.core.errors import NodeCrashError
        nodes = self.cluster.node_list
        live = [n for n in nodes if getattr(n, "alive", True)]
        if not live:
            raise NodeCrashError(None, "no live node in the cluster")
        if spec.affinity:
            for n in nodes:
                if n.name == spec.affinity:
                    if not getattr(n, "alive", True):
                        raise NodeCrashError(
                            n.name, f"{spec.name}: affinity node "
                                    f"{n.name} crashed")
                    return n
            raise KeyError(f"affinity node {spec.affinity!r} not in cluster")
        if holders is None:
            holders = self._holders(hint)
        health = getattr(self.cluster, "health", None)
        with self._lock:
            def score(n) -> float:
                load = float(self._load.get(n.name, 0))
                if hint is not None:
                    w = self._weight(hint)
                    if w > 0:
                        load -= w * self._resident_fraction(n.name, hint,
                                                            holders)
                    if hint.avoid == n.name:
                        load += self.AVOID_PENALTY
                if health is not None:
                    # suspect nodes compete at a handicap; degraded ones
                    # effectively never win (finite, so a fully sick
                    # cluster still places rather than wedging)
                    load += health.penalty(n.name)
                return load
            # min() is stable: ties keep the node_list order, so behavior
            # without hints is exactly the old least-loaded placement
            return min(live, key=score)

    def _kick_prefetch(self, hint: PlacementHint, node_name: str,
                       holders: Dict[str, Dict[str, int]]) -> bool:
        """Relay ONLY ``hint.digest`` — the content the data path will ship
        and alias-check (for a fan-in pass, the joined blob). Relaying
        per-dep parts would be pure extra fabric traffic: the ship is keyed
        on the joined digest and could never follow or alias them."""
        prefetcher = getattr(self.cluster, "prefetcher", None)
        if prefetcher is None or hint.digest is None:
            return False
        nodes = holders.get(hint.digest, {})
        if nodes.get(node_name, 0) >= max(hint.size, 1):
            return False                      # (fully) resident already
        kicked = prefetcher.kick(hint.digest, node_name,
                                 compression=hint.compression)
        if kicked:
            with self._lock:
                self.stats["prefetch_kicks"] += 1
        return kicked

    def release(self, node_name: str) -> None:
        with self._lock:
            self._load[node_name] = max(0, self._load.get(node_name, 0) - 1)

    def load_of(self, node_name: str) -> int:
        """Current in-flight scheduled-invocation count for a node."""
        with self._lock:
            return self._load.get(node_name, 0)
