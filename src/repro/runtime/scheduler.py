"""Placement scheduler (Kubernetes analogue). Emits ``scheduling.placed``
events on the bus — the Truffle Watcher's entire CSP mechanism hangs off
the fact that the host is known HERE, long before the sandbox is up.

Locality-aware placement: a request carrying a :class:`PlacementHint`
(digest + size of its input, threaded down from ``Request.content_ref``)
is scored against the cluster-wide :class:`~repro.runtime.registry.
DigestRegistry` — a node already holding the input's bytes gets a load
credit of ``locality_weight × resident_fraction``, so fan-out stages and
repeated inputs land *on the data* and the CSP/SDP transfer degenerates to
a zero-cost local alias. Load skew still wins once it exceeds the credit
(``locality_weight`` load units for a fully resident input); affinity pins
override everything.

Knobs: ``scheduling_s`` (α, the activator + kube-scheduler path) and
``locality_weight`` (load units a fully resident input is worth; 0 disables
locality and recovers pure least-loaded placement).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Optional

from repro.runtime.function import FunctionSpec, Request
from repro.runtime.registry import DigestRegistry


@dataclass(frozen=True)
class PlacementHint:
    """Where-the-bytes-live hint for one placement decision."""
    digest: Optional[str] = None
    size: int = 0

    @classmethod
    def from_request(cls, request: Request) -> Optional["PlacementHint"]:
        """Hint from the request's content ref; None when the input carries
        no digest (nothing for locality to match on)."""
        ref = request.content_ref
        if ref is None or ref.digest is None:
            return None
        return cls(digest=ref.digest, size=ref.size)


class Scheduler:
    def __init__(self, cluster, scheduling_s: float = 0.15,
                 locality_weight: float = 2.0):
        self.cluster = cluster
        self.scheduling_s = scheduling_s   # α: activator + kube-scheduler path
        self.locality_weight = locality_weight
        self._lock = threading.Lock()
        self._load: Dict[str, int] = {}
        self.stats = {"placements": 0, "locality_hits": 0}

    def schedule(self, spec: FunctionSpec, invocation_id: str,
                 hint: Optional[PlacementHint] = None, record=None):
        """Blocks for α, picks a node, publishes the placement event.

        ``hint`` enables digest-aware scoring; ``record`` (a
        LifecycleRecord) gets ``locality_hit`` stamped with the decision.
        """
        clock = self.cluster.clock
        clock.sleep(self.scheduling_s)
        holders = self._holders(hint)
        node = self._pick(spec, hint, holders)
        # report from the SAME snapshot the decision scored — a second
        # registry read here could disagree with the placement it describes
        resident = holders.get(node.name, 0)
        # a hit means locality scoring PLACED us on the data — coincidental
        # residency under an affinity pin or with locality disabled is not
        # one (keeps the load-only control runs honest)
        scored = (hint is not None and not spec.affinity
                  and self.locality_weight > 0)
        locality_hit = scored and resident > 0
        with self._lock:
            self._load[node.name] = self._load.get(node.name, 0) + 1
            self.stats["placements"] += 1
            if locality_hit:
                self.stats["locality_hits"] += 1
        if record is not None:
            record.locality_hit = locality_hit
        self.cluster.bus.publish("scheduling.placed", {
            "function": spec.name, "node": node.name,
            "invocation": invocation_id, "t": clock.now(),
            "locality_hit": locality_hit, "resident_bytes": resident,
        })
        return node

    def _holders(self, hint: Optional[PlacementHint]) -> Dict[str, int]:
        """One registry snapshot per placement: {node: resident_bytes}."""
        registry = getattr(self.cluster, "digests", None)
        if hint is None or registry is None:
            return {}
        return registry.nodes_for(hint.digest)

    def _pick(self, spec: FunctionSpec,
              hint: Optional[PlacementHint] = None,
              holders: Optional[Dict[str, int]] = None):
        nodes = self.cluster.node_list
        if spec.affinity:
            for n in nodes:
                if n.name == spec.affinity:
                    return n
            raise KeyError(f"affinity node {spec.affinity!r} not in cluster")
        if holders is None:
            holders = self._holders(hint)
        with self._lock:
            def score(n) -> float:
                load = float(self._load.get(n.name, 0))
                if hint is not None:
                    load -= self.locality_weight * DigestRegistry.fraction(
                        holders.get(n.name, 0), hint.size)
                return load
            # min() is stable: ties keep the node_list order, so behavior
            # without hints is exactly the old least-loaded placement
            return min(nodes, key=score)

    def release(self, node_name: str) -> None:
        with self._lock:
            self._load[node_name] = max(0, self._load.get(node_name, 0) - 1)

    def load_of(self, node_name: str) -> int:
        """Current in-flight scheduled-invocation count for a node."""
        with self._lock:
            return self._load.get(node_name, 0)
