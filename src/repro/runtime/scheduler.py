"""Placement scheduler (Kubernetes analogue). Emits ``scheduling.placed``
events on the bus — the Truffle Watcher's entire CSP mechanism hangs off
the fact that the host is known HERE, long before the sandbox is up."""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.runtime.function import FunctionSpec


class Scheduler:
    def __init__(self, cluster, scheduling_s: float = 0.15):
        self.cluster = cluster
        self.scheduling_s = scheduling_s   # α: activator + kube-scheduler path
        self._rr = itertools.cycle(range(1 << 30))
        self._lock = threading.Lock()
        self._load: Dict[str, int] = {}

    def schedule(self, spec: FunctionSpec, invocation_id: str):
        """Blocks for α, picks a node, publishes the placement event."""
        clock = self.cluster.clock
        clock.sleep(self.scheduling_s)
        node = self._pick(spec)
        with self._lock:
            self._load[node.name] = self._load.get(node.name, 0) + 1
        self.cluster.bus.publish("scheduling.placed", {
            "function": spec.name, "node": node.name,
            "invocation": invocation_id, "t": clock.now(),
        })
        return node

    def _pick(self, spec: FunctionSpec):
        nodes = self.cluster.node_list
        if spec.affinity:
            for n in nodes:
                if n.name == spec.affinity:
                    return n
            raise KeyError(f"affinity node {spec.affinity!r} not in cluster")
        with self._lock:
            return min(nodes, key=lambda n: self._load.get(n.name, 0))

    def release(self, node_name: str) -> None:
        with self._lock:
            self._load[node_name] = max(0, self._load.get(node_name, 0) - 1)
