"""Simulation clock: scale factor for *simulated* delays (provisioning,
network). Benchmarks run at scale=1.0 (faithful seconds); unit tests shrink
simulated time without changing orderings."""
from __future__ import annotations

import time
from dataclasses import dataclass


@dataclass
class Clock:
    scale: float = 1.0

    def sleep(self, sim_seconds: float) -> None:
        if sim_seconds > 0:
            time.sleep(sim_seconds * self.scale)

    def now(self) -> float:
        """Wall-clock seconds (monotonic)."""
        return time.monotonic()

    def elapsed_sim(self, wall_delta: float) -> float:
        """Convert a measured wall delta back to simulated seconds."""
        return wall_delta / self.scale if self.scale else wall_delta

    def sleep_until(self, wall_deadline: float) -> None:
        """Sleep to an absolute wall deadline (no-op if already past).
        Deadline-based pacing self-corrects OS sleep overshoot — essential
        for chunk-granular transfers made of many small sleeps."""
        wait = wall_deadline - time.monotonic()
        if wait > 0:
            time.sleep(wait)

    def pacer(self) -> "Pacer":
        return Pacer(self)


class Pacer:
    """Drift-compensated repeated sleeper: many small ``sleep(sim)`` calls
    average to the requested total instead of accumulating one OS timer
    quantum of overshoot each (per-chunk compute in streaming handlers)."""

    def __init__(self, clock: Clock):
        self.clock = clock
        self._debt = 0.0            # wall seconds overslept so far

    def sleep(self, sim_seconds: float) -> None:
        want = sim_seconds * self.clock.scale
        effective = want - self._debt
        if effective <= 0:
            self._debt = -effective
            return
        t0 = time.monotonic()
        time.sleep(effective)
        self._debt = (time.monotonic() - t0) - effective


DEFAULT_CLOCK = Clock(1.0)
