"""Simulation clock: scale factor for *simulated* delays (provisioning,
network). Benchmarks run at scale=1.0 (faithful seconds); unit tests shrink
simulated time without changing orderings."""
from __future__ import annotations

import time
from dataclasses import dataclass


@dataclass
class Clock:
    scale: float = 1.0

    def sleep(self, sim_seconds: float) -> None:
        if sim_seconds > 0:
            time.sleep(sim_seconds * self.scale)

    def now(self) -> float:
        """Wall-clock seconds (monotonic)."""
        return time.monotonic()

    def elapsed_sim(self, wall_delta: float) -> float:
        """Convert a measured wall delta back to simulated seconds."""
        return wall_delta / self.scale if self.scale else wall_delta


DEFAULT_CLOCK = Clock(1.0)
