"""Serverless platform (Knative analogue): activator + autoscaler + queue-proxy.

Baseline semantics (paper Fig. 2): the activator HOLDS the request — payload
included — until the sandbox is fully up; input data therefore moves only
after Fn-start. Truffle's whole contribution is routing around exactly this.
"""
from __future__ import annotations

import threading
import uuid
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple

from repro.runtime.function import (FunctionInstance, FunctionSpec,
                                    LifecycleRecord, Request)
from repro.runtime.scheduler import PlacementHint


class Platform:
    #: activator/queue-proxy handling overhead for a request carrying a full
    #: payload (buffering, proxy hops). Reference-only triggers (Truffle) are
    #: nearly free.
    INGRESS_OVERHEAD_S = 0.30
    REF_TRIGGER_OVERHEAD_S = 0.05

    def __init__(self, cluster):
        self.cluster = cluster
        self._specs: Dict[str, FunctionSpec] = {}
        self._warm: Dict[str, List[FunctionInstance]] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ API
    def register(self, spec: FunctionSpec) -> None:
        with self._lock:
            self._specs[spec.name] = spec
            self._warm.setdefault(spec.name, [])

    def scale_to_zero(self, fn: Optional[str] = None) -> None:
        with self._lock:
            for name in ([fn] if fn else list(self._warm)):
                self._warm[name] = []

    def warm_instances(self, fn: str) -> List[FunctionInstance]:
        with self._lock:
            return [i for i in self._warm.get(fn, ())
                    if i.state == FunctionInstance.WARM]

    def invoke_async(self, request: Request, *,
                     lightweight_trigger: bool = False,
                     record: Optional[LifecycleRecord] = None,
                     hint: Optional[PlacementHint] = None,
                     ) -> Tuple[Future, LifecycleRecord]:
        """Accept a request; returns (future, record). ``lightweight_trigger``
        marks a Truffle reference-key event (no payload through the ingress).
        ``hint`` carries the execution plan's placement directives (per-dep
        digests, locality-weight override, prefetch, avoid-node) straight to
        the scheduler; without one it is derived from the request's content
        ref and meta (``PlacementHint.from_request``)."""
        clock = self.cluster.clock
        rec = record or LifecycleRecord(fn=request.fn)
        if not rec.t_request:
            rec.t_request = clock.now()
        inv_id = request.meta.setdefault("invocation", uuid.uuid4().hex)
        fut: Future = Future()

        def run():
            try:
                fut.set_result(self._invoke(request, rec, inv_id,
                                            lightweight_trigger, hint))
            except BaseException as e:  # noqa: BLE001
                fut.set_exception(e)

        threading.Thread(target=run, daemon=True,
                         name=f"invoke-{request.fn}-{inv_id[:6]}").start()
        return fut, rec

    def invoke(self, request: Request, **kw) -> Tuple[bytes, LifecycleRecord]:
        fut, rec = self.invoke_async(request, **kw)
        return fut.result(), rec

    # ----------------------------------------------------------- internals
    def _invoke(self, request: Request, rec: LifecycleRecord, inv_id: str,
                lightweight: bool,
                hint: Optional[PlacementHint] = None) -> bytes:
        clock = self.cluster.clock
        spec = self._specs[request.fn]
        clock.sleep(self.REF_TRIGGER_OVERHEAD_S if lightweight
                    else self.INGRESS_OVERHEAD_S)

        inst = self._checkout_warm(request.fn)
        scheduled_node = None           # set iff this invocation took a load
        if inst is not None:            # credit via scheduler.schedule()
            rec.cold = False
            rec.t_placed = rec.t_prov_end = rec.t_startup_end = clock.now()
            rec.node = inst.node.name
            # host already assigned — tell the watcher (hot-function path)
            self.cluster.bus.publish("scheduling.placed", {
                "function": spec.name, "node": inst.node.name,
                "invocation": inv_id, "warm": True, "t": clock.now()})
        else:
            node = self.cluster.scheduler.schedule(
                spec, inv_id,
                hint=(hint if hint is not None
                      else PlacementHint.from_request(request)),
                record=rec)
            scheduled_node = node.name
            rec.t_placed = clock.now()
            rec.node = node.name
            inst = FunctionInstance(spec, node, self.cluster)
            inst.provision(rec)          # ν + η (Truffle's overlap window)

        try:
            # queue-proxy resumes the request: a direct payload crosses the
            # network only NOW (after Fn-start) in the baseline path.
            if request.payload is not None and request.source_node:
                src = self.cluster.node(request.source_node)
                rec.t_transfer_start = clock.now()
                self.cluster.transfer(src, inst.node, request.payload)
                rec.t_transfer_end = clock.now()

            out = inst.invoke(request, rec)
            with self._lock:
                self._warm[request.fn].append(inst)
            return out
        finally:
            # release ONLY what schedule() charged: warm checkouts never took
            # a load credit, and releasing one here would steal the credit of
            # an in-flight cold start on the same node, skewing least-loaded
            # (and locality-vs-load) placement
            if scheduled_node is not None:
                self.cluster.scheduler.release(scheduled_node)

    def _checkout_warm(self, fn: str) -> Optional[FunctionInstance]:
        health = getattr(self.cluster, "health", None)
        with self._lock:
            pool = self._warm.get(fn, [])
            for i, inst in enumerate(pool):
                if inst.state != FunctionInstance.WARM:
                    continue
                # a warm container on a crashed node is gone; one on a
                # degraded node must not short-circuit the scheduler's
                # steering — leave it to the drain
                if not getattr(inst.node, "alive", True):
                    continue
                if health is not None and health.state(inst.node.name) in (
                        "degraded", "dead"):
                    continue
                return pool.pop(i)
        return None

    def purge_node(self, name: str) -> int:
        """Drop every warm instance on ``name`` (node crash: the sandboxes
        died with it). Returns how many were purged."""
        purged = 0
        with self._lock:
            for fn, pool in self._warm.items():
                keep = [i for i in pool if i.node.name != name]
                purged += len(pool) - len(keep)
                self._warm[fn] = keep
        return purged
