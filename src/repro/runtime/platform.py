"""Serverless platform (Knative analogue): activator + autoscaler + queue-proxy.

Baseline semantics (paper Fig. 2): the activator HOLDS the request — payload
included — until the sandbox is fully up; input data therefore moves only
after Fn-start. Truffle's whole contribution is routing around exactly this.
"""
from __future__ import annotations

import threading
import uuid
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple

from repro.runtime.executor import EXECUTOR
from repro.runtime.function import (FunctionInstance, FunctionSpec,
                                    LifecycleRecord, Request)
from repro.runtime.scheduler import PlacementHint


class Platform:
    #: activator/queue-proxy handling overhead for a request carrying a full
    #: payload (buffering, proxy hops). Reference-only triggers (Truffle) are
    #: nearly free.
    INGRESS_OVERHEAD_S = 0.30
    REF_TRIGGER_OVERHEAD_S = 0.05
    #: default warm-pool cap per function: a checkin past it discards the
    #: instance (scale-down) instead of growing the pool forever — a burst
    #: used to pin its high-water mark of sandboxes permanently
    DEFAULT_POOL_MAX = 8

    def __init__(self, cluster):
        self.cluster = cluster
        self._specs: Dict[str, FunctionSpec] = {}
        self._warm: Dict[str, List[FunctionInstance]] = {}
        self._lock = threading.Lock()
        # fn -> (max_instances, idle_ttl_s | None, min_keep): fleet pool
        # sizing; unset functions get (DEFAULT_POOL_MAX, no TTL, 0)
        self._pool_limits: Dict[str, Tuple[int, Optional[float], int]] = {}
        #: fleet warm-pool manager hook (WarmPools attaches itself): on a
        #: warm-checkout miss the platform may ADOPT an instance the pool is
        #: already provisioning instead of paying a fresh cold start
        self.pools = None
        self.stats = {"warm_hits": 0, "cold_starts": 0, "adoptions": 0,
                      "pool_drops": 0, "pool_expired": 0}

    # ------------------------------------------------------------------ API
    def register(self, spec: FunctionSpec) -> None:
        with self._lock:
            self._specs[spec.name] = spec
            self._warm.setdefault(spec.name, [])

    def scale_to_zero(self, fn: Optional[str] = None) -> None:
        with self._lock:
            for name in ([fn] if fn else list(self._warm)):
                self._warm[name] = []

    def set_pool_limit(self, fn: str, max_instances: int,
                       idle_ttl_s: Optional[float] = None,
                       min_instances: int = 0) -> None:
        """Size ``fn``'s warm pool: checkins past ``max_instances`` discard
        the instance, and instances idle longer than ``idle_ttl_s``
        sim-seconds expire (lazily, at checkout / ``reap_idle`` time) down
        to a floor of ``min_instances``."""
        with self._lock:
            self._pool_limits[fn] = (max(int(max_instances), 0), idle_ttl_s,
                                     max(int(min_instances), 0))

    def pool_limit(self, fn: str) -> Tuple[int, Optional[float], int]:
        """(max, idle_ttl_s, min) in force for ``fn``'s warm pool."""
        with self._lock:
            return self._pool_limits.get(fn, (self.DEFAULT_POOL_MAX, None, 0))

    def reap_idle(self) -> int:
        """Expire TTL-idle warm instances across all pools; returns how many
        were reaped. (Checkout also expires lazily — this is the explicit
        sweep for pools nothing is invoking.)"""
        clock = self.cluster.clock
        now = clock.now()
        before = self.stats["pool_expired"]
        with self._lock:
            for fn in list(self._warm):
                self._expire_idle_locked(fn, now)
        return self.stats["pool_expired"] - before

    def warm_instances(self, fn: str) -> List[FunctionInstance]:
        with self._lock:
            return [i for i in self._warm.get(fn, ())
                    if i.state == FunctionInstance.WARM]

    def invoke_async(self, request: Request, *,
                     lightweight_trigger: bool = False,
                     record: Optional[LifecycleRecord] = None,
                     hint: Optional[PlacementHint] = None,
                     ) -> Tuple[Future, LifecycleRecord]:
        """Accept a request; returns (future, record). ``lightweight_trigger``
        marks a Truffle reference-key event (no payload through the ingress).
        ``hint`` carries the execution plan's placement directives (per-dep
        digests, locality-weight override, prefetch, avoid-node) straight to
        the scheduler; without one it is derived from the request's content
        ref and meta (``PlacementHint.from_request``)."""
        clock = self.cluster.clock
        rec = record or LifecycleRecord(fn=request.fn)
        if not rec.t_request:
            rec.t_request = clock.now()
        inv_id = request.meta.setdefault("invocation", uuid.uuid4().hex)
        fut: Future = Future()

        def run():
            try:
                fut.set_result(self._invoke(request, rec, inv_id,
                                            lightweight_trigger, hint))
            except BaseException as e:  # noqa: BLE001
                fut.set_exception(e)

        EXECUTOR.submit(run, name=f"invoke-{request.fn}-{inv_id[:6]}")
        return fut, rec

    def invoke(self, request: Request, **kw) -> Tuple[bytes, LifecycleRecord]:
        fut, rec = self.invoke_async(request, **kw)
        return fut.result(), rec

    # ----------------------------------------------------------- internals
    def _invoke(self, request: Request, rec: LifecycleRecord, inv_id: str,
                lightweight: bool,
                hint: Optional[PlacementHint] = None) -> bytes:
        clock = self.cluster.clock
        spec = self._specs[request.fn]
        clock.sleep(self.REF_TRIGGER_OVERHEAD_S if lightweight
                    else self.INGRESS_OVERHEAD_S)

        inst = self._checkout_warm(request.fn)
        scheduled_node = None           # set iff this invocation took a load
        if inst is not None:            # credit via scheduler.schedule()
            rec.cold = False
            rec.warm_hit = True
            rec.prewarmed = inst.prewarmed
            rec.t_placed = rec.t_prov_end = rec.t_startup_end = clock.now()
            rec.node = inst.node.name
            with self._lock:
                self.stats["warm_hits"] += 1
            # host already assigned — tell the watcher (hot-function path)
            self.cluster.bus.publish("scheduling.placed", {
                "function": spec.name, "node": inst.node.name,
                "invocation": inv_id, "warm": True, "t": clock.now()})
        else:
            if self.pools is not None:
                inst = self._adopt_provisioning(request.fn, rec, spec, inv_id)
            if inst is None:
                node = self.cluster.scheduler.schedule(
                    spec, inv_id,
                    hint=(hint if hint is not None
                          else PlacementHint.from_request(request)),
                    record=rec)
                scheduled_node = node.name
                rec.t_placed = clock.now()
                rec.node = node.name
                inst = FunctionInstance(spec, node, self.cluster)
                inst.provision(rec)      # ν + η (Truffle's overlap window)
                with self._lock:
                    self.stats["cold_starts"] += 1

        try:
            # queue-proxy resumes the request: a direct payload crosses the
            # network only NOW (after Fn-start) in the baseline path.
            if request.payload is not None and request.source_node:
                src = self.cluster.node(request.source_node)
                rec.t_transfer_start = clock.now()
                self.cluster.transfer(src, inst.node, request.payload)
                rec.t_transfer_end = clock.now()

            out = inst.invoke(request, rec)
            self._checkin(request.fn, inst)
            return out
        finally:
            # release ONLY what schedule() charged: warm checkouts never took
            # a load credit, and releasing one here would steal the credit of
            # an in-flight cold start on the same node, skewing least-loaded
            # (and locality-vs-load) placement
            if scheduled_node is not None:
                self.cluster.scheduler.release(scheduled_node)

    def _adopt_provisioning(self, fn: str, rec: LifecycleRecord,
                            spec: FunctionSpec,
                            inv_id: str) -> Optional[FunctionInstance]:
        """Checkout miss while the fleet pool is still provisioning an
        instance for ``fn``: wait for THAT cold start instead of paying a
        fresh one — the CSP ship lands in an already-provisioning sandbox.
        The record stays ``cold`` (honest accounting: the invocation did
        wait), but its cold-start phase is only the RESIDUAL wait, not the
        full ν+η. Returns None (fall back to a real cold start) when
        nothing is in flight or the adopted provision failed."""
        pw = self.pools.adopt(fn)
        if pw is None:
            return None
        clock = self.cluster.clock
        rec.t_placed = clock.now()
        pw.ready.wait(timeout=120.0)
        inst = pw.instance
        if (pw.error is not None or inst is None
                or inst.state != FunctionInstance.WARM
                or not getattr(inst.node, "alive", True)):
            return None
        rec.node = inst.node.name
        rec.prewarmed = True
        rec.t_prov_end = rec.t_startup_end = clock.now()
        with self._lock:
            self.stats["adoptions"] += 1
        self.cluster.bus.publish("scheduling.placed", {
            "function": spec.name, "node": inst.node.name,
            "invocation": inv_id, "warm": False, "prewarm_adopted": True,
            "t": clock.now()})
        return inst

    def _checkin(self, fn: str, inst: FunctionInstance) -> None:
        """Return an instance to the warm pool — bounded: past the pool's
        ``max`` the instance is discarded (scale-down) instead of appended,
        so a burst no longer inflates the pool permanently."""
        inst.idle_since = self.cluster.clock.now()
        with self._lock:
            limit = self._pool_limits.get(fn,
                                          (self.DEFAULT_POOL_MAX, None, 0))
            pool = self._warm.setdefault(fn, [])
            if len(pool) < limit[0]:
                pool.append(inst)
            else:
                self.stats["pool_drops"] += 1

    def checkin_prewarmed(self, fn: str, inst: FunctionInstance) -> None:
        """A pool-provisioned instance lands in the warm pool (subject to
        the same cap as any checkin)."""
        self._checkin(fn, inst)

    def _expire_idle_locked(self, fn: str, now: float) -> None:
        """Drop WARM instances idle past the pool's TTL, keeping the newest
        ``min`` as a floor. Caller holds ``self._lock``."""
        limit = self._pool_limits.get(fn)
        if limit is None or limit[1] is None:
            return
        _max, ttl, keep = limit
        pool = self._warm.get(fn)
        if not pool or len(pool) <= keep:
            return
        clock = self.cluster.clock
        expired = [
            inst for inst in pool
            if inst.state == FunctionInstance.WARM
            and clock.elapsed_sim(now - inst.idle_since) > ttl]
        # floor: retain the most-recently idle of the expired set
        excess = expired[:max(len(pool) - keep, 0)] if keep else expired
        if not excess:
            return
        gone = set(map(id, excess))
        self._warm[fn] = [i for i in pool if id(i) not in gone]
        self.stats["pool_expired"] += len(excess)

    def _checkout_warm(self, fn: str) -> Optional[FunctionInstance]:
        health = getattr(self.cluster, "health", None)
        with self._lock:
            self._expire_idle_locked(fn, self.cluster.clock.now())
            pool = self._warm.get(fn, [])
            for i, inst in enumerate(pool):
                if inst.state != FunctionInstance.WARM:
                    continue
                # a warm container on a crashed node is gone; one on a
                # degraded node must not short-circuit the scheduler's
                # steering — leave it to the drain
                if not getattr(inst.node, "alive", True):
                    continue
                if health is not None and health.state(inst.node.name) in (
                        "degraded", "dead"):
                    continue
                return pool.pop(i)
        return None

    def purge_node(self, name: str) -> int:
        """Drop every warm instance on ``name`` (node crash: the sandboxes
        died with it). Returns how many were purged."""
        purged = 0
        with self._lock:
            for fn, pool in self._warm.items():
                keep = [i for i in pool if i.node.name != name]
                purged += len(pool) - len(keep)
                self._warm[fn] = keep
        return purged
