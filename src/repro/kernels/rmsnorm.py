"""Fused RMSNorm (row-blocked): one VMEM pass computes the rsqrt(mean-square)
and applies the learned scale — no separate mean/normalize HBM round-trips."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * r * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_2d(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
               block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = False) -> jax.Array:
    """x [N, D]; scale [D] -> [N, D]."""
    N, D = x.shape
    block_rows = min(block_rows, N)
    assert N % block_rows == 0, (N, block_rows)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(N // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, scale)
    return out
