"""Blocked causal flash attention (FA-2 style) for TPU via Pallas.

TPU adaptation of the GPU algorithm (DESIGN.md §6): the KV stream is a grid
dimension with VMEM-resident (Bq x d) / (Bk x d) tiles sized to the MXU's
128-lane geometry; running (m, l, acc) live in VMEM scratch that persists
across the innermost ("arbitrary") grid dimension — the Pallas/TPU idiom for
the CUDA shared-memory loop. GQA is handled in the K/V index map (q-head h
reads kv-head h // G), so no head replication is materialized.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, block_q: int, block_k: int, causal: bool,
               num_kv_blocks: int):
    i = pl.program_id(2)               # q block
    j = pl.program_id(3)               # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (j * block_k <= i * block_q + block_q - 1) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [Bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                  # [Bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [Bq,Bk]
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        # guard fully-masked rows (exp(-inf - -inf)) — keep them zero
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, ...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False) -> jax.Array:
    """q [B,Hq,S,d]; k/v [B,Hkv,S,d] -> [B,Hq,S,d]."""
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = d ** -0.5

    kernel = functools.partial(_fa_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, causal=causal, num_kv_blocks=nk)
    grid = (B, Hq, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
