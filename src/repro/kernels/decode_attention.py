"""Flash-decode: single-query attention against a long KV cache (the serving
hot spot that Truffle's CSP feeds).

Grid: (batch, kv_head, kv_block); the GQA query group for that kv head
([G, d]) stays VMEM-resident while KV tiles stream; running (m, l, acc)
persist in VMEM scratch across the kv-block grid dim. ``kv_len`` arrives via
scalar-prefetch SMEM so block masking is known before the tile loads."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")
DEFAULT_BLOCK_K = 256


def _decode_kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, block_k: int, num_kv_blocks: int,
                   scale: float):
    j = pl.program_id(2)
    kv_len = kv_len_ref[0]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(j * block_k < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # [G, d]
        k = k_ref[0, 0].astype(jnp.float32)                    # [Bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [G, Bk]
        cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, ...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_bhd(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array, *, block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False) -> jax.Array:
    """q [B,Hq,d]; k/v [B,Hkv,S,d]; kv_len scalar int32 -> [B,Hq,d]."""
    B, Hq, d = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    qg = q.reshape(B, Hkv, G, d)

    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               num_kv_blocks=nk, scale=d ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, *_: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, *_: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, d), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32).reshape(1), qg, k, v)
    return out.reshape(B, Hq, d)
