"""Jit'd public wrappers for the Pallas kernels.

Layout contract with the model code: attention tensors arrive [B, S, H, D]
(model layout) and are transposed to the kernels' [B, H, S, D]. Backward
passes go through ``jax.custom_vjp`` with the reference implementation's
gradient (recompute — standard flash-attention training setup).
Dispatch: impl='pallas' on real TPUs, 'pallas_interpret' in CPU tests,
'xla' for dry-run lowering (TPU pallas_call cannot lower to host)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_bhd
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rmsnorm import rmsnorm_2d


# ---------------------------------------------------------------- attention
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, interpret: bool = False):
    """q [B,S,Hq,D]; k/v [B,S,Hkv,D] -> [B,S,Hq,D]."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


def _fa_fwd(q, k, v, causal, interpret):
    return flash_attention(q, k, v, causal, interpret), (q, k, v)


def _fa_bwd(causal, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.flash_attention_ref(q_, k_, v_, causal),
                     q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def decode_attention(q, k, v, kv_len, interpret: bool = False):
    """q [B,1,Hq,D]; k/v [B,S,Hkv,D] (cache); kv_len scalar -> [B,1,Hq,D]."""
    q3 = q[:, 0]                                   # [B,Hq,D]
    kt = jnp.swapaxes(k, 1, 2)                     # [B,Hkv,S,D]
    vt = jnp.swapaxes(v, 1, 2)
    out = decode_attention_bhd(q3, kt, vt, kv_len, interpret=interpret)
    return out[:, None]


# ------------------------------------------------------------------ rmsnorm
def rmsnorm(x, scale, eps: float = 1e-6, interpret: bool = False):
    """x [..., D]; scale [D]."""
    shape = x.shape
    out = rmsnorm_2d(x.reshape(-1, shape[-1]), scale, eps=eps,
                     interpret=interpret)
    return out.reshape(shape)


# -------------------------------------------------- data-plane codec kernel
@jax.jit
def _byte_entropy_bits(x):
    """Order-0 Shannon entropy (bits/byte) of a uint8 sample window — the
    chunk codec's compressibility probe as one vectorized histogram +
    reduction instead of a Python-level deflate of the window."""
    counts = jnp.bincount(x, length=256).astype(jnp.float32)
    p = counts / jnp.maximum(x.shape[0], 1)
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(p), 0.0))


def entropy_wire_ratio(data, floor: float = 0.05) -> float:
    """Estimated wire/payload byte ratio from the window's byte entropy.

    Order-0 entropy lower-bounds what ANY byte-level codec can keep, and
    ignores match/repeat structure — so this is a cheap, vectorizable
    estimator, not a replacement for measuring the codec: highly
    repetitive but byte-diverse payloads (e.g. a repeated 256-byte
    pattern) estimate near 1.0 where deflate would crush them. Use where
    estimator throughput matters more than estimator fidelity."""
    import numpy as np
    buf = np.frombuffer(memoryview(data), dtype=np.uint8)
    if buf.size == 0:
        return 1.0
    bits = float(_byte_entropy_bits(jnp.asarray(buf)))
    return min(1.0, max(floor, bits / 8.0))
