"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q [B,S,Hq,D]; k/v [B,S,Hkv,D] (GQA: Hq % Hkv == 0) -> [B,S,Hq,D]."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * D ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(B, Sq, Hq, D)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array) -> jax.Array:
    """q [B,Hq,D]; k/v [B,S,Hkv,D]; kv_len scalar — attend to [0, kv_len)."""
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k).astype(jnp.float32) * D ** -0.5
    valid = jnp.arange(S)[None, None, None, :] < kv_len
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v)
    return out.reshape(B, Hq, D)


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
