"""Sharding rules: divisibility fallbacks, no-axis-reuse, ZeRO-1 placement,
and the per-shape rule presets — plus a hypothesis property sweep."""
import jax
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # optional dep: vendored deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (default_rules, rules_for_shape,
                                        spec_for_axes)
from repro.distributed.zero import zero1_spec
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def mesh():
    # 1 real device; abstract mesh construction needs none
    try:
        return jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    except TypeError:   # jax 0.4.x signature: ((name, size), ...) pairs
        return jax.sharding.AbstractMesh((("data", 16), ("model", 16)))


def test_spec_basic(mesh):
    rules = default_rules()
    spec = spec_for_axes(mesh, rules, (4096, 13696), ("embed", "ff"))
    assert spec == P(None, "model")


def test_spec_divisibility_fallback(mesh):
    rules = default_rules()
    # glm4: 2 kv heads cannot shard over 16-way model axis -> replicate
    spec = spec_for_axes(mesh, rules, (128, 4096, 2, 128),
                         ("cache_batch", "cache_seq", "cache_heads", None))
    assert spec in (P("data", None, None), P("data"))


def test_spec_no_axis_reuse(mesh):
    rules = default_rules()
    spec = spec_for_axes(mesh, rules, (64, 64), ("heads", "ff"))
    # both want 'model'; only the first gets it
    assert spec == P("model")


def test_decode_rules_seq_shard(mesh):
    rules = rules_for_shape("decode", global_batch=128, seq_len=32768)
    spec = spec_for_axes(mesh, rules, (40, 128, 32768, 2, 128),
                         ("layers", "cache_batch", "cache_seq", "cache_heads",
                          None))
    assert spec == P(None, "data", "model")


def test_long_context_rules(mesh):
    rules = rules_for_shape("decode", global_batch=1, seq_len=524288)
    spec = spec_for_axes(mesh, rules, (4, 1, 524288, 8, 128),
                         ("layers", "cache_batch", "cache_seq", "cache_heads",
                          None))
    assert spec == P(None, None, ("data", "model"))


def test_zero1_spec(mesh):
    # param replicated on model axis dims -> moments shard over data
    spec = zero1_spec(P(None, "model"), (4096, 13696), mesh, ("data",))
    assert spec == P("data", "model")
    # scalar: nothing to shard
    assert zero1_spec(P(), (), mesh, ("data",)) == P()
    # non-divisible: stays put
    assert zero1_spec(P(), (7,), mesh, ("data",)) == P()


@settings(max_examples=60, deadline=None)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
       names=st.lists(st.sampled_from(["heads", "ff", "embed", "batch", None]),
                      min_size=1, max_size=4))
def test_spec_property_never_invalid(mesh, dims, names):
    """Property: produced specs never shard a non-divisible dim and never
    reuse a mesh axis across dims."""
    n = min(len(dims), len(names))
    dims, names = tuple(dims[:n]), tuple(names[:n])
    rules = default_rules()
    spec = spec_for_axes(mesh, rules, dims, names)
    used = []
    for dim, entry in zip(dims, tuple(spec) + (None,) * (n - len(spec))):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for ax in axes:
            assert ax not in used
            used.append(ax)
        size = 1
        for ax in axes:
            size *= mesh.shape[ax]
        assert dim % size == 0
