"""Concurrency auditor tests: each static rule demonstrated on a fixture,
the repo itself clean against the baseline, the dynamic checker catching a
synthetic inversion, and the DigestRegistry re-entrancy regression."""
import os
import subprocess
import sys
import threading

from repro.analysis import lockcheck
from repro.analysis.lockgraph import analyze_paths
from repro.analysis.rules import evaluate, load_baseline, split_baselined

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "analysis_fixtures")
REPO = os.path.dirname(HERE)
SRC = os.path.join(REPO, "src", "repro")


def _violations(fixture):
    prog = analyze_paths([os.path.join(FIXTURES, fixture)])
    return evaluate(prog)


# ----------------------------------------------------------- static rules

def test_r1_lock_order_cycle_caught():
    viols = _violations("fx_cycle.py")
    r1 = [v for v in viols if v.rule == "R1"]
    assert r1, "opposite-order lock acquisition must raise R1"
    blob = " ".join(v.ident for v in r1)
    assert "CycleA._lock" in blob and "CycleB._lock" in blob


def test_r2_blocking_under_lock_caught():
    viols = _violations("fx_publish_under_lock.py")
    r2 = {v.ident for v in viols if v.rule == "R2"}
    assert any("publish" in i for i in r2), "bus.publish under lock is R2"
    assert any("sleep" in i for i in r2), "time.sleep under lock is R2"


def test_r3_unlocked_write_caught():
    viols = _violations("fx_unlocked_write.py")
    r3 = [v for v in viols if v.rule == "R3"]
    assert any("Counter.reset" in v.ident and "_count" in v.ident
               for v in r3), "unlocked write to a guarded attr is R3"


def test_r4_locked_suffix_misuse_caught():
    viols = _violations("fx_locked_misuse.py")
    r4 = [v for v in viols if v.rule == "R4"]
    assert any("drop_fast" in v.ident for v in r4), \
        "_locked call without the lock is R4"
    assert not any("Table.drop|" in v.ident for v in r4), \
        "the correctly-locked call site must NOT be flagged"


def test_r5_silent_except_caught():
    viols = _violations("fx_silent_except.py")
    assert any(v.rule == "R5" for v in viols)


def test_clean_fixture_passes():
    assert _violations("fx_clean.py") == []


def test_repo_clean_against_baseline():
    """The shipped tree has zero non-baselined violations (the CI gate)."""
    prog = analyze_paths([os.path.join(SRC, "core"),
                          os.path.join(SRC, "runtime")])
    viols = evaluate(prog)
    baseline = load_baseline(os.path.join(SRC, "analysis", "baseline.json"))
    fresh, _ = split_baselined(viols, baseline)
    assert fresh == [], "new violations:\n" + "\n".join(
        f"{v.ident}: {v.message}" for v in fresh)


def test_fleet_subpackage_is_walked_and_its_locks_named():
    """The auditor's default roots cover ``runtime/fleet/`` and resolve the
    fleet classes' lock identities (NAME_HINTS), so new fleet code cannot
    silently escape the lock-graph."""
    from repro.analysis.__main__ import DEFAULT_PATHS

    assert any(p.endswith(os.path.join("runtime", "fleet"))
               for p in DEFAULT_PATHS)
    prog = analyze_paths(DEFAULT_PATHS)
    for ident in ("FleetGate._lock", "WarmPools._lock",
                  "TenantLedger._lock", "CasSharing._lock", "Fleet._lock"):
        assert ident in prog.decls, f"{ident} missing from lock graph"
    # dual roots (runtime/ AND the explicit runtime/fleet/ entry) must not
    # double-count files reached through both
    without_dup = analyze_paths(
        [p for p in DEFAULT_PATHS
         if not p.endswith(os.path.join("runtime", "fleet"))])
    assert len(prog.acqs) == len(without_dup.acqs)


def test_cli_exits_zero_on_clean_tree():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-m", "repro.analysis"],
                          cwd=REPO, env=env, capture_output=True, text=True,
                          timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_exits_nonzero_on_violations():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--no-baseline",
         os.path.join(FIXTURES, "fx_cycle.py")],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 1
    assert "R1" in proc.stdout


# --------------------------------------------------------- dynamic checker

def test_lockcheck_detects_inversion():
    with lockcheck.isolated():
        lock_a = lockcheck._CheckedLock()
        lock_b = lockcheck._CheckedLock()

        def ab():
            with lock_a:
                with lock_b:
                    pass

        def ba():
            with lock_b:
                with lock_a:
                    pass

        for fn in (ab, ba):                  # opposite orders, two threads
            t = threading.Thread(target=fn, daemon=True)
            t.start()
            t.join(timeout=5.0)
        invs = lockcheck.inversions()
        assert len(invs) == 1
        pair = invs[0]["pair"]
        assert pair[0] != pair[1]
        assert invs[0]["witness_ab"]["stack"]   # witness trace captured


def test_lockcheck_consistent_order_is_clean():
    with lockcheck.isolated():
        lock_a = lockcheck._CheckedLock()
        lock_b = lockcheck._CheckedLock()
        for _ in range(3):
            with lock_a:
                with lock_b:
                    pass
        assert lockcheck.inversions() == []
        assert lockcheck.report()["order_edges"] == 1


def test_lockcheck_long_hold_warns(monkeypatch):
    monkeypatch.setattr(lockcheck, "HOLD_S", 0.0)
    with lockcheck.isolated():
        lock = lockcheck._CheckedLock()
        with lock:
            pass
        holds = lockcheck.long_holds()
        assert holds and holds[0]["site"].startswith("test_analysis.py")


def test_lockcheck_rlock_reentry_no_self_edge():
    with lockcheck.isolated():
        rl = lockcheck._CheckedRLock()
        with rl:
            with rl:                # re-entrant: adds no ordering info
                pass
        assert lockcheck.report()["order_edges"] == 0
        # Condition protocol must survive the wrapper
        cv = threading.Condition(rl)
        with cv:
            cv.notify_all()


# ------------------------------------------- satellite 1: re-entrancy fix

def _run_with_deadline(fn, timeout=5.0):
    done = []

    def drive():
        fn()
        done.append(True)

    t = threading.Thread(target=drive, daemon=True)
    t.start()
    t.join(timeout=timeout)
    return bool(done)


def test_registry_subscriber_reentry_no_deadlock():
    """A bus subscriber that re-enters the DigestRegistry (query AND nested
    publish) must not deadlock: events fire after ``_lock`` is released."""
    from repro.runtime.events import EventBus
    from repro.runtime.registry import DigestRegistry, EVENT_DIGEST_ADDED

    bus = EventBus()
    reg = DigestRegistry(bus)
    seen = []

    def reenter(evt):
        seen.append((evt["digest"], reg.nodes_for(evt["digest"])))
        if evt["digest"] == "d1":
            reg.publish("n2", "d2", 7)      # nested publish from delivery

    bus.subscribe(EVENT_DIGEST_ADDED, reenter)
    assert _run_with_deadline(lambda: reg.publish("n1", "d1", 5)), \
        "subscriber re-entering DigestRegistry deadlocked"
    assert ("d1", {"n1": 5}) in seen        # state visible at delivery time
    assert reg.nodes_for("d2") == {"n2": 7}


def test_registry_withdraw_reentry_no_deadlock():
    from repro.runtime.events import EventBus
    from repro.runtime.registry import DigestRegistry, EVENT_DIGEST_REMOVED

    bus = EventBus()
    reg = DigestRegistry(bus)
    reg.publish("n1", "d1", 5)
    views = []
    bus.subscribe(EVENT_DIGEST_REMOVED,
                  lambda evt: views.append(reg.nodes_for(evt["digest"])))
    assert _run_with_deadline(lambda: reg.withdraw("n1", "d1"))
    assert views == [{}]                    # withdrawal applied before event


def test_buffer_flush_subscriber_reentry_no_deadlock():
    """Full chain: Buffer.set → residency flush → registry → bus → a
    subscriber that re-enters BOTH the buffer and the registry."""
    from repro.core.buffer import Buffer, content_digest
    from repro.runtime.events import EventBus
    from repro.runtime.registry import DigestRegistry, EVENT_DIGEST_ADDED

    bus = EventBus()
    reg = DigestRegistry(bus)
    buf = Buffer(capacity_bytes=1 << 20, name="n1")
    buf.on_residency = reg.listener("n1")
    data = b"x" * 64
    digest = content_digest(data)
    got = []
    bus.subscribe(EVENT_DIGEST_ADDED,
                  lambda evt: got.append((buf.get("k"),
                                          reg.nodes_for(evt["digest"]))))
    assert _run_with_deadline(lambda: buf.set("k", data, digest=digest))
    assert got == [(data, {"n1": len(data)})]
