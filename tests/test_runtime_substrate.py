"""Runtime substrate: event bus semantics, network-channel timing/contention,
and the Fig. 2 property the whole paper rests on — the target host is known
(watcher-resolvable) BEFORE the sandbox is provisioned."""
import threading
import time

import pytest

from repro.runtime.clock import Clock
from repro.runtime.cluster import Cluster
from repro.runtime.events import EventBus
from repro.runtime.function import FunctionSpec, Request
from repro.runtime.netsim import Channel, GBPS


# ---------------------------------------------------------------- event bus
def test_bus_history_replay():
    bus = EventBus()
    bus.publish("t", {"x": 1})
    got = bus.wait_for("t", lambda e: e["x"] == 1, timeout=0.1)
    assert got == {"x": 1}                      # late joiner sees history


def test_bus_wait_future_event():
    bus = EventBus()
    box = {}

    def waiter():
        box["e"] = bus.wait_for("t", lambda e: e["x"] == 2, timeout=5)

    th = threading.Thread(target=waiter)
    th.start()
    bus.publish("t", {"x": 1})                  # non-matching
    bus.publish("t", {"x": 2})
    th.join(timeout=5)
    assert box["e"] == {"x": 2}


def test_bus_timeout_returns_none():
    bus = EventBus()
    assert bus.wait_for("never", lambda e: True, timeout=0.05) is None


def test_bus_subscribe_callback():
    bus = EventBus()
    seen = []
    bus.subscribe("s", seen.append)
    bus.publish("s", {"k": 1})
    assert seen == [{"k": 1}]


# ------------------------------------------------------------------ netsim
def test_channel_transfer_time_model():
    ch = Channel("t", bandwidth=100e6, latency=0.01, clock=Clock(0.0))
    assert ch.transfer_time(100_000_000) == pytest.approx(1.01)
    # measured wall time matches modeled time at scale
    ch2 = Channel("t2", bandwidth=10 * GBPS, latency=0.0, clock=Clock(0.01))
    t0 = time.monotonic()
    modeled = ch2.transfer(bytes(1 << 20))
    wall = time.monotonic() - t0
    assert wall >= modeled * 0.01 * 0.5


def test_channel_contention_serializes():
    """Two concurrent transfers on one channel share bandwidth (serialize)."""
    clock = Clock(1.0)
    ch = Channel("c", bandwidth=10e6, latency=0.0, clock=clock)  # 10 MB/s
    payload = bytes(500_000)  # 50 ms each

    t0 = time.monotonic()
    ths = [threading.Thread(target=ch.transfer, args=(payload,))
           for _ in range(2)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    wall = time.monotonic() - t0
    assert wall >= 0.09                          # ~2 x 50 ms, not ~50 ms


# ------------------------------------------- Fig. 2: host known before Fn-up
def test_host_known_before_provisioning_ends(fast_clock):
    """The Watcher resolves the placement while the sandbox is still cold —
    the structural fact SDP/CSP exploit (paper Fig. 2)."""
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("fig2-fn", lambda d, inv: d, provision_s=1.0,
                        startup_s=0.3)
    cluster.platform.register(spec)

    fut, rec = cluster.platform.invoke_async(
        Request(fn="fig2-fn", payload=b"x", source_node="edge-0"))
    inv_id = None
    # resolve via the bus (any invocation of this function)
    node = cluster.node_list[0].truffle.watcher.resolve_host(
        "fig2-fn", inv_id, timeout=5)
    t_resolved = cluster.clock.now()
    fut.result()
    assert node in cluster.nodes
    # resolution strictly precedes the end of provisioning (ν), i.e. there
    # was a usable overlap window of ~β
    assert t_resolved < rec.t_prov_end
    assert rec.t_prov_end - t_resolved >= 0.5 * fast_clock.scale
