"""Chaos/soak tier: long chained workflows under scripted fault timelines.

The repo's unit tests assert single mechanisms; this tier asserts that
NOTHING ACCUMULATES. A 50+-wave chained workflow (chunk-streamed, dedup'd,
capacity-pressured buffers) runs under degrade/recover/flap timelines and
the test then checks the system drained back to baseline: no leaked
executor/data-path threads, no in-flight relay-table entries, no
outstanding scheduler load credits, no incomplete (writer-abandoned)
buffer entries, buffers within capacity. A second soak runs WITH mid-flight
re-planning enabled under a flapping link and asserts the replan rate
limits held while the run still completed.

Also here: the telemetry tear regression — hammering channel grants while
concurrently snapshotting and reseeding (``Cluster.reseed_telemetry``)
must never produce a torn snapshot (half-old/half-new tier priors) or a
bandwidth estimate outside the envelope of configurations that ever
existed. (Seeds are replaced in one telemetry lock hold; channel
reconfiguration happens under the channel's grant lock.)

``SOAK_WAVES`` (env) scales the chain length — the nightly CI soak job
runs it longer than the PR-path default.
"""
import os
import threading
import time

import pytest

from harness import FaultTimeline
from repro.runtime.clock import Clock
from repro.runtime.cluster import Cluster
from repro.runtime.function import FunctionSpec
from repro.runtime.netsim import GBPS
from repro.runtime.planner import EdgeProfile
from repro.runtime.policy import (DataPolicy, ReplanPolicy, RetryPolicy,
                                  WorkflowBuilder)
from repro.runtime.workflow import WorkflowRunner

MB = 1 << 20
SOAK_WAVES = max(50, int(os.environ.get("SOAK_WAVES", "55")))
# node-churn chaos rides the nightly soak job (SOAK_NODE_FAULTS=1): crashes
# mid-run are deliberately violent (CAS loss, link teardown) and the
# recovery machinery has its own unit tier (test_node_faults.py)
NODE_FAULTS = os.environ.get("SOAK_NODE_FAULTS", "") not in ("", "0")
# multi-tenant serving soak (SOAK_TENANTS=8 on the nightly job): N tenants
# flood the fleet gate concurrently; the unit tier lives in test_fleet.py
SOAK_TENANTS = int(os.environ.get("SOAK_TENANTS", "0") or 0)


# ------------------------------------------------------------------ helpers
def _soak_chain(tag: str, waves: int, size: int, policy: DataPolicy,
                nodes=("edge-0", "edge-1", "cloud-0"), pin: bool = True):
    """Linear chain of ``waves`` stages round-robined over ``nodes``; every
    stage emits DISTINCT content (dedup must not collapse the chain into
    aliases — we want real transfers churning the buffers). ``pin=False``
    leaves stages unpinned so the health-scored scheduler places them —
    node-churn soaks need placements free to steer off sick nodes."""
    b = WorkflowBuilder(f"soak-{tag}", default_policy=policy)
    prev = None
    for i in range(waves):
        def handler(d, inv, _i=i):
            return _i.to_bytes(4, "big") * (size // 4)
        sb = b.stage(f"w{i}", FunctionSpec(
            f"soak-{tag}-{i}", handler, provision_s=0.08, startup_s=0.02,
            exec_s=0.005,
            affinity=nodes[i % len(nodes)] if pin else None))
        if prev is not None:
            sb.after(prev)
        prev = f"w{i}"
    return b.build()


def _incomplete_entries(cluster) -> list:
    """In-flight (non-aborted) stream entries across all buffers. Aborted
    entries are tombstones a failed data path left for its reader —
    consumed on wait, zero-sized, not leaks."""
    leaked = []
    for node in cluster.node_list:
        with node.buffer._lock:
            leaked += [(node.name, e.key)
                       for e in node.buffer._entries.values()
                       if not e.complete and not e.aborted]
    return leaked


def _assert_drained(cluster, base_threads: int, slack: int = 3) -> None:
    """Every per-run resource returned to baseline. Quiescence is polled as
    a whole — threads, relay table, AND in-flight stream entries — because
    a background shipper (e.g. a health-triggered evacuation thread) can
    hide inside the thread slack while its stream is still landing; only an
    entry still incomplete after the deadline is a leak."""
    deadline = time.monotonic() + 15
    while (threading.active_count() > base_threads + slack
           or cluster.relays._inflight or _incomplete_entries(cluster)) \
            and time.monotonic() < deadline:
        time.sleep(0.05)
    assert threading.active_count() <= base_threads + slack, \
        [t.name for t in threading.enumerate()]
    assert cluster.relays._inflight == {}          # no wedged relays
    assert _incomplete_entries(cluster) == []      # no abandoned streams
    for node in cluster.node_list:
        assert cluster.scheduler.load_of(node.name) == 0
        with node.buffer._lock:
            size, cap = node.buffer._size, node.buffer.capacity
        assert size <= cap


# ------------------------------------------------------------------- soaks
def test_soak_long_chain_under_fault_timeline_no_leaks():
    """50+ cold-start waves of chunk-streamed dedup'd passing, buffers
    under capacity pressure (forced eviction churn all run long), while
    the fabric degrades, recovers, and flaps mid-run. The run completes
    and every resource drains back to baseline."""
    base_threads = threading.active_count()
    cluster = Cluster(clock=Clock(0.004))
    size = 256 * 1024
    for node in cluster.node_list:                 # ~8 entries per buffer
        node.buffer.capacity = 2 * MB
    wf = _soak_chain("leak", SOAK_WAVES, size,
                     DataPolicy(stream=True, dedup=True))
    runner = WorkflowRunner(cluster, use_truffle=True)
    mid, late = SOAK_WAVES // 3, 2 * SOAK_WAVES // 3
    with FaultTimeline(cluster) as tl:
        tl.degrade_at(2, "edge-0", "edge-1", bandwidth_factor=0.2,
                      extra_rtt=0.002)
        tl.restore_at(mid)
        tl.flap("edge-1", "cloud-0", waves=(late, late + 2, late + 4,
                                            late + 6),
                bandwidth_factor=0.25)
        tr = runner.run(wf, b"go", source_node="edge-0")

    assert len(tr.stages) == SOAK_WAVES
    assert all(sr.record.t_exec_end > 0 for sr in tr.stages.values())
    waves = [e["wave"] for e in cluster.bus.history("workflow.stage_done")]
    assert waves == list(range(1, SOAK_WAVES + 1))
    assert [w for w, _ in tl.log] == [2, mid, late, late + 2, late + 4,
                                      late + 6]
    # capacity pressure really exercised the (residency-aware) evictor
    assert sum(n.buffer.stats["evictions"] for n in cluster.node_list) > 0
    _assert_drained(cluster, base_threads)


def test_soak_with_replanning_under_flap():
    """A 30-wave auto-planned chain with re-planning enabled while a link
    flaps (with ambient probe traffic converging telemetry each phase):
    the run completes, at least one replan fires, the rate limits hold,
    and nothing leaks."""
    base_threads = threading.active_count()
    waves = 30
    cluster = Cluster(clock=Clock(0.004))
    size = 4 * MB
    nodes = ("edge-0", "edge-1", "cloud-0")
    wf = _soak_chain("replan", waves, size, DataPolicy(strategy="auto"),
                     nodes=nodes)
    profiles = {
        (f"w{i}", f"w{i+1}"): EdgeProfile(
            size=size, src_node=nodes[i % 3], dst_node=nodes[(i + 1) % 3],
            compress_ratio=0.05)
        for i in range(waves - 1)}
    pol = ReplanPolicy(drift_ratio=1.2, min_interval=0.5, max_replans=3)
    runner = WorkflowRunner(cluster, use_truffle=True, replan=pol)
    plan = runner.compile(wf, profiles=profiles)
    with FaultTimeline(cluster) as tl:
        tl.flap("edge-0", "edge-1", waves=(5, 11, 17, 23),
                bandwidth_factor=0.01, probes=15, probe_bytes=256 * 1024)
        tr = runner.run(wf, b"go", source_node="edge-0", plan=plan)

    assert len(tr.stages) == waves
    assert 1 <= tr.plan_generation <= pol.max_replans
    assert len(tr.replans) == tr.plan_generation
    assert len(cluster.bus.history("plan.replanned")) == tr.plan_generation
    # every record names the generation that dispatched it, monotonically
    gens = [tr.stages[f"w{i}"].record.replan_count for i in range(waves)]
    assert gens == sorted(gens)
    assert gens[-1] == tr.plan_generation
    _assert_drained(cluster, base_threads)


@pytest.mark.skipif(not NODE_FAULTS, reason="set SOAK_NODE_FAULTS=1")
def test_soak_node_churn_crash_restart_no_leaks():
    """50+ waves of unpinned chained passing while nodes crash and restart
    on a rolling schedule (source node excluded) and one node is drained
    mid-run. The workflow always completes — retries re-ship from replicas,
    lineage re-execution covers lost last replicas — no placement ever
    lands inside a crash->restart dark window, placements steer off the
    drained node, and everything drains back to baseline."""
    base_threads = threading.active_count()
    cluster = Cluster(clock=Clock(0.004))
    waves = SOAK_WAVES
    size = 128 * 1024
    nodes = ("edge-0", "edge-1", "cloud-0")
    wf = _soak_chain("churn", waves, size,
                     DataPolicy(stream=True, dedup=True,
                                retry=RetryPolicy(max_attempts=3,
                                                  backoff_s=0.002)),
                     pin=False)
    runner = WorkflowRunner(cluster, use_truffle=True)
    # nominal round-robin profiles: placement is free to differ, but the
    # compile stamps per-stage Eq. 4 predictions we can bound against
    profiles = {
        (f"w{i}", f"w{i+1}"): EdgeProfile(
            size=size, src_node=nodes[i % 3], dst_node=nodes[(i + 1) % 3])
        for i in range(waves - 1)}
    plan = runner.compile(wf, profiles=profiles)
    victims = ["edge-1", "cloud-0"]
    drain_t = []
    with FaultTimeline(cluster) as tl:
        for k, w in enumerate(range(8, waves - 10, 12)):
            v = victims[k % 2]
            tl.crash_at(w, v)
            tl.restart_node_at(w + 6, v)

        def drain(_faults):
            cluster.drain_node("edge-1")
            drain_t.append(cluster.clock.now())

        tl.at_wave(waves - 6, drain, "drain edge-1")
        tr = runner.run(wf, b"go", source_node="edge-0", plan=plan)

    assert len(tr.stages) == waves
    waves_seen = [e["wave"] for e in cluster.bus.history("workflow.stage_done")]
    assert waves_seen == list(range(1, waves + 1))

    # no placement inside any crash->restart dark window
    downs = {}                       # node -> [crash_t, ...] / [restart_t...]
    for e in cluster.bus.history("node.crashed"):
        downs.setdefault(e["node"], []).append([e["t"], float("inf")])
    for e in cluster.bus.history("node.restarted"):
        for span in downs.get(e["node"], []):   # close the oldest open span
            if span[1] == float("inf"):
                span[1] = e["t"]
                break
    placed = cluster.bus.history("scheduling.placed")
    for node, spans in downs.items():
        for t0, t1 in spans:
            dark = [e for e in placed
                    if e["node"] == node and t0 < e["t"] < t1]
            assert dark == [], (node, t0, t1, dark)

    # degraded-node steering: nothing placed on the drained node afterwards
    assert drain_t, "drain action never fired"
    assert [e for e in placed
            if e["node"] == "edge-1" and e["t"] > drain_t[0]] == []

    # prediction error stays bounded across churn: every stage carries its
    # plan prediction and the typical first-attempt stage lands within an
    # order of magnitude of it (at this tiny clock scale host-scheduling
    # noise dominates — this catches systemic stalls, not Eq. 4 drift)
    ratios = sorted(
        cluster.clock.elapsed_sim(sr.record.total) / sr.record.predicted_s
        for sr in tr.stages.values()
        if sr.attempts == 1 and sr.record.predicted_s)
    assert ratios, "no prediction-stamped stages"
    assert 0 < ratios[len(ratios) // 2] < 10.0, ratios[len(ratios) // 2]
    _assert_drained(cluster, base_threads)


@pytest.mark.skipif(not SOAK_TENANTS, reason="set SOAK_TENANTS=N")
def test_soak_multitenant_fleet_drains_and_conserves():
    """N tenants flood one fleet with identical chains. Every submission
    must eventually admit and complete (aging: no starvation), identical
    cross-tenant content aliases (ledger bytes conserved), warm pools stay
    capped, and the cluster drains back to baseline."""
    from repro.runtime.fleet import Fleet, TenantQuota

    base_threads = threading.active_count()
    cluster = Cluster(clock=Clock(0.004))
    fleet = Fleet(cluster, fleet_max=4, ordering="predicted")
    runs = []
    for i in range(SOAK_TENANTS):
        tenant = f"t{i}"
        fleet.register_tenant(tenant, TenantQuota(
            max_concurrent=2, max_queued=64, warm_slots=2))
        # one wf per tenant, SHARED spec names + identical stage outputs:
        # warm pools and the CAS both get cross-tenant reuse pressure
        wf = _soak_chain("mt", 8, 128 * 1024,
                         DataPolicy(stream=False, dedup=True))
        for _ in range(3):
            runs.append(fleet.submit(tenant, wf, b"go",
                                     source_node="edge-0"))

    for run in runs:
        tr = run.result(timeout=180)
        assert len(tr.stages) == 8

    stats = fleet.stats()
    for i in range(SOAK_TENANTS):
        st = stats["tenants"][f"t{i}"]
        assert st["completed"] == 3 and st["shed"] == 0
        assert st["running"] == 0 and st["queue_depth"] == 0
    assert fleet.gate.queue_depth() == 0 and fleet.gate.running() == 0
    # ledger conservation: charged shares sum exactly to resident bytes
    ledger = fleet.sharing.ledger
    charged = sum(ledger.charged(f"t{i}") for i in range(SOAK_TENANTS))
    assert abs(charged - ledger.physical_bytes()) < 1e-6
    if SOAK_TENANTS > 1:
        saved = sum(ledger.saved(f"t{i}") for i in range(SOAK_TENANTS))
        assert saved > 0                           # aliasing actually hit
    for i in range(8):
        assert len(cluster.platform._warm[f"soak-mt-{i}"]) \
            <= cluster.platform.pool_limit(f"soak-mt-{i}")[0]
    _assert_drained(cluster, base_threads)


def test_repeated_runs_on_one_cluster_reach_steady_state():
    """Back-to-back runs of the same workflow on one cluster must not
    accumulate warm instances, relay entries, load credits, or threads —
    the warm path reuses what the cold path built."""
    base_threads = threading.active_count()
    cluster = Cluster(clock=Clock(0.004))
    wf = _soak_chain("steady", 8, 128 * 1024,
                     DataPolicy(stream=True, dedup=True))
    runner = WorkflowRunner(cluster, use_truffle=True)
    for _ in range(4):
        tr = runner.run(wf, b"go", source_node="edge-0")
        assert len(tr.stages) == 8
    for i in range(8):
        pool = cluster.platform._warm[f"soak-steady-{i}"]
        assert len(pool) <= 2, (i, len(pool))      # no per-run pile-up
    _assert_drained(cluster, base_threads)


# ----------------------------------------- telemetry tear regression (PR 5)
def test_snapshot_and_reseed_never_tear_under_grants():
    """Regression: hammer grants on one link while another thread flips the
    fabric configuration through ``reseed_telemetry`` and a third snapshots
    telemetry. Atomic reseed + under-lock channel reconfiguration mean
    every snapshot shows ONE configuration for the quiet tiers (never a
    half-reseeded mix) and the hammered link's estimate stays inside the
    envelope of configurations that ever existed."""
    cluster = Cluster(clock=Clock(0.0))
    src, dst = cluster.node("edge-0"), cluster.node("edge-1")
    cfg_a = dict(cluster.network.tier_links)
    cfg_b = {k: (bw * 2, lat * 2) for k, (bw, lat) in cfg_a.items()}
    cluster.transfer(src, dst, bytes(1024))        # materialize the channel

    stop = threading.Event()
    errors = []
    snaps = []

    def hammer():
        payload = bytes(64 * 1024)
        try:
            while not stop.is_set():
                cluster.transfer(src, dst, payload)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def reseeder():
        try:
            for i in range(150):
                cluster.network.tier_links = cfg_b if i % 2 else cfg_a
                cluster.reseed_telemetry()
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def snapshotter():
        try:
            for _ in range(300):
                snaps.append(cluster.telemetry.snapshot())
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=f, daemon=True)
               for f in (hammer, hammer, reseeder, snapshotter)]
    for t in threads:
        t.start()
    threads[2].join(30)
    threads[3].join(30)
    stop.set()
    threads[0].join(30)
    threads[1].join(30)
    assert not errors, errors
    assert not any(t.is_alive() for t in threads)

    # tiers with NO traffic sit exactly on their seed: every snapshot must
    # show the SAME configuration for all of them (torn reseed = a mix)
    quiet = [("cloud", "cloud"), ("edge", "cloud"), ("cloud", "edge")]
    checked = 0
    for snap in snaps:
        tiers = snap["tiers"]
        if not all(k in tiers for k in quiet):
            continue
        labels = set()
        for k in quiet:
            est = tiers[k]
            if (est.bandwidth, est.rtt) == cfg_a[k]:
                labels.add("a")
            elif (est.bandwidth, est.rtt) == cfg_b[k]:
                labels.add("b")
            else:
                labels.add("torn")
        assert labels in ({"a"}, {"b"}), (labels, snap["tiers"])
        checked += 1
    assert checked >= len(snaps) // 2

    # the hammered link's estimate never left the [cfg_a, cfg_b] envelope:
    # a torn grant (bytes priced at one bandwidth, observed at another)
    # would have poisoned the EWMA with a rate that never existed
    lo = cfg_a[("edge", "edge")][0] * 0.999
    hi = cfg_b[("edge", "edge")][0] * 1.001
    est = cluster.telemetry.link("edge-0", "edge-1")
    assert est is not None and est.samples > 0
    assert lo <= est.bandwidth <= hi, (est.bandwidth, lo, hi)


def test_reseed_applies_to_live_channels_atomically():
    """reseed_telemetry recalibrates already-materialized channels through
    Channel.reconfigure (bandwidth AND latency move together)."""
    cluster = Cluster(clock=Clock(0.0))
    src, dst = cluster.node("edge-0"), cluster.node("cloud-0")
    ch = cluster.network.channel(src, dst)
    cluster.network.tier_links = dict(cluster.network.tier_links)
    cluster.network.tier_links[("edge", "cloud")] = (1.0 * GBPS, 0.001)
    cluster.reseed_telemetry()
    assert (ch.bandwidth, ch.latency) == (1.0 * GBPS, 0.001)
    est = cluster.telemetry.link(None, None, tiers=("edge", "cloud"))
    assert est.bandwidth == 1.0 * GBPS and est.samples == 0
