"""Checkpointing: roundtrip equality, atomicity/rotation, async saves,
restore-latest, byte-stream serialize (the CSP payload path)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (CheckpointManager, deserialize,
                                         serialize)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "blocks": {"scale": jnp.ones((4,), jnp.bfloat16)}},
        "opt": {"m": jnp.zeros((8, 16)), "step": jnp.asarray(7, jnp.int32)},
    }


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    s = _state()
    mgr.save(3, s)
    restored, step = mgr.restore(_state(seed=9))
    assert step == 3
    _assert_tree_equal(s, restored)


def test_latest_and_rotation(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(step))
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]          # rotated
    restored, step = mgr.restore(_state())
    _assert_tree_equal(_state(4), restored)


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = mgr.save_async(5, _state(5))
    mgr.wait()
    assert mgr.latest_step() == 5
    restored, _ = mgr.restore(_state())
    _assert_tree_equal(_state(5), restored)


def test_sharded_save(tmp_path):
    mgr = CheckpointManager(tmp_path, shard_bytes=128)  # force many shards
    s = _state()
    mgr.save(1, s)
    d = mgr.dir / "step-00000001"
    assert len(list(d.glob("shard-*.npz"))) > 1
    restored, _ = mgr.restore(_state(2))
    _assert_tree_equal(s, restored)


def test_serialize_bytes_roundtrip():
    s = _state()
    data = serialize(s)
    assert isinstance(data, bytes) and len(data) > 100
    restored = deserialize(data, _state(1))
    _assert_tree_equal(s, restored)


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state())
