"""Fleet subsystem: admission gate (quotas, Eq. 5 ordering, fairness),
warm pools (pre-warm, adoption, caps), CAS sharing (ledger conservation,
quota pressure, isolation), and the end-to-end multi-tenant serving path."""
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.runtime.cluster import Cluster
from repro.runtime.fleet import (AdmissionRejected, CasSharing, Fleet,
                                 FleetGate, PoolPolicy, TenantLedger,
                                 TenantQuota, WarmPools)
from repro.runtime.function import FunctionSpec, Request
from repro.runtime.policy import DataPolicy
from repro.runtime.workflow import Stage, Workflow


# ------------------------------------------------------------------ helpers

def _chain(tag, n=3, *, provision_s=0.4, payload=None, dedup=True):
    """n-stage chain whose every stage echoes its input (so content is
    identical across workflows built with the same payload)."""
    def handler(data, inv):
        return data or b"x"

    stages = {}
    for i in range(n):
        spec = FunctionSpec(f"fleet-{tag}-{i}", handler,
                            provision_s=provision_s, startup_s=0.1,
                            exec_s=0.02)
        stages[f"s{i}"] = Stage(spec, deps=[f"s{i-1}"] if i else [])
    return Workflow(f"wf-{tag}", stages,
                    default_policy=DataPolicy(strategy="direct", dedup=dedup))


# ------------------------------------------------------------ admission gate

class _ScriptClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_gate_predicted_ordering_is_sjf():
    """With the fleet full, the shortest predicted_total admits first."""
    now = _ScriptClock()
    gate = FleetGate(fleet_max=1, now_fn=now)
    hog = gate.submit("a", 5.0)
    long_t = gate.submit("a", 9.0)
    short_t = gate.submit("a", 1.0)
    assert hog.state == "admitted"
    assert long_t.state == "queued" and short_t.state == "queued"
    gate.complete(hog)
    assert short_t.state == "admitted", "SJF must pick the short job"
    assert long_t.state == "queued"


def test_gate_fifo_ordering_ignores_predictions():
    gate = FleetGate(fleet_max=1, ordering="fifo")
    hog = gate.submit("a", 5.0)
    first = gate.submit("a", 9.0)
    second = gate.submit("a", 1.0)
    gate.complete(hog)
    assert first.state == "admitted" and second.state == "queued"


def test_gate_sheds_past_queue_quota_with_typed_error():
    gate = FleetGate(fleet_max=1,
                     default_quota=TenantQuota(max_concurrent=1,
                                               max_queued=2))
    gate.submit("a", 1.0)                      # admitted
    gate.submit("a", 1.0)
    gate.submit("a", 1.0)                      # queue now at max_queued=2
    with pytest.raises(AdmissionRejected) as ei:
        gate.submit("a", 1.0)
    assert ei.value.tenant == "a"
    assert ei.value.reason == "queue-full"
    assert ei.value.depth >= ei.value.limit
    assert gate.stats()["a"]["shed"] == 1


def test_gate_per_tenant_concurrency_quota():
    """Tenant 'a' may not occupy the whole fleet past its own cap; 'b'
    gets the remaining slot even with worse predictions."""
    gate = FleetGate(fleet_max=4,
                     default_quota=TenantQuota(max_concurrent=2))
    a = [gate.submit("a", 1.0) for _ in range(4)]
    assert [t.state for t in a] == ["admitted", "admitted", "queued",
                                    "queued"]
    b = gate.submit("b", 100.0)
    assert b.state == "admitted", "within-quota tenant must not be starved"


def test_gate_aging_prevents_starvation():
    """An aged long job eventually beats fresher short jobs."""
    now = _ScriptClock()
    gate = FleetGate(fleet_max=1, aging_weight=1.0, now_fn=now)
    hog = gate.submit("x", 1.0)
    old_long = gate.submit("x", 50.0)
    now.t = 100.0                              # old_long has waited 100 s
    fresh_short = gate.submit("x", 1.0)
    gate.complete(hog)
    assert old_long.state == "admitted", \
        "aging must eventually dominate SJF (starvation freedom)"
    assert fresh_short.state == "queued"


def test_gate_tenant_weight_scales_rank():
    """A weight-2 tenant's jobs rank at half their predicted cost."""
    gate = FleetGate(fleet_max=1)
    gate.register("heavy", TenantQuota(weight=2.0))
    hog = gate.submit("x", 1.0)
    plain = gate.submit("x", 6.0)
    weighted = gate.submit("heavy", 10.0)      # 10/2 = 5 < 6
    gate.complete(hog)
    assert weighted.state == "admitted"
    assert plain.state == "queued"


def test_gate_events_on_bus():
    from repro.runtime.events import EventBus
    bus = EventBus()
    gate = FleetGate(fleet_max=1, bus=bus,
                     default_quota=TenantQuota(max_queued=1))
    gate.submit("a", 1.0)
    gate.submit("a", 2.0)
    with pytest.raises(AdmissionRejected):
        gate.submit("a", 3.0)
    assert len(bus.history("fleet.admitted")) == 1
    assert len(bus.history("fleet.queued")) == 1
    assert len(bus.history("fleet.shed")) == 1


@settings(max_examples=30)
@given(arrivals=st.lists(
    st.tuples(st.integers(min_value=0, max_value=3),
              st.integers(min_value=1, max_value=40)),
    min_size=1, max_size=24))
def test_gate_fairness_property(arrivals):
    """Under random arrival mixes: aggregate admitted concurrency never
    exceeds the fleet cap, no tenant exceeds its own cap, and every
    queued (non-shed) ticket is eventually admitted as the fleet drains
    — the no-starvation guarantee the aging term provides."""
    now = _ScriptClock()
    fleet_max = 3
    quota = TenantQuota(max_concurrent=2, max_queued=100)
    gate = FleetGate(fleet_max=fleet_max, now_fn=now, default_quota=quota)

    def check_caps():
        st_ = gate.stats()
        running = sum(v["running"] for v in st_.values())
        assert running <= fleet_max
        for v in st_.values():
            assert v["running"] <= quota.max_concurrent

    tickets = []
    for tenant_ix, predicted in arrivals:
        tickets.append(gate.submit(f"t{tenant_ix}", float(predicted)))
        check_caps()
        now.t += 1.0

    # drain: complete one admitted ticket per step until all are done
    pending = list(tickets)
    steps = 0
    while pending and steps < 10 * len(tickets) + 10:
        steps += 1
        now.t += 1.0
        admitted = [t for t in pending if t.state == "admitted"]
        if not admitted:
            gate.pump()                        # aging advanced; re-rank
            admitted = [t for t in pending if t.state == "admitted"]
        assert admitted, "queued tickets with free capacity must admit"
        gate.complete(admitted[0])
        pending.remove(admitted[0])
        check_caps()
    assert all(t.state == "done" for t in tickets), \
        "every non-shed ticket must eventually dispatch"


# ------------------------------------------------------------------- pools

def test_prewarm_converges_and_pool_is_capped(fast_clock):
    cluster = Cluster(clock=fast_clock)
    pools = WarmPools(cluster, default=PoolPolicy(min=0, warm=2, max=2))
    spec = FunctionSpec("pw-fn", lambda d, inv: d, provision_s=0.1,
                        startup_s=0.05, exec_s=0.01)
    cluster.platform.register(spec)
    pools.configure(spec)
    started = pools.prewarm(spec, 2)
    assert started == 2
    # repeated calls count warm + in-flight: nothing stacks past target
    assert pools.prewarm(spec, 2) == 0
    deadline = fast_clock.now() + 5.0
    while (len(cluster.platform.warm_instances("pw-fn")) < 2
           and fast_clock.now() < deadline):
        time.sleep(0.005)
    assert len(cluster.platform.warm_instances("pw-fn")) == 2
    assert pools.prewarm(spec, 2) == 0


def test_adopt_hands_inflight_provision_to_live_request(fast_clock):
    cluster = Cluster(clock=fast_clock)
    pools = WarmPools(cluster, default=PoolPolicy(warm=1, max=2))
    spec = FunctionSpec("adopt-fn", lambda d, inv: d or b"y",
                        provision_s=0.6, startup_s=0.1, exec_s=0.01)
    cluster.platform.register(spec)
    pools.configure(spec)
    pools.prewarm(spec, 1)
    out, rec = cluster.platform.invoke(
        Request(fn="adopt-fn", payload=b"hi", source_node="edge-0"))
    assert rec.prewarmed, "checkout miss must adopt the in-flight provision"
    assert rec.cold, "adoption still waited — honest cold accounting"
    assert cluster.platform.stats["adoptions"] == 1
    # the adopted instance is checked back in afterwards: next call is warm
    out, rec2 = cluster.platform.invoke(
        Request(fn="adopt-fn", payload=b"hi", source_node="edge-0"))
    assert rec2.warm_hit and rec2.prewarmed and not rec2.cold


def test_prewarmed_bus_event_fires(fast_clock):
    cluster = Cluster(clock=fast_clock)
    pools = WarmPools(cluster, default=PoolPolicy(warm=1, max=2))
    spec = FunctionSpec("ev-fn", lambda d, inv: d, provision_s=0.05,
                        startup_s=0.02, exec_s=0.01)
    cluster.platform.register(spec)
    pools.configure(spec)
    pools.prewarm(spec, 1)
    deadline = fast_clock.now() + 5.0
    while (not cluster.bus.history("fleet.prewarmed")
           and fast_clock.now() < deadline):
        time.sleep(0.005)
    evs = cluster.bus.history("fleet.prewarmed")
    assert evs and evs[0]["function"] == "ev-fn"


# ---------------------------------------------------------------- sharing

def test_ledger_conservation_and_cross_tenant_saving():
    led = TenantLedger()
    led.on_residency("added", "n1", "d1", 100)
    led.on_residency("added", "n2", "d1", 100)    # 2 replicas
    led.on_residency("added", "n1", "d2", 50)
    assert led.claim("a", "d1", 100) is False     # first claimant: no alias
    assert led.claim("b", "d1", 100) is True      # cross-tenant alias
    led.claim("a", "d2", 50)
    # conservation: per-tenant charges partition the physical bytes
    assert led.physical_bytes() == 2 * 100 + 50
    assert abs(led.charged("a") + led.charged("b")
               - led.physical_bytes()) < 1e-9
    assert led.saved("b") == 100 and led.saved("a") == 0
    # d1 is shared: never a private eviction victim; d2 is a-private
    assert led.private_digests("a") == ["d2"]
    assert led.private_digests("b") == []


def test_sharing_isolation_salts_digests():
    class _Digests:
        def add_ledger(self, cb):
            pass

    class _Cluster:
        digests = _Digests()

    sh = CasSharing(_Cluster())
    sh.register("open", TenantQuota(share_cas=True))
    sh.register("sealed", TenantQuota(share_cas=False))
    assert sh.salt_for("open") is None
    assert sh.salt_for("sealed") == b"cas-ns:sealed:"
    assert sh.salt_for(None) is None


def test_quota_pressure_evicts_private_digests(fast_clock):
    from repro.core.transfer import publish_content
    cluster = Cluster(clock=fast_clock)
    sh = CasSharing(cluster)
    sh.register("a", TenantQuota(cas_bytes=150))
    node = cluster.node_list[0]
    blobs = [b"A" * 100, b"B" * 100]
    from repro.core.buffer import content_digest
    digests = [content_digest(b) for b in blobs]
    for b, d in zip(blobs, digests):
        publish_content(node, b, d)
        sh.claim("a", d, len(b))
    assert sh.ledger.charged("a") == 200
    evicted = sh.pressure("a")
    assert evicted >= 1
    assert sh.ledger.charged("a") <= 150
    # the oldest private digest left the node's buffer AND the registry
    assert node.buffer.find_digest(digests[0]) is None
    assert cluster.digests.nodes_for(digests[0]) == {}


# ------------------------------------------------------------- end to end

def test_fleet_end_to_end_multitenant(fast_clock):
    cluster = Cluster(clock=fast_clock)
    fleet = Fleet(cluster, fleet_max=2, ordering="predicted",
                  pool_policy=PoolPolicy(warm=1, max=4))
    fleet.register_tenant("acme", TenantQuota(max_concurrent=2))
    fleet.register_tenant("globex", TenantQuota(max_concurrent=2))
    runs = [fleet.submit("acme", _chain("a0"), b"p" * 512),
            fleet.submit("globex", _chain("g0"), b"p" * 512),
            fleet.submit("acme", _chain("a1"), b"p" * 512)]
    traces = [r.result(timeout=120) for r in runs]
    assert all(len(t.stages) == 3 for t in traces)
    stats = fleet.stats()
    assert stats["tenants"]["acme"]["completed"] == 2
    assert stats["tenants"]["globex"]["completed"] == 1
    # plan-aware pre-warming absorbed cold starts on next-wave stages
    assert stats["tenants"]["acme"]["warm_hit_rate"] > 0
    assert any(sr.record.warm_hit or sr.record.prewarmed
               for t in traces for sr in t.stages.values())
    # queue-to-run lifecycle events are on the bus
    assert len(cluster.bus.history("fleet.admitted")) == 3
    # identical cross-tenant content: resident once per node, and the
    # later tenant's claim counts as saved bytes
    assert stats["tenants"]["globex"]["cas_saved_bytes"] \
        + stats["tenants"]["acme"]["cas_saved_bytes"] > 0


def test_fleet_cross_tenant_bytes_resident_once_per_node(fast_clock):
    """Two tenants seeding IDENTICAL content alias to one resident copy
    per node (shared CAS), and the ledger's per-tenant charges conserve
    the physical bytes."""
    cluster = Cluster(clock=fast_clock)
    fleet = Fleet(cluster, fleet_max=2, pools=False)
    fleet.register_tenant("t1", TenantQuota())
    fleet.register_tenant("t2", TenantQuota())
    r1 = fleet.submit("t1", _chain("x1", n=2), b"same-bytes" * 64)
    r1.result(timeout=120)
    r2 = fleet.submit("t2", _chain("x2", n=2), b"same-bytes" * 64)
    r2.result(timeout=120)
    led = fleet.sharing.ledger
    for node in cluster.node_list:
        for digest in list(cluster.digests.holdings(node.name)):
            # one buffer key per digest per node — never a second copy
            assert node.buffer.find_digest(digest) is not None
    assert abs(led.charged("t1") + led.charged("t2")
               - led.physical_bytes()) < 1e-9
    assert led.saved("t2") > 0, "t2's identical content must alias"


def test_fleet_isolated_tenant_never_aliases(fast_clock):
    cluster = Cluster(clock=fast_clock)
    fleet = Fleet(cluster, fleet_max=2, pools=False)
    fleet.register_tenant("open", TenantQuota())
    fleet.register_tenant("sealed", TenantQuota(share_cas=False))
    fleet.submit("open", _chain("o", n=2), b"zz" * 64).result(timeout=120)
    fleet.submit("sealed", _chain("s", n=2), b"zz" * 64).result(timeout=120)
    assert fleet.sharing.ledger.saved("sealed") == 0, \
        "share_cas=False must prevent cross-tenant aliasing"
    assert fleet.sharing.stats["shared_claims"] == 0


def test_fleet_shed_surfaces_to_submitter(fast_clock):
    cluster = Cluster(clock=fast_clock)
    fleet = Fleet(cluster, fleet_max=1, pools=False,
                  default_quota=TenantQuota(max_concurrent=1, max_queued=0))
    slow = fleet.submit("a", _chain("slow", provision_s=2.0), b"x")
    with pytest.raises(AdmissionRejected):
        fleet.submit("a", _chain("shed2"), b"x")
    slow.result(timeout=120)


def test_fleet_stats_shape(fast_clock):
    cluster = Cluster(clock=fast_clock)
    fleet = Fleet(cluster, fleet_max=2)
    fleet.register_tenant("a", TenantQuota())
    fleet.submit("a", _chain("st", n=2), b"x" * 32).result(timeout=120)
    stats = fleet.stats()
    for key in ("queue_depth", "running", "shed", "completed",
                "warm_hit_rate", "cas_saved_bytes", "cas_charged_bytes"):
        assert key in stats["tenants"]["a"]
    assert "pools" in stats and "platform" in stats and "sharing" in stats


def test_gate_thread_safety_under_concurrent_submitters():
    """Hammer the gate from many threads: caps hold, nothing deadlocks,
    everything drains."""
    gate = FleetGate(fleet_max=4,
                     default_quota=TenantQuota(max_concurrent=2,
                                               max_queued=100))
    tickets, tlock = [], threading.Lock()

    def submitter(tenant):
        for i in range(10):
            t = gate.submit(tenant, float(i % 5 + 1))
            with tlock:
                tickets.append(t)

    threads = [threading.Thread(target=submitter, args=(f"t{i}",))
               for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=10)
    assert gate.running() <= 4
    # drain
    for _ in range(len(tickets) + 5):
        admitted = [t for t in tickets if t.state == "admitted"]
        if not admitted:
            break
        gate.complete(admitted[0])
    assert all(t.state == "done" for t in tickets)
