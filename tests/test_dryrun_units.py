"""Unit tests for the dry-run analysis stack: HLO collective parser and the
analytic FLOPs model (no 512-device compile here — that's the sweep's job)."""
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.launch.flops import model_flops
from repro.launch.hlo import collective_stats, total_collective_bytes

HLO_SAMPLE = """
  %all-reduce.5 = bf16[16,4096,2560]{2,1,0} all-reduce(%fusion.1), replica_groups={...}
  %all-gather.2 = f32[512,1024]{1,0} all-gather(%param.3), dimensions={0}
  %rs = f32[64,128]{1,0} reduce-scatter(%x), dimensions={0}
  %a2a = (s8[8,64]{1,0}, s8[8,64]{1,0}) all-to-all(%q, %r)
  %cp = bf16[32]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %ar-start = bf16[128]{0} all-reduce-start(%z)
  %dot.1 = f32[10,10]{1,0} dot(%a, %b)
"""


def test_collective_parser_kinds():
    stats = collective_stats(HLO_SAMPLE)
    assert stats["all-reduce"]["count"] >= 1
    assert stats["all-gather"]["count"] == 1
    assert stats["reduce-scatter"]["count"] == 1
    assert stats["all-to-all"]["count"] == 1
    assert stats["collective-permute"]["count"] == 1


def test_collective_parser_bytes():
    stats = collective_stats(HLO_SAMPLE)
    # all-gather result: 512*1024*4 bytes
    assert stats["all-gather"]["bytes"] == 512 * 1024 * 4
    # all-reduce counted 2x (ring RS+AG)
    assert stats["all-reduce"]["bytes"] >= 16 * 4096 * 2560 * 2 * 2
    # tuple result (all-to-all): both operands counted
    assert stats["all-to-all"]["bytes"] == 2 * 8 * 64
    assert total_collective_bytes(stats) > 0


@pytest.mark.parametrize("arch", ["glm4-9b", "olmoe-1b-7b", "jamba-v0.1-52b",
                                  "whisper-medium", "xlstm-125m"])
def test_model_flops_sane(arch):
    cfg = get_config(arch)
    train = model_flops(cfg, SHAPES["train_4k"])
    prefill = model_flops(cfg, SHAPES["prefill_32k"])
    decode = model_flops(cfg, SHAPES["decode_32k"])
    assert train > 0 and prefill > 0 and decode > 0
    # train is 3x prefill per token; prefill >= as many tokens here
    assert prefill >= train / 3.1
    # decode processes B tokens vs B*S: orders less compute (whisper keeps
    # per-token cross-attention against 1500 frames -> looser bound)
    assert decode < prefill / 50


def test_model_flops_6nd_consistency():
    """Dense train FLOPs ~ 6*N*D within the attention-term margin."""
    cfg = get_config("qwen3-4b")
    shape = SHAPES["train_4k"]
    six_nd = 6 * cfg.param_count() * shape.seq_len * shape.global_batch
    got = model_flops(cfg, shape)
    assert six_nd * 0.8 < got < six_nd * 1.6
