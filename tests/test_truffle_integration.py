"""Integration: SDP/CSP end-to-end on a cluster — the paper's central claims
at test scale: Truffle ≥ baseline never worse, I/O hidden inside cold start,
hot functions take the proxy path, Eq. 4 predicts the measured gain."""
import pytest

from repro.core.model import PhaseEstimate, improvement
from repro.runtime.clock import Clock
from repro.runtime.cluster import Cluster
from repro.runtime.function import ContentRef, FunctionSpec, Request
from repro.runtime.workflow import Stage, Workflow, WorkflowRunner

PAYLOAD = bytes(4 << 20)  # 4 MB


def _spec(name, **kw):
    kw.setdefault("provision_s", 1.0)
    kw.setdefault("startup_s", 0.3)
    kw.setdefault("exec_s", 0.05)
    return FunctionSpec(name, lambda d, inv: d, **kw)


def _chained(tag=""):
    return Workflow("chained", {
        "a": Stage(_spec(f"a{tag}")),
        "b": Stage(_spec(f"b{tag}"), deps=["a"]),
    })


@pytest.mark.parametrize("storage", ["direct", "kvs", "s3"])
def test_truffle_not_worse_and_hides_io(storage, fast_clock):
    totals = {}
    io = {}
    for mode in (False, True):
        cluster = Cluster(clock=fast_clock)
        runner = WorkflowRunner(cluster, use_truffle=mode, storage=storage)
        tr = runner.run(_chained(f"-{storage}-{mode}"), PAYLOAD)
        totals[mode] = tr.total
        io[mode] = tr.phase_totals()["io"]
    # allow 5% scheduling jitter + a few ms of absolute wall noise (at
    # scale 0.01 the whole run is ~30ms wall, so 5% alone is ~1.5ms —
    # thinner than OS scheduling jitter under a loaded suite)
    assert totals[True] <= totals[False] * 1.05 + 0.005
    assert io[True] <= io[False] + 0.02


def test_csp_transfers_during_cold_start(fast_clock):
    cluster = Cluster(clock=fast_clock)
    spec = _spec("csp-target", provision_s=2.0)
    cluster.platform.register(spec)
    truffle = cluster.node("edge-0").truffle
    out, rec = truffle.pass_data("csp-target", PAYLOAD)
    assert out == PAYLOAD
    assert rec.cold
    # the transfer finished BEFORE the cold start did -> fully hidden
    assert rec.t_transfer_end <= rec.t_startup_end + 0.01 / fast_clock.scale * 0
    assert rec.io_visible * 0 == 0  # finite
    assert rec.io_visible <= 0.02   # wall seconds at scale=0.01


def test_sdp_prefetch_from_kvs(fast_clock):
    cluster = Cluster(clock=fast_clock)
    spec = _spec("sdp-fn", input_storage="kvs")
    cluster.platform.register(spec)
    cluster.storage["kvs"].put("obj-1", PAYLOAD)
    truffle = cluster.node("edge-0").truffle
    req = Request(fn="sdp-fn", content_ref=ContentRef("kvs", "obj-1",
                                                      len(PAYLOAD)))
    out, rec = truffle.handle_request(req)
    assert out == PAYLOAD
    assert rec.mode == "truffle"
    assert rec.io_visible <= 0.02


def test_hot_function_takes_proxy_path(fast_clock):
    cluster = Cluster(clock=fast_clock)
    spec = _spec("hot-fn")
    cluster.platform.register(spec)
    truffle = cluster.node("edge-0").truffle
    out1, rec1 = truffle.pass_data("hot-fn", PAYLOAD)   # cold: CSP
    assert rec1.mode == "truffle" and rec1.cold
    out2, rec2 = truffle.pass_data("hot-fn", PAYLOAD)   # warm: proxy
    assert rec2.mode == "truffle-proxy"
    assert not rec2.cold
    assert rec2.total <= rec1.total


def test_eq4_predicts_measured_gain(fast_clock):
    """Validate the analytic model against the running system (±35%)."""
    prov, startup, exec_s = 1.5, 0.3, 0.05
    results = {}
    for mode in (False, True):
        cluster = Cluster(clock=fast_clock)
        spec = _spec("m-fn", provision_s=prov, startup_s=startup, exec_s=exec_s)
        cluster.platform.register(spec)
        if mode:
            out, rec = cluster.node("edge-0").truffle.pass_data("m-fn", PAYLOAD)
        else:
            out, rec = cluster.platform.invoke(
                Request(fn="m-fn", payload=PAYLOAD, source_node="edge-0"))
        results[mode] = rec.total
    measured_gain = results[False] - results[True]

    ch = Cluster(clock=fast_clock).network  # same calibration
    bw, lat = ch.tier_links[("edge", "edge")]
    delta = lat + len(PAYLOAD) / bw
    p = PhaseEstimate(alpha=0.15, nu=prov, eta=startup, delta=delta,
                      gamma=exec_s)
    predicted_gain = improvement(p) * fast_clock.scale
    # the platform ingress-overhead difference adds a constant on top of Eq.4
    overhead = (0.30 - 0.05) * fast_clock.scale
    assert measured_gain == pytest.approx(predicted_gain + overhead,
                                          rel=0.35, abs=0.02)


def test_fanout_fanin_workflow(fast_clock):
    wf = Workflow("video", {
        "stream": Stage(_spec("v-stream")),
        "dec0": Stage(_spec("v-dec0"), deps=["stream"]),
        "dec1": Stage(_spec("v-dec1"), deps=["stream"]),
        "recog": Stage(_spec("v-recog"), deps=["dec0", "dec1"]),
    })
    cluster = Cluster(clock=fast_clock)
    tr = WorkflowRunner(cluster, use_truffle=True, storage="direct").run(
        wf, PAYLOAD)
    assert set(tr.stages) == {"stream", "dec0", "dec1", "recog"}
    assert tr.stages["recog"].output == PAYLOAD * 2  # fan-in concat
    assert tr.total > 0
