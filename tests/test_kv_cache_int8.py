"""int8 KV cache (§Perf decode lever): numerically close to the fp cache
path and structurally sound (scales tracked per token/head)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import api
from repro.models.attention import _dequantize_kv, _quantize_kv


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16)) * 2.0
    q, s = _quantize_kv(x)
    assert q.dtype == jnp.int8
    assert s.shape == (2, 8, 4)
    back = _dequantize_kv(q, s, jnp.float32)
    err = np.abs(np.asarray(x - back))
    assert err.max() <= float(np.asarray(s).max()) * 0.51


@pytest.mark.parametrize("arch", ["glm4-9b", "qwen3-4b"])
def test_int8_cache_decode_close_to_fp(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 17), 0, cfg.vocab_size)
    full, _ = api.prefill(cfg, params, {"tokens": toks})

    c8 = cfg.replace(kv_cache_dtype="int8")
    _, cache = api.prefill(c8, params, {"tokens": toks[:, :16]})

    def grow(path, a):
        n = str(getattr(path[-1], "key", ""))
        if n in ("k", "v"):
            return jnp.pad(a, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        if n in ("k_scale", "v_scale"):
            return jnp.pad(a, ((0, 0), (0, 0), (0, 1), (0, 0)))
        return a

    cache = jax.tree_util.tree_map_with_path(grow, cache)
    dl, new_cache = api.decode_step(c8, params, cache, toks[:, 16:17],
                                    jnp.asarray(16, jnp.int32))
    rel = (np.abs(np.asarray(dl, np.float32) - np.asarray(full, np.float32)).max()
           / np.abs(np.asarray(full, np.float32)).max())
    assert rel < 0.05, rel
    # int8 payload really is int8
    assert jax.tree.leaves(new_cache["pos0"])[0].dtype in (jnp.int8, jnp.float32)


def test_mamba_perchunk_paths_identical():
    """Both SSM-param paths (per-chunk vs full-seq) compute the same math
    (fp32 activations: bf16 would amplify benign op-ordering deltas)."""
    import dataclasses
    from repro.models import mamba
    from repro.models.params import init_params
    cfg = get_config("jamba-v0.1-52b", smoke=True).replace(dtype="float32")
    p = init_params(mamba.mamba_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32) * 0.3
    outs = []
    for perchunk in (True, False):
        c = cfg.replace(mamba=dataclasses.replace(cfg.mamba,
                                                  perchunk_params=perchunk))
        y, _ = mamba.mamba_apply(c, p, x, mode="train")
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6, rtol=1e-6)
