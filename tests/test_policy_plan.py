"""Per-edge DataPolicy + compiled ExecutionPlan: builder fluency, cycle
detection, planner resolution/merging, the legacy-kwargs back-compat shim,
multi-input fan-in hints, registry-driven prefetch, WAN chunk compression,
and speculative-backup failure independence."""
import dataclasses
import itertools

import pytest

from repro.core.buffer import content_digest
from repro.core.errors import PlanError, WorkflowCycleError
from repro.runtime.cluster import Cluster
from repro.runtime.function import ContentRef, FunctionSpec, Request
from repro.runtime.planner import ExecutionPlan, Planner
from repro.runtime.policy import DataPolicy, WorkflowBuilder
from repro.runtime.scheduler import PlacementHint
from repro.runtime.workflow import Stage, Workflow, WorkflowRunner

MB = 1 << 20


def _spec(name, **kw):
    kw.setdefault("provision_s", 0.2)
    kw.setdefault("startup_s", 0.05)
    kw.setdefault("exec_s", 0.01)
    return FunctionSpec(name, lambda d, inv: d, **kw)


# ----------------------------------------------------------------- DataPolicy
def test_policy_validation():
    with pytest.raises(ValueError, match="strategy"):
        DataPolicy(strategy="redis")
    with pytest.raises(ValueError, match="compression"):
        DataPolicy(compression="zstd")
    with pytest.raises(ValueError, match="speculation"):
        DataPolicy(speculation=-1.0)
    with pytest.raises(ValueError, match="locality_weight"):
        DataPolicy(locality_weight=-0.5)
    with pytest.raises(ValueError, match="requires dedup"):
        DataPolicy(prefetch=True)            # registry-driven: needs digests


def test_policy_but_derives_and_is_frozen():
    base = DataPolicy(dedup=True)
    wan = base.but(stream=True, compression="lz4-like")
    assert wan.dedup and wan.stream and wan.compression == "lz4-like"
    assert base.stream is False                    # original untouched
    with pytest.raises(dataclasses.FrozenInstanceError):
        base.stream = True


# ------------------------------------------------------------ WorkflowBuilder
def test_builder_fluent_build():
    b = WorkflowBuilder("wf", default_policy=DataPolicy(dedup=True))
    b.stage("a", _spec("a"))
    b.stage("b", _spec("b"), policy=DataPolicy(stream=True)).after("a")
    b.stage("c", _spec("c")).after("a").after(
        "b", policy=DataPolicy(compression="lz4-like"))
    wf = b.build()
    assert wf.stages["c"].deps == ["a", "b"]
    assert wf.stages["b"].policy == DataPolicy(stream=True)
    assert wf.stages["c"].dep_policies["b"].compression == "lz4-like"
    assert wf.default_policy == DataPolicy(dedup=True)


def test_builder_rejects_duplicates_and_unknown_deps():
    b = WorkflowBuilder("wf")
    b.stage("a", _spec("a"))
    with pytest.raises(ValueError, match="duplicate stage"):
        b.stage("a", _spec("a2"))
    with pytest.raises(KeyError, match="not declared"):
        b.edge("a", "ghost")
    b.stage("b", _spec("b")).after("missing")
    with pytest.raises(KeyError, match="missing"):
        b.build()


def test_builder_detects_cycle_and_names_it():
    b = WorkflowBuilder("cyclic")
    b.stage("a", _spec("a"))
    b.stage("b", _spec("b")).after("a")
    b.stage("c", _spec("c")).after("b")
    b.edge("c", "a")                                # closes a -> b -> c -> a
    with pytest.raises(WorkflowCycleError) as ei:
        b.build()
    assert set(ei.value.cycle) >= {"a", "b", "c"}
    assert "->" in str(ei.value)


def test_topo_order_raises_on_cycle_instead_of_recursing():
    """Satellite fix: a hand-built cyclic Workflow used to recurse forever
    (RecursionError at best, hang at worst)."""
    wf = Workflow("loop", {"x": Stage(_spec("x"), deps=["y"]),
                           "y": Stage(_spec("y"), deps=["x"])})
    with pytest.raises(WorkflowCycleError) as ei:
        wf.topo_order()
    assert set(ei.value.cycle) >= {"x", "y"}
    with pytest.raises(WorkflowCycleError):
        Planner().compile(wf)


def test_self_cycle():
    wf = Workflow("self", {"x": Stage(_spec("x"), deps=["x"])})
    with pytest.raises(WorkflowCycleError) as ei:
        wf.topo_order()
    assert ei.value.cycle == ["x", "x"]


# ------------------------------------------------------------------- Planner
def test_planner_resolution_precedence():
    edge_pol = DataPolicy(compression="lz4-like")
    stage_pol = DataPolicy(stream=True)
    wf_pol = DataPolicy(dedup=True)
    b = WorkflowBuilder("prec", default_policy=wf_pol)
    b.stage("a", _spec("a"))
    b.stage("b", _spec("b"), policy=stage_pol).after("a")
    b.stage("c", _spec("c")).after("b", policy=edge_pol)
    plan = Planner(default=DataPolicy(strategy="kvs")).compile(b.build())
    # edge policy > stage policy > workflow default > planner default
    assert plan.edge_policy("b", "c") == edge_pol
    assert plan.edge_policy("a", "b") == stage_pol
    assert plan.edge_policy(None, "a") == wf_pol      # ingress: wf default
    # planner default only applies when the workflow declares nothing
    plain = Workflow("plain", {"x": Stage(_spec("x"))})
    plan2 = Planner(default=DataPolicy(strategy="kvs")).compile(plain)
    assert plan2.edge_policy(None, "x").strategy == "kvs"


def test_planner_merges_fanin_transport_and_hints():
    b = WorkflowBuilder("fanin")
    b.stage("a", _spec("a"))
    b.stage("b", _spec("b"))
    b.stage("j", _spec("j")) \
        .after("a", policy=DataPolicy(dedup=True, speculation=2.0)) \
        .after("b", policy=DataPolicy(stream=True, compression="lz4-like"))
    plan = b.plan()
    sp = plan.stages["j"]
    assert sp.transport.stream and sp.transport.dedup
    assert sp.transport.compression == "lz4-like"
    assert sp.transport.speculation == 2.0
    assert sp.hint_deps == ("a",)           # only the dedup edge hints
    assert plan.stages["a"].seed_output     # a consumer edge dedups
    assert not plan.stages["b"].seed_output


def test_planner_rejects_conflicting_codecs():
    b = WorkflowBuilder("codecs")
    b.stage("a", _spec("a"))
    b.stage("b", _spec("b"))
    b.stage("j", _spec("j")) \
        .after("a", policy=DataPolicy(compression="lz4-like")) \
        .after("b", policy=DataPolicy(compression="none"))
    # none + a codec merges to the codec (one edge opting out is fine)
    assert b.plan().stages["j"].transport.compression == "lz4-like"


def test_planner_rejects_conflicting_strategies():
    b = WorkflowBuilder("conflict")
    b.stage("a", _spec("a"))
    b.stage("b", _spec("b"))
    b.stage("j", _spec("j")) \
        .after("a", policy=DataPolicy(strategy="kvs")) \
        .after("b", policy=DataPolicy(strategy="s3"))
    with pytest.raises(PlanError, match="conflicting strategies"):
        b.plan()


def test_planner_weight_merge_rules():
    from repro.runtime.planner import EdgePlan, Planner

    def merged(*weights):
        edges = tuple(EdgePlan(f"d{i}", "j",
                               DataPolicy(locality_weight=w))
                      for i, w in enumerate(weights))
        return Planner._merge("j", edges).locality_weight

    assert merged(None, None) is None        # everyone defers to scheduler
    assert merged(3.0, None) == 3.0          # positive override wins
    assert merged(0.0, 3.0) == 3.0
    assert merged(0.0, 0.0) == 0.0           # unanimous disable sticks
    # one edge disabling must NOT strip the default the other relies on
    assert merged(0.0, None) is None


def test_plan_is_immutable():
    b = WorkflowBuilder("frozen")
    b.stage("a", _spec("a"))
    plan = b.plan()
    with pytest.raises(dataclasses.FrozenInstanceError):
        plan.workflow = "other"
    with pytest.raises(TypeError):
        plan.stages["zzz"] = None
    with pytest.raises(dataclasses.FrozenInstanceError):
        plan.stages["a"].transport = DataPolicy()


# ------------------------------------------------- legacy-kwargs shim mapping
@pytest.mark.parametrize(
    "storage,stream,dedup,straggler",
    list(itertools.product(["direct", "kvs", "s3"], [False, True],
                           [False, True], [0.0, 2.5])))
def test_legacy_kwargs_compile_to_uniform_plan(storage, stream, dedup,
                                               straggler):
    """Property: EVERY legacy WorkflowRunner kwargs combination maps to the
    equivalent uniform ExecutionPlan — same strategy/stream/dedup on every
    edge, speculation on every stage, hints exactly when dedup."""
    runner = WorkflowRunner(None, use_truffle=True, storage=storage,
                            stream=stream, dedup=dedup,
                            straggler_factor=straggler)
    wf = Workflow("shim", {
        "a": Stage(_spec("a")),
        "b": Stage(_spec("b"), deps=["a"]),
        "c": Stage(_spec("c"), deps=["a"]),
        "d": Stage(_spec("d"), deps=["b", "c"]),
    })
    plan = runner.compile(wf)
    expected = DataPolicy(strategy=storage, stream=stream, dedup=dedup,
                          speculation=straggler)
    assert plan.uniform() == expected
    assert plan.label() == storage
    for name, sp in plan.stages.items():
        assert sp.transport == expected
        assert all(e.policy == expected for e in sp.in_edges)
        assert sp.hint_deps == (sp.deps if dedup else ())
        consumers = [s for s in plan.stages.values() if name in s.deps]
        assert sp.seed_output == (dedup and bool(consumers))
    # legacy attribute mirrors stay readable
    assert runner.storage == storage
    assert runner.stream == stream
    assert runner.dedup == dedup
    assert runner.straggler_factor == straggler


def test_legacy_kwargs_still_run_end_to_end(fast_clock):
    cluster = Cluster(clock=fast_clock)
    wf = Workflow("legacy", {"a": Stage(_spec("leg-a")),
                             "b": Stage(_spec("leg-b"), deps=["a"])})
    tr = WorkflowRunner(cluster, use_truffle=True, storage="kvs",
                        stream=True, dedup=True).run(wf, b"x")
    assert set(tr.stages) == {"a", "b"}
    assert tr.storage == "kvs"


# -------------------------------------------------- multi-input PlacementHint
def test_hint_canonicalization_and_from_request():
    legacy = PlacementHint(digest="d1", size=10)
    assert legacy.input_hints() == (("d1", 10),)
    multi = PlacementHint(inputs=(("d1", 10), ("d2", 20)))
    assert multi.input_hints() == (("d1", 10), ("d2", 20))

    req = Request(fn="f", content_ref=ContentRef(
        "truffle", "k", size=30, digest="dj",
        inputs=(("d1", 10), ("d2", 20))))
    h = PlacementHint.from_request(req)
    assert h.input_hints() == (("d1", 10), ("d2", 20))

    # meta directives survive without any digest at all
    req2 = Request(fn="f", payload=b"x", meta={"avoid_node": "edge-1"})
    h2 = PlacementHint.from_request(req2)
    assert h2.avoid == "edge-1" and h2.input_hints() == ()
    assert PlacementHint.from_request(Request(fn="f", payload=b"x")) is None


def test_pick_scores_sum_of_resident_inputs(fast_clock):
    """Fan-in: the node holding the LARGER share of the hinted inputs wins,
    even though neither holds the joined blob."""
    cluster = Cluster(clock=fast_clock)
    big, small = bytes(3 * MB), bytes([1]) * MB
    db, ds = content_digest(big), content_digest(small)
    cluster.node("edge-1").buffer.set("k-big", big, digest=db)
    cluster.node("edge-0").buffer.set("k-small", small, digest=ds)
    hint = PlacementHint(inputs=((db, len(big)), (ds, len(small))))
    spec = FunctionSpec("sum-fn", lambda d, inv: d)
    assert cluster.scheduler._pick(spec, hint).name == "edge-1"
    # joined-blob hashing finds nothing: falls back to least-loaded
    joined = PlacementHint(digest=content_digest(big + small),
                           size=len(big) + len(small))
    assert cluster.scheduler._pick(spec, joined).name == "edge-0"


def test_hint_weight_override(fast_clock):
    cluster = Cluster(clock=fast_clock)
    payload = bytes(MB)
    d = content_digest(payload)
    cluster.node("edge-1").buffer.set("seed", payload, digest=d)
    spec = FunctionSpec("w-fn", lambda d, inv: d)
    with cluster.scheduler._lock:
        cluster.scheduler._load["edge-1"] = 3
    # default weight 2.0 < load skew 3: locality loses
    assert cluster.scheduler._pick(
        spec, PlacementHint(digest=d, size=MB)).name != "edge-1"
    # per-edge weight override 5.0 > skew: the data wins again
    assert cluster.scheduler._pick(
        spec, PlacementHint(digest=d, size=MB, weight=5.0)).name == "edge-1"


def test_avoid_steers_placement(fast_clock):
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("av-fn", lambda d, inv: d)
    # edge-0 is least-loaded (ties keep node order) — avoid pushes off it
    assert cluster.scheduler._pick(spec, None).name == "edge-0"
    hint = PlacementHint(avoid="edge-0")
    assert cluster.scheduler._pick(spec, hint).name != "edge-0"


# ------------------------------------------- fan-in workflow: per-dep digests
def test_workflow_fanin_carries_per_dep_hints(fast_clock):
    """A dedup fan-in stage lands on a producer node via per-dep digest
    hints when the source node (which holds the seeded joined blob) is
    load-skewed — joined-blob hashing alone would find no alternative."""
    payloads = {"l": bytes([3]) * (2 * MB), "r": bytes([7]) * MB}

    b = WorkflowBuilder("fanin-e2e", default_policy=DataPolicy(dedup=True))
    b.stage("l", FunctionSpec("fi-l", lambda d, inv: payloads["l"],
                              provision_s=0.2, startup_s=0.05, exec_s=0.01,
                              affinity="edge-1"))
    b.stage("r", FunctionSpec("fi-r", lambda d, inv: payloads["r"],
                              provision_s=0.2, startup_s=0.05, exec_s=0.01,
                              affinity="edge-0"))
    b.stage("join", _spec("fi-join")).after("l").after("r")
    cluster = Cluster(clock=fast_clock)
    # the dispatch source is r's node (last dep, edge-0), where the joined
    # blob gets seeded — overload it so the per-dep hints must decide
    w = cluster.scheduler.locality_weight
    with cluster.scheduler._lock:
        cluster.scheduler._load["edge-0"] = int(w) + 3
    tr = WorkflowRunner(cluster, use_truffle=True).run(b.build(), b"go")
    join = tr.stages["join"].record
    # edge-1 holds 2 MB of the inputs (part l) -> placement follows the sum
    assert join.node == "edge-1"
    assert join.locality_hit
    assert tr.stages["join"].output == payloads["l"] + payloads["r"]
    # producers' outputs were content-addressed and seeded where they ran
    assert tr.stages["l"].digest == content_digest(payloads["l"])
    assert cluster.node("edge-1").buffer.find_digest(tr.stages["l"].digest)


def test_fanin_unloaded_source_keeps_joined_alias(fast_clock):
    """Without load skew the source node wins: it holds the seeded JOINED
    blob (full zero-transfer alias), which the appended joined-digest hint
    credits on top of its resident part."""
    payloads = {"l": bytes([3]) * MB, "r": bytes([7]) * MB}
    b = WorkflowBuilder("fanin-alias", default_policy=DataPolicy(dedup=True))
    b.stage("l", FunctionSpec("fa-l", lambda d, inv: payloads["l"],
                              provision_s=0.2, startup_s=0.05, exec_s=0.01,
                              affinity="edge-1"))
    b.stage("r", FunctionSpec("fa-r", lambda d, inv: payloads["r"],
                              provision_s=0.2, startup_s=0.05, exec_s=0.01,
                              affinity="edge-0"))
    b.stage("join", _spec("fa-join")).after("l").after("r")
    cluster = Cluster(clock=fast_clock)
    tr = WorkflowRunner(cluster, use_truffle=True).run(b.build(), b"go")
    join = tr.stages["join"].record
    assert join.node == "edge-0"             # source: joined blob + part r
    assert join.locality_hit
    assert join.dedup_hit                    # served by the joined alias


# ------------------------------------------------- registry-driven prefetch
def test_prefetch_relays_at_placement_time(fast_clock):
    """Load-skew forces placement OFF the data; with DataPolicy.prefetch
    the scheduler kicks the relay at decision time, the CSP ship becomes
    its follower, and the bytes cross the fabric once."""
    cluster = Cluster(clock=fast_clock)
    payload = bytes(4 * MB)
    cluster.platform.register(FunctionSpec("pf-fn", lambda d, inv: d,
                                           provision_s=0.4, startup_s=0.05,
                                           exec_s=0.01))
    w = cluster.scheduler.locality_weight
    with cluster.scheduler._lock:
        cluster.scheduler._load["edge-0"] = int(w) + 2    # source overloaded
    out, rec = cluster.node("edge-0").truffle.pass_data(
        "pf-fn", payload, policy=DataPolicy(dedup=True, prefetch=True))
    assert out == payload
    assert rec.node != "edge-0"              # placed off the data
    assert rec.prefetched                    # ...so the scheduler kicked it
    assert cluster.prefetcher.stats["kicks"] >= 1
    assert cluster.scheduler.stats["prefetch_kicks"] >= 1
    # the prefetch relay led; the CSP ship aliased its landed bytes
    assert rec.dedup_hit or rec.relay_shared
    ev = cluster.bus.wait_for("scheduling.placed",
                              lambda e: e["function"] == "pf-fn", timeout=1)
    assert ev["prefetched"] is True


def test_prefetch_skips_when_resident_or_unsourced(fast_clock):
    cluster = Cluster(clock=fast_clock)
    payload = bytes(MB)
    d = content_digest(payload)
    cluster.node("edge-1").buffer.set("seed", payload, digest=d)
    assert cluster.prefetcher.kick(d, "edge-1") is False   # already resident
    assert cluster.prefetcher.kick("deadbeef", "edge-0") is False  # no holder
    assert cluster.prefetcher.stats["relays"] == 0


def test_prefetch_relay_honors_edge_compression():
    """The prefetch relay REPLACES the CSP/SDP ship (the ship becomes its
    RelayTable follower), so it must apply the edge's wire codec — a WAN
    edge's compression must not silently vanish because the scheduler
    moved the bytes at placement time."""
    import time
    from repro.runtime.clock import Clock
    durations = {}
    for compression in ("none", "lz4-like"):
        cluster = Cluster(clock=Clock(0.05))
        payload = bytes(32 * MB)
        d = content_digest(payload)
        cluster.node("edge-0").buffer.set(f"cas/{d}", payload, digest=d)
        t0 = time.monotonic()
        assert cluster.prefetcher.kick(d, "cloud-0", compression=compression)
        deadline = time.monotonic() + 30
        while (not cluster.node("cloud-0").buffer.find_digest(d)
               and time.monotonic() < deadline):
            time.sleep(0.002)
        durations[compression] = time.monotonic() - t0
        assert cluster.node("cloud-0").buffer.find_digest(d)
        assert cluster.prefetcher.stats["relays"] == 1
    # 32 MB over the 0.2 Gbit/s WAN: ~1.28 sim-s plain vs ~0.33 compressed
    # (the relay is codec-bound at compress_bps, not wire-bound)
    assert durations["lz4-like"] < durations["none"] / 1.7


def test_prefetch_not_kicked_without_policy(fast_clock):
    cluster = Cluster(clock=fast_clock)
    payload = bytes(MB)
    cluster.platform.register(FunctionSpec("nopf-fn", lambda d, inv: d,
                                           provision_s=0.3, startup_s=0.05,
                                           exec_s=0.01))
    with cluster.scheduler._lock:
        cluster.scheduler._load["edge-0"] = 5
    _, rec = cluster.node("edge-0").truffle.pass_data(
        "nopf-fn", payload, policy=DataPolicy(dedup=True))
    assert not rec.prefetched
    assert cluster.prefetcher.stats["kicks"] == 0


def test_fanin_prefetch_relays_only_the_shipped_blob(fast_clock):
    """Multi-input prefetch must relay the JOINED digest (what the ship
    aliases), never the per-dep parts — part relays are fabric traffic the
    data path can neither follow nor alias."""
    cluster = Cluster(clock=fast_clock)
    part0, part1 = bytes([1]) * MB, bytes([2]) * MB
    d0, d1 = content_digest(part0), content_digest(part1)
    cluster.node("edge-1").buffer.set(f"cas/{d0}", part0, digest=d0)
    cluster.node("edge-1").buffer.set(f"cas/{d1}", part1, digest=d1)
    cluster.platform.register(FunctionSpec("fpf-fn", lambda d, inv: d,
                                           provision_s=0.4, startup_s=0.05,
                                           exec_s=0.01))
    with cluster.scheduler._lock:            # push placement off edge-0/1
        cluster.scheduler._load["edge-0"] = 9
        cluster.scheduler._load["edge-1"] = 9
    joined = part0 + part1
    _, rec = cluster.node("edge-0").truffle.pass_data(
        "fpf-fn", joined, policy=DataPolicy(dedup=True, prefetch=True),
        input_hints=((d0, len(part0)), (d1, len(part1))))
    assert rec.node not in ("edge-0", "edge-1")
    assert rec.prefetched
    target = cluster.node(rec.node)
    dj = content_digest(joined)
    assert target.buffer.find_digest(dj)     # the joined blob was relayed
    assert not target.buffer.find_digest(d0)  # the parts were NOT
    assert not target.buffer.find_digest(d1)
    assert cluster.prefetcher.stats["kicks"] == 1


def test_sdp_storage_fetch_follows_prefetch_relay(fast_clock):
    """A storage-strategy edge CAN prefetch: the Data Engine consults the
    cluster RelayTable before touching storage, so the relay kicked at
    placement time moves the bytes exactly once and the engine's fetch
    becomes its follower (no second storage read — single-transfer
    accounting)."""
    cluster = Cluster(clock=fast_clock)
    payload = bytes(2 * MB)
    cluster.storage["kvs"].put("pf-obj", payload)
    # earlier consumer made edge-1 a registry holder of the content
    cluster.platform.register(FunctionSpec("pf-a", lambda d, inv: d,
                                           provision_s=0.3, startup_s=0.05,
                                           exec_s=0.01, affinity="edge-1"))
    cluster.platform.register(FunctionSpec("pf-b", lambda d, inv: d,
                                           provision_s=0.3, startup_s=0.05,
                                           exec_s=0.01, affinity="cloud-0"))
    truffle = cluster.node("edge-0").truffle
    ref = ContentRef("kvs", "pf-obj", len(payload))
    pol = DataPolicy(strategy="kvs", dedup=True, prefetch=True)
    truffle.handle_request(Request(fn="pf-a", content_ref=ref), policy=pol)
    engine = cluster.node("cloud-0").truffle.engine
    fetches_before = engine.stats["fetches"]
    _, rec = truffle.handle_request(Request(fn="pf-b", content_ref=ref),
                                    policy=pol)
    assert rec.node == "cloud-0"             # pinned off the holder
    assert rec.prefetched                    # scheduler kicked the relay
    assert cluster.prefetcher.stats["kicks"] == 1
    assert cluster.prefetcher.stats["relays"] == 1
    # single-transfer accounting: the engine aliased the relayed bytes —
    # no storage read happened on the target, the fabric moved them once
    assert rec.dedup_hit and rec.relay_shared
    assert engine.stats["relay_follows"] == 1
    assert engine.stats["fetches"] == fetches_before
    assert engine.stats["bytes_fetched"] == 0
    assert cluster.node("cloud-0").buffer.find_digest(
        content_digest(payload))


# ------------------------------------------------------- WAN chunk compression
def test_channel_wire_ratio_shrinks_grants():
    from repro.runtime.clock import Clock
    from repro.runtime.netsim import Channel
    ch = Channel("t", bandwidth=1e6, latency=0.0, clock=Clock(0.0))
    assert ch.transfer_time(1_000_000) == pytest.approx(1.0)
    assert ch.transfer_time(1_000_000, wire_ratio=0.1) == pytest.approx(0.1)
    chunks = list(ch.stream(bytes(2 << 20), wire_ratio=0.5))
    assert sum(len(c) for c in chunks) == 2 << 20   # payload intact


def test_csp_wan_compression_cuts_transfer(fast_clock):
    """lz4-like on an edge->cloud pass: wire grants shrink to the sampled
    ratio and the record carries it."""
    times = {}
    for label, policy in (("plain", DataPolicy(stream=True)),
                          ("lz4", DataPolicy(stream=True,
                                             compression="lz4-like"))):
        cluster = Cluster(clock=fast_clock)
        cluster.platform.register(
            FunctionSpec(f"wan-{label}", lambda d, inv: d[:4],
                         provision_s=0.2, startup_s=0.05, exec_s=0.01,
                         affinity="cloud-0"))
        payload = bytes(16 * MB)        # highly compressible -> floor ratio
        out, rec = cluster.node("edge-0").truffle.pass_data(
            f"wan-{label}", payload, policy=policy)
        assert out == payload[:4]
        times[label] = rec.t_transfer_end - rec.t_transfer_start
        if label == "lz4":
            assert rec.compress_ratio == pytest.approx(0.05)
        else:
            assert rec.compress_ratio is None
    assert times["lz4"] < times["plain"]


def test_local_pass_skips_codec(fast_clock):
    cluster = Cluster(clock=fast_clock)
    cluster.platform.register(FunctionSpec("loc-cmp", lambda d, inv: d,
                                           provision_s=0.2, startup_s=0.05,
                                           exec_s=0.01, affinity="edge-0"))
    _, rec = cluster.node("edge-0").truffle.pass_data(
        "loc-cmp", bytes(MB), policy=DataPolicy(compression="lz4-like"))
    assert rec.compress_ratio is None        # loopback: nothing crossed a wire


# --------------------------------------- speculative backup on another node
def test_speculative_backup_lands_on_different_node(fast_clock):
    """Failure independence: the backup attempt avoids the straggler's node
    even when that node is otherwise the best (least-loaded) choice."""
    import itertools as it
    calls = it.count()

    def slow_first(d, inv):
        if next(calls) == 0:
            inv.cluster.clock.sleep(60.0)    # pathological straggler
        return d + b"-ok"

    from repro.core.model import PhaseEstimate
    spec = FunctionSpec("ind-fn", slow_first, provision_s=0.1,
                        startup_s=0.05, exec_s=0.01)
    wf = Workflow("w", {"s": Stage(spec)})
    est = {"s": PhaseEstimate(alpha=0.15, nu=0.1, eta=0.05, delta=0.01,
                              gamma=0.01)}
    cluster = Cluster(clock=fast_clock)
    # every OTHER node is heavily loaded: without the avoid hint the backup
    # would re-land on the straggler's (still least-loaded) node
    with cluster.scheduler._lock:
        for n in ("edge-1", "cloud-0"):
            cluster.scheduler._load[n] = 5
    runner = WorkflowRunner(cluster, use_truffle=False,
                            straggler_factor=3.0, estimates=est)
    tr = runner.run(wf, b"x")
    sr = tr.stages["s"]
    assert sr.speculated is True
    assert sr.output == b"x-ok"
    placed = [e["node"] for e in cluster.bus.history("scheduling.placed")
              if e["function"] == "ind-fn"]
    assert len(placed) >= 2
    assert placed[-1] != placed[0]           # backup off the straggler's node
    assert sr.record.node == placed[-1]


# ----------------------------------------------------- per-edge model terms
def test_model_per_edge_terms():
    from repro.core import model as tm
    p = tm.PhaseEstimate(alpha=0.1, nu=1.0, eta=0.5, delta=4.0, gamma=0.2)
    assert tm.edge_delta(p) == 4.0
    assert tm.edge_delta(p, wire_ratio=0.25) == 1.0
    assert tm.edge_delta(p, wire_ratio=0.5, resident_fraction=0.5) == 1.0
    # compression pulls δ under β: transfer fully hidden
    assert tm.edge_time(p, wire_ratio=0.25) == pytest.approx(0.1 + 1.5 + 0.2)
    assert tm.edge_time(p) == tm.truffle_time(p)
    assert tm.edge_time(p, use_truffle=False) == tm.baseline_time(p)
    # streamed edge: visible IO = δ_e − β − overlap
    assert tm.edge_time(p, stream_exec_overlap=0.5) == pytest.approx(
        0.1 + 1.5 + (4.0 - 1.5 - 0.5) + 0.2)
    assert tm.edge_improvement(p, wire_ratio=0.25) == pytest.approx(4.0 - 1.5)
    assert tm.plan_time([(p, {}), (p, {"wire_ratio": 0.25})]) == \
        pytest.approx(tm.truffle_time(p) + 1.8)


# -------------------------------------------------------- mixed plan e2e run
def test_mixed_plan_workflow_end_to_end(fast_clock):
    wan = DataPolicy(stream=True, dedup=True, compression="lz4-like")
    b = WorkflowBuilder("mixed")
    b.stage("src", FunctionSpec("mx-src", lambda d, inv: bytes(4 * MB),
                                provision_s=0.2, startup_s=0.05,
                                exec_s=0.01, affinity="edge-0"))
    b.stage("f0", _spec("mx-f0")).after("src", policy=DataPolicy(dedup=True))
    b.stage("f1", _spec("mx-f1")).after("src", policy=DataPolicy(dedup=True))
    b.stage("up", FunctionSpec("mx-up", lambda d, inv: d[:8],
                               provision_s=0.2, startup_s=0.05, exec_s=0.01,
                               affinity="cloud-0")) \
        .after("f0", policy=wan).after("f1", policy=wan)
    wf = b.build()
    cluster = Cluster(clock=fast_clock)
    runner = WorkflowRunner(cluster, use_truffle=True)
    plan = runner.compile(wf)
    assert plan.label() == "direct"
    assert plan.stages["up"].transport.compression == "lz4-like"
    tr = runner.run(wf, b"go", source_node="edge-0")
    # dedup fan-out placed ON the source's seeded bytes
    for s in ("f0", "f1"):
        assert tr.stages[s].record.node == "edge-0"
        assert tr.stages[s].record.dedup_hit
    assert tr.stages["up"].record.compress_ratio == pytest.approx(0.05)
    assert tr.stages["up"].output == (tr.stages["f0"].output
                                      + tr.stages["f1"].output)[:8]
    assert tr.storage == "direct"
