"""Per-arch smoke tests (assignment requirement): instantiate the REDUCED
config, run one forward/train step on CPU, assert output shapes + no NaNs;
plus prefill/decode for every arch (all have a decode step — none are
encoder-only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config, list_archs
from repro.models import api

TRAIN = ShapeConfig("smoke_train", 32, 2, "train")
PREFILL = ShapeConfig("smoke_prefill", 32, 2, "prefill")
DECODE = ShapeConfig("smoke_decode", 32, 2, "decode")


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, smoke=True)
            cache[arch] = (cfg, api.init(cfg, jax.random.PRNGKey(0)))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_finite(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = api.concrete_inputs(cfg, TRAIN)["batch"]
    loss, metrics = api.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss)), f"{arch} loss NaN"
    assert float(loss) > 0
    grads = jax.grad(lambda p: api.loss_fn(cfg, p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch} grad NaN/zero"


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_shapes(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = api.concrete_inputs(cfg, PREFILL)["batch"]
    logits, cache = api.prefill(cfg, params, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert cache is not None


@pytest.mark.parametrize("arch", list_archs())
def test_decode_step(arch, arch_state):
    cfg, params = arch_state(arch)
    inp = api.concrete_inputs(cfg, DECODE)
    logits, new_cache = api.decode_step(cfg, params, inp["cache"], inp["token"],
                                        jnp.asarray(3, jnp.int32))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(inp["cache"])
