"""Function-to-function direct streaming (pipelined edges).

The tentpole behavior under test: a ``DataPolicy(pipeline=True)`` edge
fires the consumer's lightweight trigger at PRODUCER dispatch and flows
``Invocation.put_stream`` chunks into the consumer's in-flight buffer
entry while the producer is still executing — plus the failure modes
that must degrade to the whole-blob path instead of wedging anything.
"""
import threading
import time

from repro.core.errors import StageExecutionError
from repro.runtime.cluster import Cluster
from repro.runtime.function import FunctionSpec
from repro.runtime.planner import Planner
from repro.runtime.policy import DataPolicy, RetryPolicy, WorkflowBuilder
from repro.runtime.workflow import WorkflowRunner

MB = 1 << 20
CHUNK = 1 << 20
N_CHUNKS = 4
COLD = {"provision_s": 0.2, "startup_s": 0.05}
PIPED = DataPolicy(strategy="direct", stream=True, pipeline=True)


def _cluster(clock) -> Cluster:
    return Cluster(node_specs=[("edge-0", "edge"), ("edge-1", "edge"),
                               ("edge-2", "edge")], clock=clock)


def _head(fail_first=False):
    attempts = []

    def handler(_d, inv):
        def gen():
            for i in range(N_CHUNKS):
                if fail_first and not attempts and i == 1:
                    attempts.append(1)
                    raise RuntimeError("producer died mid-stream")
                inv.cluster.clock.sleep(0.05)
                yield bytes(CHUNK)
        return inv.put_stream(gen())
    return handler


def _relay(_d, inv):
    def gen():
        for chunk in inv.get_input_stream(timeout=60):
            yield chunk
    return inv.put_stream(gen())


def _sink(_d, inv):
    total = 0
    for chunk in inv.get_input_stream(timeout=60):
        total += len(chunk)
    return total.to_bytes(8, "big")


def _chain(tag, *, head_handler=None, retry=None):
    b = WorkflowBuilder(f"pipe{tag}")
    b.stage("a", FunctionSpec(f"pt-a{tag}", head_handler or _head(),
                              exec_s=0.2, streaming=True,
                              streaming_output=True, affinity="edge-0",
                              retry=retry, **COLD))
    b.stage("b", FunctionSpec(f"pt-b{tag}", _relay, exec_s=0.1,
                              streaming=True, streaming_output=True,
                              affinity="edge-1", **COLD)
            ).after("a").policy(PIPED)
    b.stage("c", FunctionSpec(f"pt-c{tag}", _sink, exec_s=0.1,
                              streaming=True, affinity="edge-2", **COLD)
            ).after("b").policy(PIPED)
    return b.build()


def test_chain_streams_mid_execution(fast_clock):
    """Chunks reach the consumer BEFORE the producer finishes executing,
    and every pipelined consumer's record says so."""
    cluster = _cluster(fast_clock)
    wf = _chain("-e2e")
    tr = WorkflowRunner(cluster, use_truffle=True).run(
        wf, b"go", source_node="edge-0")
    size = N_CHUNKS * CHUNK
    assert tr.stages["c"].output == size.to_bytes(8, "big")
    assert len(tr.stages["b"].output) == size
    assert tr.stages["a"].record.pipelined is False
    assert tr.stages["b"].record.pipelined is True
    assert tr.stages["c"].record.pipelined is True
    # the tentpole: b's input started landing while a was still executing
    a, b = tr.stages["a"].record, tr.stages["b"].record
    assert b.t_transfer_start < a.t_exec_end
    # and the trigger overlap: b was placed before a finished, too
    assert b.t_placed < a.t_exec_end


def test_warm_consumers_still_pipeline(fast_clock):
    """Second run of the same chain hits warm instances everywhere; the
    pipes must ride the warm path (request meta) just the same."""
    cluster = _cluster(fast_clock)
    tr1 = WorkflowRunner(cluster, use_truffle=True).run(
        _chain("-warm"), b"go", source_node="edge-0")
    tr2 = WorkflowRunner(cluster, use_truffle=True).run(
        _chain("-warm"), b"go", source_node="edge-0")
    size = N_CHUNKS * CHUNK
    for tr in (tr1, tr2):
        assert tr.stages["c"].output == size.to_bytes(8, "big")
        assert tr.stages["b"].record.pipelined is True
    assert tr2.stages["b"].record.warm_hit is True


def test_planner_auto_enables_pipeline_on_streaming_pairs():
    """pipeline="auto" resolves True only for streaming_output → streaming
    pairs on a direct edge; a blob-consuming stage keeps it off."""
    auto = DataPolicy(strategy="direct", stream=True, pipeline="auto")
    b = WorkflowBuilder("auto")
    b.stage("p", FunctionSpec("au-p", lambda d, inv: d, exec_s=0.1,
                              streaming=True, streaming_output=True))
    b.stage("s", FunctionSpec("au-s", lambda d, inv: d, exec_s=0.1,
                              streaming=True)).after("p").policy(auto)
    b.stage("blob", FunctionSpec("au-b", lambda d, inv: d,
                                 exec_s=0.1)).after("s").policy(auto)
    plan = Planner().compile(b.build())
    assert plan.stages["s"].in_edges[0].policy.pipeline is True
    # "s" has no streaming_output: its consumer cannot be fed mid-execution
    assert plan.stages["blob"].in_edges[0].policy.pipeline is False


def test_producer_crash_falls_back_to_whole_blob_retry(fast_clock):
    """Producer dies after streaming one chunk: the pipe poisons the
    consumer's in-flight input (it fails NOW, no timeout burn), the retry
    layer re-runs the producer, and the consumers fall back to the normal
    whole-blob dispatch against the retried output."""
    cluster = _cluster(fast_clock)
    wf = _chain("-crash", head_handler=_head(fail_first=True),
                retry=RetryPolicy(max_attempts=2, backoff_s=0.0))
    tr = WorkflowRunner(cluster, use_truffle=True).run(
        wf, b"go", source_node="edge-0")
    size = N_CHUNKS * CHUNK
    assert tr.stages["c"].output == size.to_bytes(8, "big")
    assert tr.stages["a"].record.attempt == 2
    # fallback consumers ran the robust path, not the (dead) pipes
    assert tr.stages["b"].record.pipelined is False
    assert tr.stages["c"].record.pipelined is False


def test_producer_crash_without_retry_fails_the_run(fast_clock):
    cluster = _cluster(fast_clock)
    wf = _chain("-fatal", head_handler=_head(fail_first=True))
    try:
        WorkflowRunner(cluster, use_truffle=True).run(
            wf, b"go", source_node="edge-0")
        raise AssertionError("expected the producer failure to surface")
    except StageExecutionError as e:
        assert e.stage == "a"


def test_trigger_failure_never_wedges_the_producer(fast_clock):
    """A pipe whose consumer trigger fails outright (unregistered target)
    must self-abort: writes no-op instead of parking on a placement that
    will never resolve."""
    cluster = _cluster(fast_clock)
    pipe = cluster.node("edge-0").truffle.csp.open_pipe(
        "pt-not-registered", policy=PIPED)
    pipe.bind_source(cluster.node("edge-0"))
    done = []

    def writer():
        pipe.write(b"x" * 1024)      # must return promptly, not raise
        pipe.close()
        done.append(True)

    th = threading.Thread(target=writer, daemon=True)
    th.start()
    th.join(timeout=10)
    assert done, "producer write wedged on a dead trigger"
    assert not pipe.used             # nothing ever shipped


def test_pipe_threads_wind_down(fast_clock):
    """No pipe/invoke machinery thread outlives the run."""
    cluster = _cluster(fast_clock)
    WorkflowRunner(cluster, use_truffle=True).run(
        _chain("-leak"), b"go", source_node="edge-0")
    deadline = time.monotonic() + 5.0
    alive = []
    while time.monotonic() < deadline:
        alive = [t.name for t in threading.enumerate()
                 if t.name.startswith("pipe-")]
        if not alive:
            break
        time.sleep(0.05)
    assert not alive, alive
