"""Model-level correctness invariants:
  * prefill+decode == full prefill (KV-cache/state consistency) per family
  * causality: future tokens cannot influence past logits
  * MoE degenerates to a dense MLP for E=1/k=1
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.models import api, lm, layers, moe

# one representative per cache family: GQA, MLA, hybrid(mamba), xLSTM, enc-dec
DECODE_FAMILIES = ["glm4-9b", "minicpm3-4b", "jamba-v0.1-52b", "xlstm-125m",
                   "whisper-medium"]


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init(cfg, jax.random.PRNGKey(1))
    return cfg, params


@pytest.mark.parametrize("arch", DECODE_FAMILIES)
def test_decode_matches_prefill(arch):
    """Prefill on T tokens then decode token T must equal prefill on T+1."""
    cfg, params = _setup(arch)
    T = 16
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (2, T + 1), 0, cfg.vocab_size)

    if cfg.encoder is not None:
        frames = jax.random.normal(key, (2, cfg.encoder.num_frames, cfg.d_model),
                                   jnp.float32).astype(cfg.dtype) * 0.1
        full_logits, _ = api.prefill(cfg, params, {"frames": frames,
                                                   "tokens": toks})
        logits_T, cache = api.prefill(cfg, params, {"frames": frames,
                                                    "tokens": toks[:, :T]})
        # grow self cache to T+1 slots
        cache = {"self": jax.tree.map(
            lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))),
            cache["self"]), "cross": cache["cross"]}
        dec_logits, _ = api.decode_step(cfg, params, cache, toks[:, T:T + 1],
                                        jnp.asarray(T, jnp.int32))
    else:
        full_logits, _ = api.prefill(cfg, params, {"tokens": toks})
        logits_T, cache = api.prefill(cfg, params, {"tokens": toks[:, :T]})
        cache = _grow_cache(cfg, cache, extra=1)
        dec_logits, _ = api.decode_step(cfg, params, cache, toks[:, T:T + 1],
                                        jnp.asarray(T, jnp.int32))

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        atol=0.12, rtol=0.12)  # bf16 accumulation tolerance (deep stacks)


def _grow_cache(cfg, cache, extra):
    """Pad the sequence dim of attention caches by ``extra`` slots."""
    def pad(path, a):
        names = [str(getattr(p, "key", "")) for p in path]
        if names[-1] in ("k", "v"):           # [P,B,S,H,D]
            return jnp.pad(a, ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
        if names[-1] in ("ckv", "kpe"):       # [P,B,S,R]
            return jnp.pad(a, ((0, 0), (0, 0), (0, extra), (0, 0)))
        return a
    return jax.tree_util.tree_map_with_path(pad, cache)


@pytest.mark.parametrize("arch", ["qwen3-4b", "jamba-v0.1-52b", "xlstm-125m"])
def test_causality(arch):
    cfg, params = _setup(arch)
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (1, 24), 0, cfg.vocab_size)
    toks2 = toks.at[:, -4:].set((toks[:, -4:] + 7) % cfg.vocab_size)

    h1, _, _ = lm.forward(cfg, params, toks, mode="train")
    h2, _, _ = lm.forward(cfg, params, toks2, mode="train")
    # positions before the edit are bit-identical
    np.testing.assert_array_equal(np.asarray(h1[:, :20], np.float32),
                                  np.asarray(h2[:, :20], np.float32))
    assert not np.allclose(np.asarray(h1[:, -1], np.float32),
                           np.asarray(h2[:, -1], np.float32))


def test_moe_single_expert_equals_dense_mlp():
    cfg = get_config("olmoe-1b-7b", smoke=True).replace(
        moe=MoEConfig(num_experts=1, top_k=1, d_expert=128,
                      capacity_factor=2.0))
    key = jax.random.PRNGKey(0)
    from repro.models.params import init_params
    p = init_params(moe.moe_defs(cfg), key)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, cfg.d_model),
                          jnp.float32) * 0.3
    out, aux = moe.moe_apply(cfg, p, x)
    # same weights through the plain MLP path
    mlp_p = {"wi_gate": p["w_gate"][0], "wi_up": p["w_up"][0],
             "wo": p["w_down"][0]}
    want = layers.apply_mlp(cfg, mlp_p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_moe_load_balance_loss_range():
    cfg = get_config("olmoe-1b-7b", smoke=True)
    from repro.models.params import init_params
    p = init_params(moe.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, cfg.d_model),
                          jnp.float32)
    out, aux = moe.moe_apply(cfg, p, x)
    assert out.shape == x.shape
    # Switch LB loss is >= 1 (perfect balance) for softmax routing
    assert float(aux["moe_lb"]) >= 0.99
    assert 0.0 <= float(aux["moe_drop_frac"]) <= 1.0
