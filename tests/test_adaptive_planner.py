"""Adaptive planner: telemetry-backed Eq. 4 auto-selection per edge.

Covers: LinkTelemetry EWMA measurement/seeding, ``DataPolicy(strategy=
"auto")`` argmin resolution (stream/compression/chunk grid), per-edge
``chunk_bytes`` plumbing down to the channel grants, compile-time Eq. 4
predictions stamped on LifecycleRecords (error ≤ 10% asserted), and the
property suite: for random DAGs and random link matrices the auto plan's
model time never exceeds either uniform extreme, and compilation is
deterministic given frozen telemetry."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import model as tm
from repro.runtime.clock import Clock
from repro.runtime.cluster import Cluster
from repro.runtime.function import FunctionSpec
from repro.runtime.netsim import (Channel, DEFAULT_CHUNK_BYTES, GBPS,
                                  LinkTelemetry)
from repro.runtime.planner import (AdaptivePlanner, CHUNK_GRID, EdgeProfile,
                                   Planner)
from repro.runtime.policy import DataPolicy, WorkflowBuilder
from repro.runtime.workflow import WorkflowRunner

MB = 1 << 20
AUTO = DataPolicy(strategy="auto")
BLOB = DataPolicy()
STREAM_LZ4 = DataPolicy(stream=True, compression="lz4-like")


def _spec(name, *, provision_s=0.5, startup_s=0.1, exec_s=0.2,
          streaming=False, affinity=None, handler=None):
    return FunctionSpec(name, handler or (lambda d, inv: d),
                        provision_s=provision_s, startup_s=startup_s,
                        exec_s=exec_s, streaming=streaming,
                        affinity=affinity)


def _streaming_consumer(gamma_s, total_bytes, out=None):
    """Handler that drives get_input_stream with per-chunk compute summing
    to ``gamma_s`` (the planner's γ), independent of chunk size."""
    rate = gamma_s / max(total_bytes, 1)

    def handler(_d, inv):
        pacer = inv.cluster.clock.pacer()
        n = 0
        for chunk in inv.get_input_stream(timeout=120):
            pacer.sleep(len(chunk) * rate)
            n += len(chunk)
        return out if out is not None else bytes(8)
    return handler


# ------------------------------------------------------------ LinkTelemetry
def test_telemetry_seed_and_observe_ewma():
    tel = LinkTelemetry(alpha=0.25)
    tel.seed(tier_key=("edge", "edge"), bandwidth=100.0, rtt=0.01)
    est = tel.link("a", "b", tiers=("edge", "edge"))
    assert est.bandwidth == 100.0 and est.samples == 0
    # node-pair observations take precedence over the tier prior
    for _ in range(30):
        tel.observe_transfer(("a", "b"), ("edge", "edge"),
                             nbytes=1000, seconds=50.0, rtt=0.02)
    est = tel.link("a", "b", tiers=("edge", "edge"))
    assert est.bandwidth == pytest.approx(20.0, rel=0.05)   # 1000/50
    assert est.rtt == pytest.approx(0.02, rel=0.05)
    assert est.samples == 30
    # the tier EWMA converged off its seed toward the same evidence
    tier = tel.link(None, None, tiers=("edge", "edge"))
    assert tier.bandwidth == pytest.approx(20.0, rel=0.05)
    # unknown links resolve to nothing rather than a made-up number
    assert tel.link("x", "y") is None


def test_telemetry_codec_ratio_ewma():
    tel = LinkTelemetry(alpha=0.5)
    assert tel.codec_ratio("lz4-like") is None
    assert tel.codec_ratio("lz4-like", default=1.0) == 1.0
    tel.observe_codec("lz4-like", 0.1)
    tel.observe_codec("lz4-like", 0.3)
    assert tel.codec_ratio("lz4-like") == pytest.approx(0.2)


def test_channel_reports_grants_to_telemetry():
    tel = LinkTelemetry()
    ch = Channel("t", bandwidth=1e8, latency=0.001, clock=Clock(0.0),
                 link_key=("a", "b"), tier_key=("edge", "edge"),
                 telemetry=tel)
    ch.transfer(bytes(4 * MB))
    for _ in ch.stream(bytes(4 * MB), chunk_bytes=MB):
        pass
    est = tel.link("a", "b")
    assert est.bandwidth == pytest.approx(1e8, rel=0.01)
    assert est.rtt == pytest.approx(0.001, rel=0.2)
    assert est.samples == 5                       # 1 blob + 4 chunks
    assert tel.stats["observations"] == 5


def test_cluster_seeds_tier_priors():
    cluster = Cluster(clock=Clock(0.0))
    est = cluster.telemetry.link(None, None, tiers=("edge", "cloud"))
    bw, lat = cluster.network.tier_links[("edge", "cloud")]
    assert est.bandwidth == bw and est.rtt == lat and est.samples == 0


# ------------------------------------------------------- auto resolution
def _one_edge_plan(spec, profile, *, telemetry=None, default=AUTO):
    tel = telemetry
    if tel is None:
        tel = LinkTelemetry()
        tel.seed(link_key=("s", "d"), bandwidth=0.2 * GBPS, rtt=0.02)
    b = WorkflowBuilder("auto1", default_policy=default)
    b.stage("a", _spec("auto1-a"))
    b.stage("b", spec).after("a")
    plan = Planner(telemetry=tel).compile(
        b.build(), profiles={("a", "b"): profile})
    return plan.stages["b"].edge_policy("a")


def test_auto_picks_compression_on_slow_wan():
    """Compressible payload, bandwidth-bound WAN: stream + lz4 wins."""
    spec = _spec("wan-auto", streaming=True)
    pol = _one_edge_plan(
        spec, EdgeProfile(size=64 * MB, src_node="s", dst_node="d",
                          compress_ratio=0.05))
    assert pol.strategy == "direct"
    assert pol.compression == "lz4-like"
    assert pol.stream and pol.chunk_bytes in CHUNK_GRID


def test_auto_rejects_compression_on_codec_bound_link():
    """A link faster than the codec makes compression a slowdown (the
    transfer becomes codec-bound) — auto keeps the wire uncompressed."""
    tel = LinkTelemetry()
    tel.seed(link_key=("s", "d"), bandwidth=10.0 * GBPS, rtt=0.0002)
    spec = _spec("cc-auto")
    pol = _one_edge_plan(
        spec, EdgeProfile(size=64 * MB, src_node="s", dst_node="d",
                          compress_ratio=0.05),
        telemetry=tel)
    assert pol.compression == "none"


def test_auto_without_telemetry_or_profile_is_conservative():
    b = WorkflowBuilder("auto0", default_policy=AUTO)
    b.stage("a", _spec("auto0-a"))
    b.stage("b", _spec("auto0-b")).after("a")
    plan = Planner().compile(b.build())          # no telemetry, no profiles
    pol = plan.stages["b"].edge_policy("a")
    assert pol.strategy == "direct"
    assert not pol.stream and pol.compression == "none"
    assert plan.predicted_total is None


def test_auto_preserves_non_transport_fields():
    tel = LinkTelemetry()
    tel.seed(link_key=("s", "d"), bandwidth=0.2 * GBPS, rtt=0.02)
    pol = _one_edge_plan(
        _spec("keep-auto"),
        EdgeProfile(size=32 * MB, src_node="s", dst_node="d"),
        telemetry=tel,
        default=DataPolicy(strategy="auto", dedup=True, prefetch=True,
                           locality_weight=3.0, speculation=2.5))
    assert pol.dedup and pol.prefetch
    assert pol.locality_weight == 3.0 and pol.speculation == 2.5


def test_chunk_bytes_validation_and_merge():
    with pytest.raises(ValueError, match="chunk_bytes"):
        DataPolicy(chunk_bytes=0)
    b = WorkflowBuilder("chunks")
    b.stage("a", _spec("ch-a"))
    b.stage("b", _spec("ch-b"))
    b.stage("j", _spec("ch-j")) \
        .after("a", policy=DataPolicy(stream=True, chunk_bytes=4 * MB)) \
        .after("b", policy=DataPolicy(stream=True, chunk_bytes=MB))
    plan = b.plan()
    # the joined input moves once: the finest declared grant wins
    assert plan.stages["j"].transport.chunk_bytes == MB


def test_policy_chunk_bytes_reaches_channel_grants(fast_clock):
    """Per-edge chunk_bytes plumbs EdgePlan -> CSP -> Channel.stream: the
    grant count (telemetry observations) matches the policy's chunk size."""
    payload = bytes(4 * MB)
    counts = {}
    for chunk in (MB, 256 * 1024):
        cluster = Cluster(clock=fast_clock)
        cluster.platform.register(
            FunctionSpec(f"chunk-{chunk}", lambda d, inv: d[:4],
                         provision_s=0.2, startup_s=0.05, exec_s=0.01,
                         affinity="edge-1"))
        before = cluster.telemetry.stats["observations"]
        cluster.node("edge-0").truffle.pass_data(
            f"chunk-{chunk}", payload,
            policy=DataPolicy(stream=True, chunk_bytes=chunk))
        counts[chunk] = cluster.telemetry.stats["observations"] - before
    assert counts[MB] == 4
    assert counts[256 * 1024] == 16


# --------------------------------------------------- Eq. 4 predictions
def _hetero_chain(tag, *, size, gamma=0.2):
    """src(edge-0) -> mid(edge-1) -> fin(cloud-0): LAN hop carrying
    incompressible bytes, WAN hop carrying compressible bytes."""
    import random
    rnd = random.Random(7)
    lan_payload = rnd.randbytes(size)

    b = WorkflowBuilder(f"het{tag}", default_policy=AUTO)
    b.stage("src", _spec(f"src{tag}", exec_s=0.05, affinity="edge-0",
                         handler=lambda d, inv: lan_payload))
    b.stage("mid", _spec(f"mid{tag}", streaming=True, exec_s=gamma,
                         affinity="edge-1",
                         handler=_streaming_consumer(gamma, size,
                                                     out=bytes(size)))
            ).after("src")
    b.stage("fin", _spec(f"fin{tag}", streaming=True, exec_s=gamma,
                         affinity="cloud-0",
                         handler=_streaming_consumer(gamma, size))
            ).after("mid")
    wf = b.build()
    profiles = {
        ("src", "mid"): EdgeProfile(size=size, src_node="edge-0",
                                    dst_node="edge-1", compress_ratio=1.0),
        ("mid", "fin"): EdgeProfile(size=size, src_node="edge-1",
                                    dst_node="cloud-0", compress_ratio=0.05),
    }
    return wf, profiles


def test_eq4_prediction_error_within_10pct():
    """Compile-time Eq. 4 per-edge predictions vs measured stage times on
    the auto plan: error ≤ 10% for every cold stage."""
    clock = Clock(0.1)
    cluster = Cluster(clock=clock)
    wf, profiles = _hetero_chain("-eq4", size=24 * MB)
    plan = AdaptivePlanner(cluster).compile(wf, profiles=profiles)
    runner = WorkflowRunner(cluster, use_truffle=True, prewarm_roots=True,
                            plan=plan)
    tr = runner.run(wf, b"go", source_node="edge-0")
    checked = 0
    for name in ("mid", "fin"):
        rec = tr.stages[name].record
        if not rec.cold:
            continue
        assert rec.predicted_s is not None
        measured = clock.elapsed_sim(rec.total)
        err = abs(rec.predicted_s - measured) / measured
        assert err <= 0.10, (name, rec.predicted_s, measured)
        checked += 1
    assert checked >= 1


def test_auto_plan_measured_no_worse_than_uniform_extremes():
    """Measured end-to-end: the auto plan is not beaten by either uniform
    extreme (all whole-blob, all stream+lz4) on the heterogeneous chain."""
    clock = Clock(0.05)
    totals = {}
    for label, default in (("auto", AUTO), ("blob", BLOB),
                           ("slz4", STREAM_LZ4)):
        cluster = Cluster(clock=clock)
        wf, profiles = _hetero_chain(f"-mx-{label}", size=24 * MB)
        wf.default_policy = default
        plan = AdaptivePlanner(cluster).compile(wf, profiles=profiles)
        runner = WorkflowRunner(cluster, use_truffle=True,
                                prewarm_roots=True, plan=plan)
        tr = runner.run(wf, b"go", source_node="edge-0")
        totals[label] = clock.elapsed_sim(tr.total)
    floor = min(totals["blob"], totals["slz4"])
    assert totals["auto"] <= floor * 1.05 + 0.1, totals


# ------------------------------------------------------- property suite
N = 5
TRI = [(i, j) for i in range(N) for j in range(i + 1, N)]


def _compile_three(edge_flags, sizes_mb, bws, rtts, ratios):
    """Build the random DAG + link matrix; compile auto and the two
    uniform extremes against identical profiles/telemetry."""
    tel = LinkTelemetry()
    edges = [(i, j) for flag, (i, j) in zip(edge_flags, TRI) if flag]
    profiles = {}
    for k, (i, j) in enumerate(edges):
        tel.seed(link_key=(f"n{i}", f"n{j}"),
                 bandwidth=bws[k % len(bws)], rtt=rtts[k % len(rtts)])
        profiles[(f"s{i}", f"s{j}")] = EdgeProfile(
            size=int(sizes_mb[k % len(sizes_mb)] * MB),
            src_node=f"n{i}", dst_node=f"n{j}",
            compress_ratio=ratios[k % len(ratios)])

    def build():
        b = WorkflowBuilder("prop")
        for i in range(N):
            b.stage(f"s{i}", _spec(f"p{i}", streaming=(i % 2 == 0)))
        for i, j in edges:
            b.edge(f"s{i}", f"s{j}")
        return b.build()

    plans = {}
    for label, default in (("auto", AUTO), ("blob", BLOB),
                           ("slz4", STREAM_LZ4)):
        plans[label] = Planner(default=default, telemetry=tel).compile(
            build(), profiles=profiles)
    return plans


@settings(max_examples=40, deadline=None)
@given(
    st.tuples(*[st.booleans()] * len(TRI)),
    st.tuples(*[st.floats(min_value=0.5, max_value=192.0)] * 4),
    st.tuples(*[st.floats(min_value=1e6, max_value=2e9)] * 4),
    st.tuples(*[st.floats(min_value=0.0, max_value=0.05)] * 4),
    st.tuples(*[st.floats(min_value=0.03, max_value=1.0)] * 4),
)
def test_auto_never_exceeds_uniform_extremes(edge_flags, sizes_mb, bws,
                                             rtts, ratios):
    """Property: for random DAGs and random link matrices, the auto plan's
    model time (Eq. 5 over per-edge Eq. 4 terms) never exceeds EITHER
    uniform extreme — per-edge argmin dominates any uniform choice."""
    plans = _compile_three(edge_flags, sizes_mb, bws, rtts, ratios)
    auto_t = plans["auto"].predicted_total
    for extreme in ("blob", "slz4"):
        ext_t = plans[extreme].predicted_total
        if auto_t is None or ext_t is None:
            assert auto_t is None and ext_t is None    # edgeless DAG
            continue
        assert auto_t <= ext_t + 1e-9, (auto_t, ext_t, extreme)


@settings(max_examples=20, deadline=None)
@given(
    st.tuples(*[st.booleans()] * len(TRI)),
    st.tuples(*[st.floats(min_value=0.5, max_value=192.0)] * 4),
    st.tuples(*[st.floats(min_value=1e6, max_value=2e9)] * 4),
    st.tuples(*[st.floats(min_value=0.0, max_value=0.05)] * 4),
    st.tuples(*[st.floats(min_value=0.03, max_value=1.0)] * 4),
)
def test_compile_deterministic_given_frozen_telemetry(edge_flags, sizes_mb,
                                                      bws, rtts, ratios):
    """Property: same workflow + frozen telemetry -> identical plans
    (resolved policies AND predictions), twice over."""
    a = _compile_three(edge_flags, sizes_mb, bws, rtts, ratios)["auto"]
    b = _compile_three(edge_flags, sizes_mb, bws, rtts, ratios)["auto"]
    assert a.order == b.order
    for name in a.order:
        ea = a.stages[name].in_edges
        eb = b.stages[name].in_edges
        assert [(e.src, e.policy, e.predicted_s) for e in ea] \
            == [(e.src, e.policy, e.predicted_s) for e in eb]
    assert a.predicted_total == b.predicted_total
    assert a.describe() == b.describe()


# ---------------------------------------------------- model edge cases
def test_edge_delta_allows_codec_bound_stretch():
    p = tm.PhaseEstimate(alpha=0.1, nu=0.5, eta=0.1, delta=1.0, gamma=0.2)
    assert tm.edge_delta(p, wire_ratio=3.0) == pytest.approx(3.0)
    assert tm.edge_time(p, wire_ratio=3.0) == pytest.approx(0.1 + 3.0 + 0.2)
    # overhead is additive and un-compressible
    assert tm.edge_time(p, wire_ratio=0.1, overhead_s=0.7) \
        == pytest.approx(0.1 + max(0.6, 0.1 + 0.7) + 0.2)
