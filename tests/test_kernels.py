"""Per-kernel validation (assignment requirement): sweep shapes/dtypes in
interpret mode and assert_allclose against the ref.py pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # optional dep: vendored deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype, scale=0.3):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 128, 8, 1, 128),    # MQA, MXU-width head
    (2, 64, 4, 2, 32),      # small
])
def test_flash_attention_sweep(B, S, Hq, Hkv, D, dtype):
    key = jax.random.PRNGKey(0)
    q = _rand(key, (B, S, Hq, D), dtype)
    k = _rand(jax.random.fold_in(key, 1), (B, S, Hkv, D), dtype)
    v = _rand(jax.random.fold_in(key, 2), (B, S, Hkv, D), dtype, 1.0)
    out = ops.flash_attention(q, k, v, True, True)
    want = ref.flash_attention_ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype], rtol=ATOL[dtype])


def test_flash_attention_noncausal():
    key = jax.random.PRNGKey(1)
    q = _rand(key, (1, 128, 4, 64), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (1, 128, 4, 64), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (1, 128, 4, 64), jnp.float32, 1.0)
    out = ops.flash_attention(q, k, v, False, True)
    want = ref.flash_attention_ref(q, k, v, False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_backward():
    key = jax.random.PRNGKey(2)
    q = _rand(key, (1, 128, 4, 64), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (1, 128, 2, 64), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (1, 128, 2, 64), jnp.float32, 1.0)
    g1 = jax.grad(lambda a, b, c: ops.flash_attention(a, b, c, True, True).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda a, b, c: ref.flash_attention_ref(a, b, c, True).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4,
                                   rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kv_len", [1, 63, 256, 511, 512])
def test_decode_attention_lengths(kv_len, dtype):
    key = jax.random.PRNGKey(3)
    B, S, Hq, Hkv, D = 2, 512, 8, 2, 64
    q = _rand(key, (B, 1, Hq, D), dtype)
    k = _rand(jax.random.fold_in(key, 1), (B, S, Hkv, D), dtype)
    v = _rand(jax.random.fold_in(key, 2), (B, S, Hkv, D), dtype, 1.0)
    out = ops.decode_attention(q, k, v, jnp.asarray(kv_len), True)
    want = ref.decode_attention_ref(q[:, 0], k, v, jnp.asarray(kv_len))[:, None]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype], rtol=ATOL[dtype])


@settings(max_examples=10, deadline=None)
@given(rows=st.sampled_from([64, 128, 256]),
       d=st.sampled_from([128, 256, 512]),
       dt=st.sampled_from(["float32", "bfloat16"]))
def test_rmsnorm_property(rows, d, dt):
    dtype = jnp.dtype(dt)
    key = jax.random.PRNGKey(rows * 7 + d)
    x = _rand(key, (rows, d), dtype, 1.0)
    s = _rand(jax.random.fold_in(key, 1), (d,), jnp.float32, 1.0)
    out = ops.rmsnorm(x, s, interpret=True)
    want = ref.rmsnorm_ref(x, s)
    atol = 2e-5 if dt == "float32" else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol,
                               rtol=atol)
    # scale-equivariance: rmsnorm(c*x) == rmsnorm(x) for c > 0
    out2 = ops.rmsnorm(x * 3.0, s, interpret=True)
    np.testing.assert_allclose(np.asarray(out2, np.float32),
                               np.asarray(out, np.float32), atol=5e-2,
                               rtol=5e-2)
