"""Regression tests for three runtime races/leaks:

  1. warm checkouts must not release scheduler load credits they never took
     (stealing an in-flight cold start's credit skews least-loaded AND
     locality-vs-load placement),
  2. speculative dispatch must pick a deterministic winner, label
     ``speculated`` truthfully, and shut its executor down (one leaked pool
     per straggler stage before),
  3. ``Buffer.wait_for`` must return the data observed under the lock hold
     that saw completion — not re-acquire the lock where a racing eviction
     or displacement can turn a successful wait into ``None``.
"""
import itertools
import threading
import time

from repro.core.buffer import Buffer
from repro.core.model import PhaseEstimate
from repro.runtime.cluster import Cluster
from repro.runtime.function import FunctionSpec, Request
from repro.runtime.workflow import Stage, Workflow, WorkflowRunner


# ----------------------------------------------- 1. warm-release accounting
def test_warm_invocation_does_not_release_cold_load_credit(fast_clock):
    """A warm checkout never went through schedule(); completing it must not
    decrement the load credit an in-flight cold start is holding."""
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("warm-acct", lambda d, inv: d, provision_s=0.2,
                        startup_s=0.05, exec_s=0.01)
    cluster.platform.register(spec)

    # cold invoke: leaves one warm instance, load back to 0 after release
    cluster.platform.invoke(Request(fn="warm-acct", payload=b"x",
                                    source_node="edge-0"))
    warm_node = cluster.platform.warm_instances("warm-acct")[0].node.name
    assert cluster.scheduler.load_of(warm_node) == 0

    # an unrelated cold start is in flight on the same node: schedule()
    # charged it one load credit that is still outstanding
    other = FunctionSpec("in-flight", lambda d, inv: d)
    cluster.scheduler.schedule(other, "inv-in-flight")
    assert cluster.scheduler.load_of(warm_node) == 1

    # warm traffic completes — before the fix this released the in-flight
    # cold start's credit (load dropped to 0)
    out, rec = cluster.platform.invoke(Request(fn="warm-acct", payload=b"y",
                                               source_node="edge-0"))
    assert not rec.cold
    assert cluster.scheduler.load_of(warm_node) == 1


def test_cold_release_still_happens(fast_clock):
    """The cold path's credit is still released when the invocation ends."""
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("cold-rel", lambda d, inv: d, provision_s=0.2,
                        startup_s=0.05, exec_s=0.01)
    cluster.platform.register(spec)
    _, rec = cluster.platform.invoke(Request(fn="cold-rel", payload=b"x",
                                             source_node="edge-0"))
    assert rec.cold
    assert cluster.scheduler.load_of(rec.node) == 0


# ------------------------------------------------- 2. speculative dispatch
def _straggler_setup(handler, straggler_factor=3.0):
    spec = FunctionSpec("spec-fn", handler, provision_s=0.1, startup_s=0.05,
                        exec_s=0.01)
    wf = Workflow("w", {"s": Stage(spec)})
    est = {"s": PhaseEstimate(alpha=0.15, nu=0.1, eta=0.05, delta=0.01,
                              gamma=0.01)}
    return wf, est


def test_speculative_backup_wins_is_flagged(fast_clock):
    """First attempt stalls pathologically -> backup wins, speculated=True."""
    calls = itertools.count()

    def slow_once(d, inv):
        if next(calls) == 0:
            inv.cluster.clock.sleep(60.0)       # pathological straggler
        return d + b"-done"

    wf, est = _straggler_setup(slow_once)
    cluster = Cluster(clock=fast_clock)
    runner = WorkflowRunner(cluster, use_truffle=False, storage="direct",
                            straggler_factor=3.0, estimates=est)
    tr = runner.run(wf, b"x")
    assert tr.stages["s"].speculated is True
    assert tr.stages["s"].output == b"x-done"


def test_speculative_first_finisher_wins_deterministically(fast_clock):
    """First attempt outlives the budget but still beats the backup: the
    original attempt must win and must NOT be labeled speculated."""
    calls = itertools.count()

    def late_first(d, inv):
        n = next(calls)
        if n == 0:
            inv.cluster.clock.sleep(3.0)        # past budget, finishes first
        else:
            inv.cluster.clock.sleep(120.0)      # backup: far slower
        return d + b"-" + str(n).encode()

    wf, est = _straggler_setup(late_first)
    cluster = Cluster(clock=fast_clock)
    runner = WorkflowRunner(cluster, use_truffle=False, storage="direct",
                            straggler_factor=3.0, estimates=est)
    tr = runner.run(wf, b"x")
    assert tr.stages["s"].speculated is False
    assert tr.stages["s"].output == b"x-0"      # the original attempt's result


def test_speculative_dispatch_does_not_leak_executors(fast_clock, monkeypatch):
    """Every straggler-guarded stage used to leave its ThreadPoolExecutor
    un-shutdown: worker threads stayed parked until (if ever) the GC's
    weakref callback noticed the dead pool. Capture the pools the dispatcher
    creates — holding a reference, as any registry/profiler would, which
    disables the GC band-aid — and require an explicit shutdown."""
    import repro.runtime.workflow as wfmod

    created = []
    real_pool = wfmod.ThreadPoolExecutor

    class CapturingPool(real_pool):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            created.append(self)

    monkeypatch.setattr(wfmod, "ThreadPoolExecutor", CapturingPool)

    def prompt(d, inv):
        return d

    wf, est = _straggler_setup(prompt)
    cluster = Cluster(clock=fast_clock)
    runner = WorkflowRunner(cluster, use_truffle=False, storage="direct",
                            straggler_factor=5.0, estimates=est)
    for _ in range(3):
        runner.run(wf, b"x")
    assert created                           # the guarded path ran
    assert all(pool._shutdown for pool in created)
    # and the worker threads actually wind down (no parked threads left)
    deadline = time.monotonic() + 5.0
    while (any(t.is_alive() for pool in created for t in pool._threads)
           and time.monotonic() < deadline):
        time.sleep(0.05)
    assert not any(t.is_alive() for pool in created for t in pool._threads)


# --------------------------------------------------- 3. wait_for-vs-evict
def test_wait_for_returns_data_despite_racing_eviction():
    """The old implementation exited the wait loop, dropped the lock, and
    re-read via get() — an eviction (or same-key displacement) landing in
    that window returned None even though the wait succeeded. Emulate the
    window deterministically by making the trailing re-read miss."""
    b = Buffer()
    b.set("k", b"payload")
    b.get = lambda key, pop=False: None      # any post-wait re-read misses
    assert b.wait_for("k", timeout=1) == b"payload"


def test_wait_for_pop_under_lock():
    """pop=True drops the entry atomically with the successful wait."""
    b = Buffer()
    b.set("k", b"v")
    assert b.wait_for("k", timeout=1, pop=True) == b"v"
    assert "k" not in b


def test_wait_for_still_blocks_and_times_out():
    b = Buffer()
    assert b.wait_for("missing", timeout=0.05) is None
    got = {}

    def waiter():
        got["v"] = b.wait_for("later", timeout=5)

    th = threading.Thread(target=waiter)
    th.start()
    time.sleep(0.05)
    b.set("later", b"xyz")
    th.join(timeout=5)
    assert got["v"] == b"xyz"


# ------------------------------------- 4. residency-aware eviction (PR 5)
MB = 1 << 20


def _filled(data_byte: bytes, size: int = MB) -> bytes:
    return bytes(data_byte) * size


def test_sole_replica_survives_lru_pressure():
    """A buffer wired to the cluster registry sheds replicated content
    first: the LRU-oldest entry survives capacity pressure when it is the
    cluster's LAST copy of its digest, while a newer 3-replica digest is
    evicted instead."""
    from repro.core.buffer import content_digest

    cluster = Cluster(clock=fast_clock_obj())
    buf = cluster.node("edge-0").buffer
    buf.capacity = 3 * MB

    sole = _filled(b"s")
    d_sole = content_digest(sole)
    buf.set("sole", sole, digest=d_sole)           # oldest; ONLY copy
    hot = _filled(b"h")
    d_hot = content_digest(hot)
    buf.set("hot", hot, digest=d_hot)
    # 3 replicas total: the other two nodes hold the same content
    cluster.node("edge-1").buffer.set("hot-r1", hot, digest=d_hot)
    cluster.node("cloud-0").buffer.set("hot-r2", hot, digest=d_hot)

    buf.set("filler", _filled(b"f", 2 * MB))       # 4 MB > 3 MB: evict 1 MB
    # plain LRU would evict "sole" (oldest); residency-aware evicts "hot"
    assert buf.get("sole") == sole
    assert "hot" not in buf
    assert buf.size <= buf.capacity
    # the registry saw the withdrawal, and the other replicas still resolve
    assert set(cluster.digests.nodes_for(d_hot)) == {"edge-1", "cloud-0"}
    assert set(cluster.digests.nodes_for(d_sole)) == {"edge-0"}


def test_plain_lru_without_oracle_unchanged():
    """A standalone Buffer (no replica oracle) keeps strict LRU order —
    the default path is byte-for-byte the old behavior."""
    from repro.core.buffer import content_digest

    b = Buffer(capacity_bytes=3 * MB)
    x = _filled(b"x")
    b.set("x", x, digest=content_digest(x))
    y = _filled(b"y")
    b.set("y", y, digest=content_digest(y))
    b.set("filler", _filled(b"f", 2 * MB))
    assert "x" not in b                            # oldest goes first
    assert b.get("y") == y


def test_eviction_falls_back_to_sole_replica_when_nothing_else():
    """Capacity is still a hard bound: when every victim is a sole
    replica, the LRU-oldest one IS evicted (deferral, not immunity)."""
    from repro.core.buffer import content_digest

    cluster = Cluster(clock=fast_clock_obj())
    buf = cluster.node("edge-0").buffer
    buf.capacity = 3 * MB
    x = _filled(b"x")
    buf.set("x", x, digest=content_digest(x))      # sole
    y = _filled(b"y")
    buf.set("y", y, digest=content_digest(y))      # sole
    buf.set("filler", _filled(b"f", 2 * MB))
    assert "x" not in buf                          # oldest sole replica
    assert buf.get("y") == y
    assert buf.size <= buf.capacity


def test_eviction_prefers_anonymous_entries_over_sole_replicas():
    """Entries with no digest (nothing downstream can alias them) are fair
    game before the last copy of addressable content — even when younger."""
    from repro.core.buffer import content_digest

    cluster = Cluster(clock=fast_clock_obj())
    buf = cluster.node("edge-0").buffer
    buf.capacity = 3 * MB
    x = _filled(b"x")
    buf.set("x", x, digest=content_digest(x))      # oldest; sole replica
    buf.set("anon", _filled(b"a"))                 # younger, digest-less
    buf.set("filler", _filled(b"f", 2 * MB))
    assert buf.get("x") == x
    assert "anon" not in buf


def fast_clock_obj():
    from repro.runtime.clock import Clock
    return Clock(scale=0.01)


# ----------------------------------------------- 4. warm-pool cap and TTL
def test_burst_does_not_inflate_warm_pool_past_cap(fast_clock):
    """Six concurrent cold starts used to leave six warm instances forever
    (unbounded append at check-in). With a pool limit, check-in discards
    past ``max`` and counts the drop."""
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("pool-cap", lambda d, inv: d, provision_s=0.3,
                        startup_s=0.05, exec_s=0.05)
    cluster.platform.register(spec)
    cluster.platform.set_pool_limit("pool-cap", 2)

    def one(i):
        cluster.platform.invoke(Request(fn="pool-cap", payload=b"x",
                                        source_node="edge-0"))

    threads = [threading.Thread(target=one, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    pool = cluster.platform.warm_instances("pool-cap")
    assert len(pool) <= 2
    assert cluster.platform.stats["pool_drops"] >= 4
    assert cluster.platform.stats["cold_starts"] == 6


def test_idle_warm_instances_expire_by_ttl_down_to_min(fast_clock):
    """Warm instances idle past ``idle_ttl_s`` (sim-seconds) are reaped,
    but never below the configured ``min_instances`` floor."""
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("pool-ttl", lambda d, inv: d, provision_s=0.1,
                        startup_s=0.02, exec_s=0.01)
    cluster.platform.register(spec)
    cluster.platform.set_pool_limit("pool-ttl", 4, idle_ttl_s=1.0)

    cluster.platform.invoke(Request(fn="pool-ttl", payload=b"a",
                                    source_node="edge-0"))
    assert len(cluster.platform.warm_instances("pool-ttl")) == 1

    time.sleep(0.05)                     # 5 sim-seconds at scale=0.01 > TTL
    assert cluster.platform.reap_idle() == 1
    assert cluster.platform.warm_instances("pool-ttl") == []
    assert cluster.platform.stats["pool_expired"] == 1

    # with a min floor the survivor is retained past its TTL
    cluster.platform.set_pool_limit("pool-ttl", 4, idle_ttl_s=1.0,
                                    min_instances=1)
    cluster.platform.invoke(Request(fn="pool-ttl", payload=b"b",
                                    source_node="edge-0"))
    time.sleep(0.05)
    assert cluster.platform.reap_idle() == 0
    assert len(cluster.platform.warm_instances("pool-ttl")) == 1
