"""Regression tests for three runtime races/leaks:

  1. warm checkouts must not release scheduler load credits they never took
     (stealing an in-flight cold start's credit skews least-loaded AND
     locality-vs-load placement),
  2. speculative dispatch must pick a deterministic winner, label
     ``speculated`` truthfully, and shut its executor down (one leaked pool
     per straggler stage before),
  3. ``Buffer.wait_for`` must return the data observed under the lock hold
     that saw completion — not re-acquire the lock where a racing eviction
     or displacement can turn a successful wait into ``None``.

Later sections cover the CAS/stream accounting fixes that rode the
pipelined-edge work: alias promotion when a digest's owning entry leaves
(bytes stay charged), displaced in-flight writers failing immediately,
high-water-mark backpressure on in-flight entries, and blocked readers
waking with an error on node crash or stream abort instead of burning
their timeout.
"""
import itertools
import threading
import time

from repro.core.buffer import Buffer
from repro.core.model import PhaseEstimate
from repro.runtime.cluster import Cluster
from repro.runtime.function import FunctionSpec, Request
from repro.runtime.workflow import Stage, Workflow, WorkflowRunner


# ----------------------------------------------- 1. warm-release accounting
def test_warm_invocation_does_not_release_cold_load_credit(fast_clock):
    """A warm checkout never went through schedule(); completing it must not
    decrement the load credit an in-flight cold start is holding."""
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("warm-acct", lambda d, inv: d, provision_s=0.2,
                        startup_s=0.05, exec_s=0.01)
    cluster.platform.register(spec)

    # cold invoke: leaves one warm instance, load back to 0 after release
    cluster.platform.invoke(Request(fn="warm-acct", payload=b"x",
                                    source_node="edge-0"))
    warm_node = cluster.platform.warm_instances("warm-acct")[0].node.name
    assert cluster.scheduler.load_of(warm_node) == 0

    # an unrelated cold start is in flight on the same node: schedule()
    # charged it one load credit that is still outstanding
    other = FunctionSpec("in-flight", lambda d, inv: d)
    cluster.scheduler.schedule(other, "inv-in-flight")
    assert cluster.scheduler.load_of(warm_node) == 1

    # warm traffic completes — before the fix this released the in-flight
    # cold start's credit (load dropped to 0)
    out, rec = cluster.platform.invoke(Request(fn="warm-acct", payload=b"y",
                                               source_node="edge-0"))
    assert not rec.cold
    assert cluster.scheduler.load_of(warm_node) == 1


def test_cold_release_still_happens(fast_clock):
    """The cold path's credit is still released when the invocation ends."""
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("cold-rel", lambda d, inv: d, provision_s=0.2,
                        startup_s=0.05, exec_s=0.01)
    cluster.platform.register(spec)
    _, rec = cluster.platform.invoke(Request(fn="cold-rel", payload=b"x",
                                             source_node="edge-0"))
    assert rec.cold
    assert cluster.scheduler.load_of(rec.node) == 0


# ------------------------------------------------- 2. speculative dispatch
def _straggler_setup(handler, straggler_factor=3.0):
    spec = FunctionSpec("spec-fn", handler, provision_s=0.1, startup_s=0.05,
                        exec_s=0.01)
    wf = Workflow("w", {"s": Stage(spec)})
    est = {"s": PhaseEstimate(alpha=0.15, nu=0.1, eta=0.05, delta=0.01,
                              gamma=0.01)}
    return wf, est


def test_speculative_backup_wins_is_flagged(fast_clock):
    """First attempt stalls pathologically -> backup wins, speculated=True."""
    calls = itertools.count()

    def slow_once(d, inv):
        if next(calls) == 0:
            inv.cluster.clock.sleep(60.0)       # pathological straggler
        return d + b"-done"

    wf, est = _straggler_setup(slow_once)
    cluster = Cluster(clock=fast_clock)
    runner = WorkflowRunner(cluster, use_truffle=False, storage="direct",
                            straggler_factor=3.0, estimates=est)
    tr = runner.run(wf, b"x")
    assert tr.stages["s"].speculated is True
    assert tr.stages["s"].output == b"x-done"


def test_speculative_first_finisher_wins_deterministically(fast_clock):
    """First attempt outlives the budget but still beats the backup: the
    original attempt must win and must NOT be labeled speculated."""
    calls = itertools.count()

    def late_first(d, inv):
        n = next(calls)
        if n == 0:
            inv.cluster.clock.sleep(3.0)        # past budget, finishes first
        else:
            inv.cluster.clock.sleep(120.0)      # backup: far slower
        return d + b"-" + str(n).encode()

    wf, est = _straggler_setup(late_first)
    cluster = Cluster(clock=fast_clock)
    runner = WorkflowRunner(cluster, use_truffle=False, storage="direct",
                            straggler_factor=3.0, estimates=est)
    tr = runner.run(wf, b"x")
    assert tr.stages["s"].speculated is False
    assert tr.stages["s"].output == b"x-0"      # the original attempt's result


def test_speculative_dispatch_does_not_leak_executors(fast_clock, monkeypatch):
    """Every straggler-guarded stage used to leave its ThreadPoolExecutor
    un-shutdown: worker threads stayed parked until (if ever) the GC's
    weakref callback noticed the dead pool. Capture the pools the dispatcher
    creates — holding a reference, as any registry/profiler would, which
    disables the GC band-aid — and require an explicit shutdown."""
    import repro.runtime.workflow as wfmod

    created = []
    real_pool = wfmod.ThreadPoolExecutor

    class CapturingPool(real_pool):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            created.append(self)

    monkeypatch.setattr(wfmod, "ThreadPoolExecutor", CapturingPool)

    def prompt(d, inv):
        return d

    wf, est = _straggler_setup(prompt)
    cluster = Cluster(clock=fast_clock)
    runner = WorkflowRunner(cluster, use_truffle=False, storage="direct",
                            straggler_factor=5.0, estimates=est)
    for _ in range(3):
        runner.run(wf, b"x")
    assert created                           # the guarded path ran
    assert all(pool._shutdown for pool in created)
    # and the worker threads actually wind down (no parked threads left)
    deadline = time.monotonic() + 5.0
    while (any(t.is_alive() for pool in created for t in pool._threads)
           and time.monotonic() < deadline):
        time.sleep(0.05)
    assert not any(t.is_alive() for pool in created for t in pool._threads)


# --------------------------------------------------- 3. wait_for-vs-evict
def test_wait_for_returns_data_despite_racing_eviction():
    """The old implementation exited the wait loop, dropped the lock, and
    re-read via get() — an eviction (or same-key displacement) landing in
    that window returned None even though the wait succeeded. Emulate the
    window deterministically by making the trailing re-read miss."""
    b = Buffer()
    b.set("k", b"payload")
    b.get = lambda key, pop=False: None      # any post-wait re-read misses
    assert b.wait_for("k", timeout=1) == b"payload"


def test_wait_for_pop_under_lock():
    """pop=True drops the entry atomically with the successful wait."""
    b = Buffer()
    b.set("k", b"v")
    assert b.wait_for("k", timeout=1, pop=True) == b"v"
    assert "k" not in b


def test_wait_for_still_blocks_and_times_out():
    b = Buffer()
    assert b.wait_for("missing", timeout=0.05) is None
    got = {}

    def waiter():
        got["v"] = b.wait_for("later", timeout=5)

    th = threading.Thread(target=waiter)
    th.start()
    time.sleep(0.05)
    b.set("later", b"xyz")
    th.join(timeout=5)
    assert got["v"] == b"xyz"


# ------------------------------------- 4. residency-aware eviction (PR 5)
MB = 1 << 20


def _filled(data_byte: bytes, size: int = MB) -> bytes:
    return bytes(data_byte) * size


def test_sole_replica_survives_lru_pressure():
    """A buffer wired to the cluster registry sheds replicated content
    first: the LRU-oldest entry survives capacity pressure when it is the
    cluster's LAST copy of its digest, while a newer 3-replica digest is
    evicted instead."""
    from repro.core.buffer import content_digest

    cluster = Cluster(clock=fast_clock_obj())
    buf = cluster.node("edge-0").buffer
    buf.capacity = 3 * MB

    sole = _filled(b"s")
    d_sole = content_digest(sole)
    buf.set("sole", sole, digest=d_sole)           # oldest; ONLY copy
    hot = _filled(b"h")
    d_hot = content_digest(hot)
    buf.set("hot", hot, digest=d_hot)
    # 3 replicas total: the other two nodes hold the same content
    cluster.node("edge-1").buffer.set("hot-r1", hot, digest=d_hot)
    cluster.node("cloud-0").buffer.set("hot-r2", hot, digest=d_hot)

    buf.set("filler", _filled(b"f", 2 * MB))       # 4 MB > 3 MB: evict 1 MB
    # plain LRU would evict "sole" (oldest); residency-aware evicts "hot"
    assert buf.get("sole") == sole
    assert "hot" not in buf
    assert buf.size <= buf.capacity
    # the registry saw the withdrawal, and the other replicas still resolve
    assert set(cluster.digests.nodes_for(d_hot)) == {"edge-1", "cloud-0"}
    assert set(cluster.digests.nodes_for(d_sole)) == {"edge-0"}


def test_plain_lru_without_oracle_unchanged():
    """A standalone Buffer (no replica oracle) keeps strict LRU order —
    the default path is byte-for-byte the old behavior."""
    from repro.core.buffer import content_digest

    b = Buffer(capacity_bytes=3 * MB)
    x = _filled(b"x")
    b.set("x", x, digest=content_digest(x))
    y = _filled(b"y")
    b.set("y", y, digest=content_digest(y))
    b.set("filler", _filled(b"f", 2 * MB))
    assert "x" not in b                            # oldest goes first
    assert b.get("y") == y


def test_eviction_falls_back_to_sole_replica_when_nothing_else():
    """Capacity is still a hard bound: when every victim is a sole
    replica, the LRU-oldest one IS evicted (deferral, not immunity)."""
    from repro.core.buffer import content_digest

    cluster = Cluster(clock=fast_clock_obj())
    buf = cluster.node("edge-0").buffer
    buf.capacity = 3 * MB
    x = _filled(b"x")
    buf.set("x", x, digest=content_digest(x))      # sole
    y = _filled(b"y")
    buf.set("y", y, digest=content_digest(y))      # sole
    buf.set("filler", _filled(b"f", 2 * MB))
    assert "x" not in buf                          # oldest sole replica
    assert buf.get("y") == y
    assert buf.size <= buf.capacity


def test_eviction_prefers_anonymous_entries_over_sole_replicas():
    """Entries with no digest (nothing downstream can alias them) are fair
    game before the last copy of addressable content — even when younger."""
    from repro.core.buffer import content_digest

    cluster = Cluster(clock=fast_clock_obj())
    buf = cluster.node("edge-0").buffer
    buf.capacity = 3 * MB
    x = _filled(b"x")
    buf.set("x", x, digest=content_digest(x))      # oldest; sole replica
    buf.set("anon", _filled(b"a"))                 # younger, digest-less
    buf.set("filler", _filled(b"f", 2 * MB))
    assert buf.get("x") == x
    assert "anon" not in buf


def fast_clock_obj():
    from repro.runtime.clock import Clock
    return Clock(scale=0.01)


# ----------------------------------------------- 4. warm-pool cap and TTL
def test_burst_does_not_inflate_warm_pool_past_cap(fast_clock):
    """Six concurrent cold starts used to leave six warm instances forever
    (unbounded append at check-in). With a pool limit, check-in discards
    past ``max`` and counts the drop."""
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("pool-cap", lambda d, inv: d, provision_s=0.3,
                        startup_s=0.05, exec_s=0.05)
    cluster.platform.register(spec)
    cluster.platform.set_pool_limit("pool-cap", 2)

    def one(i):
        cluster.platform.invoke(Request(fn="pool-cap", payload=b"x",
                                        source_node="edge-0"))

    threads = [threading.Thread(target=one, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    pool = cluster.platform.warm_instances("pool-cap")
    assert len(pool) <= 2
    assert cluster.platform.stats["pool_drops"] >= 4
    assert cluster.platform.stats["cold_starts"] == 6


def test_idle_warm_instances_expire_by_ttl_down_to_min(fast_clock):
    """Warm instances idle past ``idle_ttl_s`` (sim-seconds) are reaped,
    but never below the configured ``min_instances`` floor."""
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("pool-ttl", lambda d, inv: d, provision_s=0.1,
                        startup_s=0.02, exec_s=0.01)
    cluster.platform.register(spec)
    cluster.platform.set_pool_limit("pool-ttl", 4, idle_ttl_s=1.0)

    cluster.platform.invoke(Request(fn="pool-ttl", payload=b"a",
                                    source_node="edge-0"))
    assert len(cluster.platform.warm_instances("pool-ttl")) == 1

    time.sleep(0.05)                     # 5 sim-seconds at scale=0.01 > TTL
    assert cluster.platform.reap_idle() == 1
    assert cluster.platform.warm_instances("pool-ttl") == []
    assert cluster.platform.stats["pool_expired"] == 1

    # with a min floor the survivor is retained past its TTL
    cluster.platform.set_pool_limit("pool-ttl", 4, idle_ttl_s=1.0,
                                    min_instances=1)
    cluster.platform.invoke(Request(fn="pool-ttl", payload=b"b",
                                    source_node="edge-0"))
    time.sleep(0.05)
    assert cluster.platform.reap_idle() == 0
    assert len(cluster.platform.warm_instances("pool-ttl")) == 1


# ------------------------------------- 5. CAS alias/stream accounting
def test_owner_drop_promotes_surviving_alias():
    """Dropping the digest's owning entry while an alias shares its chunk
    list must promote the alias — bytes stay readable AND charged (the
    old path withdrew the digest and left the chunks resident-but-free)."""
    from repro.core.buffer import content_digest

    b = Buffer()
    data = _filled(b"d")
    d = content_digest(data)
    b.set("owner", data, digest=d)
    assert b.alias("al", d)
    assert b.size == MB                    # alias itself charged 0
    assert b.drop("owner")
    assert b.get("al") == data             # promoted heir serves the bytes
    assert b.size == MB                    # ... and still pays for them
    assert b.find_digest(d) == "al"
    assert b.stats["alias_promotions"] == 1


def test_owner_eviction_promotes_pinned_alias_and_keeps_charge():
    """Same promotion under LRU pressure: the evicted owner's byte charge
    moves to the surviving pinned alias instead of vanishing."""
    from repro.core.buffer import content_digest

    b = Buffer(capacity_bytes=3 * MB)
    data = _filled(b"o")
    d = content_digest(data)
    b.set("owner", data, digest=d)
    b.alias("keep", d, pinned=True)        # pinned: immune to eviction
    b.set("fill", _filled(b"f", 3 * MB))   # pressure: owner is the victim
    assert "owner" not in b
    assert b.get("keep") == data
    # 3 MB fill + 1 MB promoted alias: the undercount bug reported 3 MB
    assert b.size == 4 * MB
    assert b.find_digest(d) == "keep"


def test_displaced_ingest_writer_fails_immediately():
    """A same-key re-open displaces an in-flight ingest: its next append
    must raise NOW (the zombie used to keep growing entry size uncharged
    until close, drifting the capacity ledger)."""
    import pytest

    b = Buffer()

    def chunks():
        yield b"one"
        b.open_stream("k")                 # displace the ingest's entry
        yield b"two"

    with pytest.raises(IOError):
        b.ingest("k", chunks())
    # the successor stream is untouched by the zombie writer
    b.append_chunk("k", b"fresh")
    b.close_stream("k")
    assert b.get("k") == b"fresh"
    assert b.size == len(b"fresh")


# --------------------------------- 6. pipelined-edge backpressure + wakeups
def test_append_blocks_at_highwater_until_reader_drains():
    """Past the high-water mark the writer parks (bp_waits counts it) and
    a reader taking one chunk releases exactly one more append."""
    b = Buffer()
    b.open_stream("k", highwater=2)
    b.append_chunk("k", b"x")              # first chunk always admitted
    b.append_chunk("k", b"y")              # 2 unconsumed = at the mark
    landed = []

    def writer():
        b.append_chunk("k", b"z")          # must block until a drain
        landed.append(True)

    th = threading.Thread(target=writer, daemon=True)
    th.start()
    time.sleep(0.1)
    assert not landed                      # parked at the mark
    assert b.stats["bp_waits"] >= 1

    r = b.open_reader("k", timeout=5)
    assert next(r) == b"x"                 # drain 1 byte -> room for "z"
    th.join(timeout=5)
    assert landed
    b.close_stream("k")
    assert list(r) == [b"y", b"z"]


def test_wait_for_lifts_highwater_for_whole_blob_waiters():
    """A wait_for consumer never drains chunk-wise; waiting must lift the
    mark so the writer can finish instead of deadlocking against it."""
    b = Buffer()
    b.open_stream("k", highwater=2)

    def writer():
        for _ in range(5):
            b.append_chunk("k", b"c")
        b.close_stream("k")

    th = threading.Thread(target=writer, daemon=True)
    th.start()
    assert b.wait_for("k", timeout=5) == b"c" * 5
    th.join(timeout=5)


def test_blocked_reader_raises_on_node_crash_mid_stream(fast_clock):
    """A reader parked on an in-flight stream must wake with an error when
    the node dies mid-stream — not burn its timeout."""
    cluster = Cluster(clock=fast_clock)
    buf = cluster.node("edge-0").buffer
    buf.open_stream("k")
    buf.append_chunk("k", b"c1")
    r = buf.open_reader("k", timeout=30)
    assert next(r) == b"c1"
    outcome = []

    def read_next():
        t0 = time.monotonic()
        try:
            next(r)
            outcome.append(("chunk", time.monotonic() - t0))
        except Exception as e:  # noqa: BLE001
            outcome.append((type(e).__name__, time.monotonic() - t0))

    th = threading.Thread(target=read_next, daemon=True)
    th.start()
    time.sleep(0.05)
    cluster.kill_node("edge-0")
    th.join(timeout=5)
    assert outcome and outcome[0][0] in ("BufferOfflineError", "OSError")
    assert outcome[0][1] < 5.0             # woke NOW, not at the timeout


def test_blocked_reader_raises_on_stream_abort():
    b = Buffer()
    b.open_stream("k")
    r = b.open_reader("k", timeout=30)
    outcome = []

    def read_next():
        try:
            next(r)
        except Exception as e:  # noqa: BLE001
            outcome.append(type(e).__name__)

    th = threading.Thread(target=read_next, daemon=True)
    th.start()
    time.sleep(0.05)
    b.abort_stream("k")                    # writer died before any chunk
    th.join(timeout=5)
    assert outcome == ["OSError"]
