"""Gradient compression: quantization round-trip properties (single device)
and an 8-device shard_map equivalence check (subprocess: needs its own
XLA device-count flag)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # optional dep: vendored deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.distributed.compression import dequantize, quantize, quantization_error

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_quantize_roundtrip_bounds():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0
    q, s = quantize(x)
    err = np.abs(np.asarray(x - dequantize(q, s)))
    assert err.max() <= float(s) * 0.5 + 1e-7      # half-ULP of the int8 grid


def test_quantize_zeros():
    q, s = quantize(jnp.zeros((16,)))
    np.testing.assert_array_equal(np.asarray(dequantize(q, s)), 0.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-6, 1e4))
def test_quantize_relative_error_property(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * scale
    q, s = quantize(x)
    err = np.abs(np.asarray(x - dequantize(q, s))).max()
    assert err <= np.abs(np.asarray(x)).max() / 127.0 * 0.5 + 1e-9


def test_error_feedback_residual():
    x = jax.random.normal(jax.random.PRNGKey(1), (512,))
    r = quantization_error(x)
    q, s = quantize(x)
    np.testing.assert_allclose(np.asarray(dequantize(q, s) + r),
                               np.asarray(x), atol=1e-6)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import compressed_grad_sync

# version-adaptive: jax >= 0.6 has jax.shard_map/check_vma and explicit
# axis types; 0.4.x uses jax.experimental.shard_map and check_rep
if hasattr(jax, "shard_map"):
    shard_map, check_kw = jax.shard_map, {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map
    check_kw = {"check_rep": False}
axis_type = getattr(jax.sharding, "AxisType", None)
mesh_kw = {"axis_types": (axis_type.Explicit,)} if axis_type else {}
mesh = jax.make_mesh((8,), ("data",), **mesh_kw)
g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 64, 513)),
     "b": jax.random.normal(jax.random.PRNGKey(1), (8, 33))}

@functools.partial(shard_map, mesh=mesh,
                   in_specs=({"w": P("data"), "b": P("data")},),
                   out_specs={"w": P(), "b": P()}, **check_kw)
def sync(tree):
    local = jax.tree.map(lambda x: x[0], tree)
    return compressed_grad_sync(local, "data")

out = sync(g)
want = jax.tree.map(lambda x: jnp.mean(x, 0), g)
for k in ("w", "b"):
    a, b = np.asarray(out[k]), np.asarray(want[k])
    rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-9)
    assert rel < 0.02, (k, rel)   # two int8 quantization stages ~ <2% of amax
print("OK")
"""


def test_compressed_sync_8dev_subprocess():
    out = subprocess.run([sys.executable, "-c", _SUBPROC, SRC],
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
