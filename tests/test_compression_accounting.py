"""Compression accounting: wire grants charge wire bytes (not raw bytes),
codec-bound transfers pace at the codec, and the lifecycle record's
``compress_ratio`` matches the codec's sampled estimate."""
import random
import time
import zlib

import pytest

from repro.distributed.compression import LZ4_LIKE, chunk_codec
from repro.runtime.clock import Clock
from repro.runtime.cluster import Cluster
from repro.runtime.function import FunctionSpec
from repro.runtime.netsim import Channel, LinkTelemetry
from repro.runtime.policy import DataPolicy

MB = 1 << 20


def _observed(tel):
    snap = tel.snapshot()["links"][("a", "b")]
    return snap


def test_transfer_grants_charge_wire_bytes():
    """Whole-blob with wire_ratio: the bandwidth grant (what telemetry sees
    as seconds-on-the-wire) covers the COMPRESSED bytes only."""
    for ratio in (1.0, 0.25):
        tel = LinkTelemetry()
        ch = Channel("t", bandwidth=1e8, latency=0.0, clock=Clock(0.0),
                     link_key=("a", "b"), telemetry=tel)
        payload = bytes(8 * MB)
        ch.transfer(payload, wire_ratio=ratio)
        est = _observed(tel)
        wire = Channel.wire_bytes(len(payload), ratio)
        assert wire == int(len(payload) * ratio)
        # one observation: bandwidth = wire_bytes / wire_seconds = nominal
        assert est.bandwidth == pytest.approx(1e8, rel=0.01)


def test_stream_grants_charge_wire_bytes_wall_time():
    """Chunk streams with wire_ratio=0.25 take ~1/4 the wall time of the
    uncompressed stream — grants shrink with the wire bytes."""
    clock = Clock(0.5)
    durations = {}
    for ratio in (1.0, 0.25):
        ch = Channel("t", bandwidth=2e8, latency=0.0, clock=clock)
        t0 = time.monotonic()
        for _ in ch.stream(bytes(64 * MB), wire_ratio=ratio):
            pass
        durations[ratio] = clock.elapsed_sim(time.monotonic() - t0)
    expected = durations[1.0] * 0.25
    assert durations[0.25] == pytest.approx(expected, rel=0.3)


def test_codec_bound_transfer_paces_at_codec_throughput():
    """pace_bps below the wire rate: the transfer finishes at the codec's
    rate (payload/pace), not the wire's — compression on a fat link is a
    slowdown, which is exactly what the adaptive planner prices in."""
    clock = Clock(0.5)
    ch = Channel("t", bandwidth=1e9, latency=0.0, clock=clock)
    payload = bytes(16 * MB)
    t0 = time.monotonic()
    ch.transfer(payload, wire_ratio=0.05, pace_bps=1e8)
    paced = clock.elapsed_sim(time.monotonic() - t0)
    assert paced == pytest.approx(len(payload) / 1e8, rel=0.25)

    t0 = time.monotonic()
    for _ in ch.stream(payload, wire_ratio=0.05, pace_bps=1e8):
        pass
    paced = clock.elapsed_sim(time.monotonic() - t0)
    assert paced == pytest.approx(len(payload) / 1e8, rel=0.25)


def test_codec_ratio_sampled_estimate():
    """The codec's ratio comes from deflating a sampled head window, with
    the framing floor as a lower bound and 1.0 as the cap."""
    codec = chunk_codec("lz4-like")
    zeros = bytes(4 * MB)
    assert codec.ratio(zeros) == pytest.approx(codec.floor)
    rnd = random.Random(3).randbytes(4 * MB)
    assert codec.ratio(rnd) == pytest.approx(1.0)
    sample = rnd[:codec.sample_bytes]
    expected = min(1.0, max(codec.floor,
                            len(zlib.compress(sample, codec.level))
                            / len(sample)))
    assert codec.ratio(rnd) == expected


@pytest.mark.parametrize("stream", [False, True])
def test_record_compress_ratio_matches_sampled_estimate(fast_clock, stream):
    """CSP pass with lz4-like: record.compress_ratio equals the codec's
    sampled estimate of THIS payload (both blob and stream paths), and
    telemetry's codec EWMA tracks it."""
    cluster = Cluster(clock=fast_clock)
    name = f"cmp-acct-{stream}"
    cluster.platform.register(
        FunctionSpec(name, lambda d, inv: d[:4], provision_s=0.2,
                     startup_s=0.05, exec_s=0.01, affinity="cloud-0"))
    # half-compressible payload -> a mid-range sampled ratio
    rnd = random.Random(11)
    payload = b"".join(rnd.randbytes(32 * 1024) + bytes(32 * 1024)
                       for _ in range(64))
    expected = LZ4_LIKE.ratio(payload)
    assert 0.1 < expected < 0.9               # genuinely mid-range
    _, rec = cluster.node("edge-0").truffle.pass_data(
        name, payload,
        policy=DataPolicy(stream=stream, compression="lz4-like"))
    assert rec.compress_ratio == pytest.approx(expected, rel=0.01)
    assert cluster.telemetry.codec_ratio("lz4-like") \
        == pytest.approx(expected, rel=0.01)
