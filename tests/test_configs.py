"""Registry + config integrity: all 10 assigned archs, param counts vs
published sizes, shape applicability grid (40 cells)."""
import pytest

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import get_config, iter_cells, list_archs

PUBLISHED_B = {  # (total, active) billions from the papers / model cards
    "glm4-9b": (9.4, 9.4),
    "minicpm3-4b": (4.1, 4.1),
    "qwen3-4b": (4.0, 4.0),
    "stablelm-1.6b": (1.6, 1.6),
    "jamba-v0.1-52b": (52.0, 12.0),
    "olmoe-1b-7b": (6.9, 1.3),
    "qwen2-moe-a2.7b": (14.3, 2.7),
    "whisper-medium": (0.77, 0.77),
    "xlstm-125m": (0.16, 0.16),
    "qwen2-vl-7b": (7.6, 7.6),
}


def test_all_archs_present():
    assert len(list_archs()) == 10
    assert set(list_archs()) == set(PUBLISHED_B)


@pytest.mark.parametrize("arch", list(PUBLISHED_B))
def test_param_count_matches_published(arch):
    cfg = get_config(arch)
    total, active = PUBLISHED_B[arch]
    assert cfg.param_count() / 1e9 == pytest.approx(total, rel=0.15)
    assert cfg.param_count(active_only=True) / 1e9 == pytest.approx(active, rel=0.15)


@pytest.mark.parametrize("arch", list(PUBLISHED_B))
def test_smoke_config_valid(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_periods >= 1
    assert cfg.d_model <= 128  # genuinely reduced


def test_cell_grid_is_40():
    cells = list(iter_cells())
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    # long_500k only for the two sub-quadratic archs
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 8
    assert all(s[1].name == "long_500k" for s in skipped)
    assert len(runnable) == 32


def test_long_context_applicability():
    assert shape_applicable(get_config("jamba-v0.1-52b"), SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("xlstm-125m"), SHAPES["long_500k"])[0]
    ok, why = shape_applicable(get_config("glm4-9b"), SHAPES["long_500k"])
    assert not ok and "full-attention" in why
