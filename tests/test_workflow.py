"""Workflow DAG executor: topology, fan-out/fan-in, straggler mitigation,
and trace accounting."""
import pytest

from repro.core.model import PhaseEstimate
from repro.runtime.cluster import Cluster
from repro.runtime.function import FunctionSpec
from repro.runtime.workflow import Stage, Workflow, WorkflowRunner


def _spec(name, exec_s=0.02, **kw):
    kw.setdefault("provision_s", 0.2)
    kw.setdefault("startup_s", 0.05)
    return FunctionSpec(name, lambda d, inv: d + name.encode()[-1:],
                        exec_s=exec_s, **kw)


def test_topo_order():
    wf = Workflow("w", {
        "c": Stage(_spec("c"), deps=["a", "b"]),
        "a": Stage(_spec("a")),
        "b": Stage(_spec("b"), deps=["a"]),
    })
    order = wf.topo_order()
    assert order.index("a") < order.index("b") < order.index("c")
    assert wf.roots() == ["a"]


def test_diamond_dag_executes_once_each(fast_clock):
    calls = []

    def make(name):
        def h(d, inv):
            calls.append(name)
            return d
        return FunctionSpec(name, h, provision_s=0.2, startup_s=0.05,
                            exec_s=0.01)

    wf = Workflow("diamond", {
        "src": Stage(make("src")),
        "l": Stage(make("l"), deps=["src"]),
        "r": Stage(make("r"), deps=["src"]),
        "sink": Stage(make("sink"), deps=["l", "r"]),
    })
    cluster = Cluster(clock=fast_clock)
    tr = WorkflowRunner(cluster, use_truffle=True, storage="direct").run(
        wf, b"x")
    assert sorted(calls) == ["l", "r", "sink", "src"]
    assert len(tr.stages) == 4
    assert tr.total > 0


def test_straggler_speculative_dispatch(fast_clock):
    """A stage that stalls far beyond its estimate gets a backup dispatch."""
    import itertools
    stall = itertools.count()

    def slow_once(d, inv):
        if next(stall) == 0:
            inv.cluster.clock.sleep(30.0)  # first attempt: pathological
        return d

    spec = FunctionSpec("strag", slow_once, provision_s=0.1, startup_s=0.05,
                        exec_s=0.01)
    wf = Workflow("w", {"s": Stage(spec)})
    est = {"s": PhaseEstimate(alpha=0.15, nu=0.1, eta=0.05, delta=0.01,
                              gamma=0.01)}
    cluster = Cluster(clock=fast_clock)
    runner = WorkflowRunner(cluster, use_truffle=False, storage="direct",
                            straggler_factor=3.0, estimates=est)
    tr = runner.run(wf, b"x")
    # backup finished long before the 30s-sim straggler would have
    assert fast_clock.elapsed_sim(tr.total) < 10.0


def test_trace_phase_totals(fast_clock):
    wf = Workflow("w", {"a": Stage(_spec("wf-a")),
                        "b": Stage(_spec("wf-b"), deps=["a"])})
    cluster = Cluster(clock=fast_clock)
    tr = WorkflowRunner(cluster, use_truffle=False, storage="kvs").run(wf, b"x")
    pt = tr.phase_totals()
    assert set(pt) == {"scheduling", "cold_start", "io", "execution", "put"}
    assert pt["cold_start"] > 0          # both stages were cold
    assert pt["put"] > 0                 # kvs passing wrote to storage
    assert tr.io_total == pytest.approx(pt["io"] + pt["put"])
