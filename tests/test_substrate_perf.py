"""Runtime-substrate regression tests: the raw-speed properties the
event-driven rework bought, pinned at test scale.

Four families:
- speedup floors: the new substrate must beat the frozen pre-refactor
  hot paths (``benchmarks/_legacy_substrate.py``) even at tiny scale,
  with floors far below the benchmark gate's (small runs are noisy);
- bounded memory: the per-topic bus retains a bounded window under a
  200-wave publish soak (the legacy bus grew its one global log forever);
- copy-free digests: ``content_digest`` hashes a memoryview without
  materializing the payload, and the incremental hasher matches the
  joined-blob digest bit-for-bit;
- a concurrency slice that hammers the batched scheduler, batched grants,
  and sharded bus from many threads at once — run under
  ``TRUFFLE_LOCKCHECK=1`` (conftest) it doubles as the lock-discipline
  witness for the new substrate paths.
"""
import pytest

from repro.core.buffer import IncrementalDigest, content_digest
from repro.runtime.clock import Clock
from repro.runtime.events import EventBus
from repro.runtime.executor import EXECUTOR
from repro.runtime.function import FunctionSpec
from repro.runtime.netsim import Channel, LinkTelemetry
from repro.runtime.scheduler import Scheduler


def _bench():
    """The benchmark module doubles as the test fixture (same workloads,
    same frozen legacy baseline) — resolved lazily so a broken bench
    import fails the perf tests, not collection of the whole file."""
    from benchmarks import substrate_bench
    return substrate_bench


# ------------------------------------------------------- speedup floors
def _best_speedup(new_fn, legacy_fn, attempts: int = 3) -> float:
    """Best-of-N ratio: micro-runs on shared CI boxes see multi-ms noise
    spikes; the property under test is capability, not a tight CI SLA."""
    best = 0.0
    for _ in range(attempts):
        t_new = new_fn()
        t_legacy = legacy_fn()
        if t_new > 0:
            best = max(best, t_legacy / t_new)
    return best


def test_placement_speedup_floor():
    sb = _bench()
    s = _best_speedup(lambda: sb._bench_place_new(200),
                      lambda: sb._bench_place_legacy(200))
    # benchmark gate demands 5x at 1k; at n=200 demand a conservative 1.5x
    assert s >= 1.5, f"placement speedup {s:.2f}x < 1.5x floor"


def test_grant_speedup_floor():
    sb = _bench()
    s = _best_speedup(lambda: sb._bench_grant_new(4096),
                      lambda: sb._bench_grant_legacy(4096))
    assert s >= 1.5, f"grant speedup {s:.2f}x < 1.5x floor"


def test_digest_speedup_and_equality():
    sb = _bench()
    t_new, t_legacy, _ = sb._bench_digest(total_mb=8)
    # the equality assert lives inside _bench_digest; here pin that the
    # incremental fold is at least not SLOWER than join+copy+rehash
    assert t_new <= t_legacy * 1.25, \
        f"incremental digest slower than legacy: {t_new:.4f}s vs {t_legacy:.4f}s"


# ------------------------------------------------------- bounded memory
def test_bus_memory_bounded_over_soak():
    """200 publish waves on a fixed topic set: retained events stay at the
    per-topic cap (aged-out events are dropped and counted), and the
    allocation footprint stops growing once the windows are full — the
    legacy bus grew by wave_events × waves forever."""
    import tracemalloc

    retain = 64
    topics = 8
    waves, wave_events = 200, 200
    bus = EventBus(retain=retain)
    names = [f"soak.t{i}" for i in range(topics)]

    def wave(w: int) -> None:
        for i in range(wave_events):
            bus.publish(names[i % topics], {"wave": w, "i": i})

    for w in range(waves // 2):           # fill every window to its cap
        wave(w)
    tracemalloc.start()
    base, _ = tracemalloc.get_traced_memory()
    for w in range(waves // 2, waves):
        wave(w)
    grown, _ = tracemalloc.get_traced_memory()
    tracemalloc.stop()

    stats = bus.stats()
    assert stats["retained"] <= retain * topics
    assert stats["dropped"] > 0           # the soak actually aged events out
    total = waves * wave_events
    assert stats["dropped"] == total - stats["retained"]
    # steady-state waves must not accumulate: allow slack for allocator
    # noise, but nothing near the ~100k events published after the mark
    assert grown - base < 256 * 1024, \
        f"bus grew {(grown - base) / 1024:.0f} KiB during steady-state soak"
    # late-joiner semantics hold over the retained window only
    assert bus.wait_for(names[0], lambda e: e["wave"] == waves - 1,
                        timeout=1.0) is not None
    assert bus.wait_for(names[0], lambda e: e["wave"] == 0,
                        timeout=0.05) is None


# ----------------------------------------------------- copy-free digest
def test_content_digest_copy_free():
    """Digesting an 8 MiB memoryview must not materialize the payload:
    the legacy path's ``bytes(data)`` peaked at +payload bytes."""
    import tracemalloc

    payload = bytes(8 << 20)
    view = memoryview(payload)
    content_digest(view)                  # warm hashlib internals
    tracemalloc.start()
    d = content_digest(view)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert d == content_digest(payload)   # view and bytes agree
    assert peak < (1 << 20), \
        f"content_digest allocated {peak >> 10} KiB for an 8 MiB view"


def test_incremental_digest_matches_blob():
    chunks = [bytes([i]) * 1337 for i in range(32)]
    h = IncrementalDigest()
    for c in chunks:
        h.update(memoryview(c))
    assert h.hexdigest() == content_digest(b"".join(chunks))
    assert h.n_bytes == sum(len(c) for c in chunks)


# -------------------------------------------- concurrency / lock slice
class _Node:
    __slots__ = ("name", "alive")

    def __init__(self, name):
        self.name = name
        self.alive = True


class _MiniCluster:
    def __init__(self):
        self.clock = Clock(0.0)
        self.bus = EventBus()
        self.node_list = [_Node(f"n{i}") for i in range(4)]


def test_substrate_concurrency_slice():
    """Hammer every new substrate path from many threads at once: batched
    placements (flat-combining leader election), batched chunk grants +
    closed-form telemetry folds, sharded publishes with parked waiters,
    and pooled dispatch. Correctness asserts are exact counters — and
    under TRUFFLE_LOCKCHECK=1 this doubles as the inversion witness."""
    cluster = _MiniCluster()
    sched = Scheduler(cluster, scheduling_s=0.0)
    spec = FunctionSpec("slice", lambda d, inv: d)
    tel = LinkTelemetry()
    ch = Channel("slice", bandwidth=1e12, latency=0.0, clock=Clock(0.0),
                 link_key=("a", "b"), tier_key=("edge", "edge"),
                 telemetry=tel)
    threads, per = 16, 50
    errors = []

    def one(tid: int) -> None:
        try:
            after = None
            for i in range(per):
                node = sched.schedule(spec, f"t{tid}-{i}")
                deadlines, bw = ch.grant_chunks([2048] * 4, after=after)
                after = deadlines[-1]
                ch._observe_n(2048, 2048 / bw, 4)
                cluster.bus.publish(f"slice.done.{tid}", {"i": i})
                sched.release(node.name)
            got = cluster.bus.wait_for(f"slice.done.{tid}",
                                       lambda e: e["i"] == per - 1,
                                       timeout=10.0)
            assert got is not None
        except BaseException as e:  # noqa: BLE001 — surfaced below
            errors.append(e)

    tasks = [EXECUTOR.submit(one, args=(t,), name=f"slice-{t}")
             for t in range(threads)]
    for t in tasks:
        t.result(timeout=60.0)
    assert not errors, errors
    assert sched.stats["placements"] == threads * per
    assert sched.stats["placement_batches"] <= sched.stats["placements"]
    assert sum(sched._load.values()) == 0          # every release landed
    est = tel.link(tiers=("edge", "edge"))
    assert est is not None
    assert est.samples == threads * per * 4        # batch folds count exact
    assert est.bandwidth == pytest.approx(1e12)


def test_scheduler_combining_matches_serial_pick():
    """A batch leader's decisions must match what serial lock-per-placement
    picks would have produced: round-robin across equally loaded nodes."""
    cluster = _MiniCluster()
    sched = Scheduler(cluster, scheduling_s=0.0)
    spec = FunctionSpec("rr", lambda d, inv: d)
    picked = [sched.schedule(spec, f"i{i}").name for i in range(8)]
    # 4 nodes, no releases: every node charged twice, in node_list order
    assert picked == ["n0", "n1", "n2", "n3"] * 2
    assert sched.load_of("n0") == 2
