"""Chunked streaming data plane: per-chunk channel grants (fair share),
buffer streaming entries + content-addressed dedup, O(1) LRU eviction,
pipelined CSP/SDP transfers, transfer-stall detection, and the Eq. 4
pipelined-transfer model term against the running system."""
import threading
import time

import pytest

from repro.core import model as tm
from repro.core.buffer import Buffer, content_digest
from repro.core.errors import TransferStallError
from repro.runtime.clock import Clock
from repro.runtime.cluster import Cluster
from repro.runtime.function import ContentRef, FunctionSpec, Request
from repro.runtime.netsim import Channel, GBPS

MB = 1 << 20


# ---------------------------------------------------------------- channel
def test_channel_stream_yields_all_bytes_and_models_time():
    clock = Clock(0.05)
    ch = Channel("s", bandwidth=0.45 * GBPS, latency=0.0005, clock=clock)
    # correctness: exact bytes, in order
    payload = bytes(range(256)) * (2 * MB // 256)
    assert b"".join(ch.stream(payload, chunk_bytes=MB)) == payload
    # timing: consume without materializing (joins are real memcpy cost,
    # not modeled transfer time)
    payload = bytes(16 * MB)
    t0 = time.monotonic()
    n = sum(len(c) for c in ch.stream(payload, chunk_bytes=MB))
    wall = time.monotonic() - t0
    assert n == len(payload)
    modeled = clock.elapsed_sim(wall)
    assert modeled == pytest.approx(ch.transfer_time(len(payload)), rel=0.35)


def test_channel_stream_fair_share_no_head_of_line_blocking():
    """A small streamed transfer completes while a big one is in flight —
    per-chunk grants interleave instead of payload-length lock holds."""
    clock = Clock(0.05)
    ch = Channel("f", bandwidth=1 * GBPS, latency=0.0, clock=clock)
    done = {}

    def run(tag, nbytes):
        t0 = time.monotonic()
        for _ in ch.stream(bytes(nbytes), chunk_bytes=MB):
            pass
        done[tag] = time.monotonic() - t0

    big = threading.Thread(target=run, args=("big", 64 * MB))
    big.start()
    time.sleep(0.008)                      # big stream is mid-flight
    run("small", 2 * MB)
    big.join(timeout=30)
    assert done["small"] < done["big"]     # not serialized behind the blob


def test_channel_empty_payload_streams_one_empty_chunk():
    ch = Channel("e", bandwidth=GBPS, latency=0.0, clock=Clock(0.0))
    assert [bytes(c) for c in ch.stream(b"")] == [b""]


# ----------------------------------------------------------------- buffer
def test_buffer_stream_reader_sees_chunks_at_arrival():
    b = Buffer()
    b.open_stream("k")
    got = []

    def consume():
        for chunk in b.open_reader("k", timeout=5):
            got.append(bytes(chunk))

    t = threading.Thread(target=consume)
    t.start()
    b.append_chunk("k", b"aa")
    time.sleep(0.02)
    b.append_chunk("k", b"bb")
    b.close_stream("k")
    t.join(timeout=5)
    assert got == [b"aa", b"bb"]
    assert b.get("k") == b"aabb"           # complete entry reads whole


def test_buffer_wait_for_blocks_until_stream_complete():
    b = Buffer()
    b.open_stream("k")
    b.append_chunk("k", b"xy")
    assert b.get("k") is None              # in-flight: not a full value yet
    assert b.wait_for("k", timeout=0.05) is None
    b.close_stream("k")
    assert b.wait_for("k", timeout=1) == b"xy"


def test_buffer_reader_timeout_raises():
    b = Buffer()
    b.open_stream("k")
    reader = b.open_reader("k", timeout=0.05)
    with pytest.raises(TimeoutError):
        next(reader)


def test_buffer_content_addressing_alias_dedup():
    b = Buffer()
    payload = b"z" * 1000
    d = content_digest(payload)
    b.set("orig", payload, digest=d)
    assert b.find_digest(d) == "orig"
    assert b.alias("copy", d)
    assert b.get("copy") == payload
    assert b.stats["dedup_hits"] == 1
    assert b.alias("nope", content_digest(b"other")) is False
    # aliased chunks are shared, not copied
    assert b._entries["copy"].chunks is b._entries["orig"].chunks


def test_buffer_abort_stream_frees_bytes_and_wakes_reader():
    b = Buffer()
    b.open_stream("k")
    b.append_chunk("k", b"a" * 100)
    errbox = []

    def consume():
        try:
            for _ in b.open_reader("k", timeout=5):
                pass
        except IOError as e:
            errbox.append(e)

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.02)
    b.abort_stream("k")
    t.join(timeout=5)
    assert errbox, "reader must fail, not see a truncated input"
    assert "k" not in b
    assert b.size == 0                     # appended chunks not leaked


def test_buffer_alias_not_double_charged():
    b = Buffer()
    payload = b"y" * 1000
    d = content_digest(payload)
    b.set("src", payload, digest=d)
    assert b.alias("a1", d) and b.alias("a2", d)
    assert b.size == len(payload)          # shared chunks charged once
    assert b.find_digest(d) == "src"       # index still points at the source
    # self-alias (repeated fetch under the same key) must not zero the charge
    assert b.alias("src", d)
    assert b.size == len(payload)
    assert b.get("src") == payload


def test_buffer_digest_index_dropped_on_eviction():
    b = Buffer(capacity_bytes=100)
    d = content_digest(b"a" * 80)
    b.set("a", b"a" * 80, digest=d)
    b.set("b", b"b" * 80)                  # evicts "a"
    assert "a" not in b
    assert b.find_digest(d) is None
    assert not b.alias("re", d)


def test_buffer_eviction_10k_entries_o1():
    """Regression: eviction used to restart a full scan per evicted entry
    and re-scan pinned entries every pass (O(n^2)). With pinned entries at
    the front and 10k inserts, that is ~2e7 scan steps; LRU-ordered
    unpinned tracking makes it O(1) amortized."""
    b = Buffer(capacity_bytes=100 * 1024)
    for i in range(2000):                  # pinned clutter the old scan path
        b.set(f"pin/{i}", b"p" * 8, pinned=True)
    t0 = time.monotonic()
    for i in range(10_000):
        b.set(f"k/{i}", b"x" * 1024)
    elapsed = time.monotonic() - t0
    assert b.stats["evictions"] >= 9900
    assert b.size <= 100 * 1024 + 2000 * 8
    for i in range(2000):                  # pins never evicted
        assert f"pin/{i}" in b
    # generous bound: the O(n^2) implementation takes far longer
    assert elapsed < 2.0, f"eviction too slow: {elapsed:.2f}s"


def test_buffer_incomplete_streams_never_evicted():
    b = Buffer(capacity_bytes=100)
    b.open_stream("inflight")
    b.append_chunk("inflight", b"c" * 90)
    b.set("filler", b"f" * 90)             # over capacity: evicts filler only
    assert "inflight" in b
    b.close_stream("inflight")
    assert b.wait_for("inflight", timeout=1) == b"c" * 90


# ---------------------------------------------------------------- storage
def test_storage_stream_roundtrip_and_digest():
    from repro.storage.base import make_kvs

    clock = Clock(0.0)
    src, dst = make_kvs(clock), make_kvs(clock)
    payload = bytes(range(256)) * (2 * MB // 256)
    src.put("in", payload)
    t = dst.put_stream("out", src.get_stream("in"))   # get → put pipeline
    assert dst.get("out")[0] == payload
    assert t == pytest.approx(dst.latency + len(payload) / dst.put_bandwidth,
                              rel=1e-6)
    assert dst.digest("out") == content_digest(payload)
    # empty chunk iterator: stores an empty object, charges latency only
    assert dst.put_stream("empty", iter(())) == pytest.approx(dst.latency)
    assert dst.get("empty")[0] == b""


# ------------------------------------------------------------- CSP stream
def _streaming_spec(name, eps, n_chunks, **kw):
    def handler(_, inv):
        pacer = inv.cluster.clock.pacer()
        total = 0
        for chunk in inv.get_input_stream():
            pacer.sleep(eps)
            total += len(chunk)
        return str(total).encode()
    kw.setdefault("provision_s", 0.3)
    kw.setdefault("startup_s", 0.05)
    return FunctionSpec(name, handler, streaming=True, **kw)


def test_csp_stream_hides_io_behind_coldstart_and_exec():
    """Acceptance shape: transfer > cold start; streaming visible IO well
    below the whole-blob visible IO, near the Eq. 4 pipelined prediction."""
    clock = Clock(0.1)
    cluster = Cluster(clock=clock)
    n = 32
    exec_total = 0.3
    eps = exec_total / (n - 1)
    payload = bytes(n * MB)

    blob = FunctionSpec("st-blob", lambda d, inv: d, provision_s=0.3,
                        startup_s=0.05, exec_s=exec_total, affinity="edge-1")
    strm = _streaming_spec("st-strm", eps, n, affinity="edge-1")
    cluster.platform.register(blob)
    cluster.platform.register(strm)
    truffle = cluster.node("edge-0").truffle

    _, rb = truffle.pass_data("st-blob", payload)
    out, rs = truffle.pass_data("st-strm", payload, stream=True)
    assert out == str(len(payload)).encode()
    io_blob = clock.elapsed_sim(rb.io_visible)
    io_strm = clock.elapsed_sim(rs.io_visible)
    assert rs.streamed and not rb.streamed
    assert io_blob > 0.1                   # transfer exceeds cold start here
    assert io_strm <= 0.7 * io_blob        # >= 30% visible-IO reduction

    bw, lat = cluster.network.tier_links[("edge", "edge")]
    p = tm.PhaseEstimate(alpha=0.15, nu=0.3, eta=0.05,
                         delta=lat + len(payload) / bw, gamma=exec_total)
    predicted = tm.pipelined_io_visible(p, exec_overlap=exec_total)
    assert io_strm == pytest.approx(predicted, abs=0.12)
    assert clock.elapsed_sim(rb.io_visible) == pytest.approx(
        max(0.0, p.delta - p.beta), abs=0.12)


def test_csp_dedup_repeated_fanout_input_near_zero_transfer():
    """Second pass of identical bytes to the same node is served from the
    content-addressed buffer: no fetch, no relay."""
    clock = Clock(0.05)
    cluster = Cluster(clock=clock)
    payload = bytes(8 * MB)
    for i in range(3):
        cluster.platform.register(
            FunctionSpec(f"fan-{i}", lambda d, inv: d, provision_s=0.3,
                         startup_s=0.05, exec_s=0.01, affinity="edge-1"))
    truffle = cluster.node("edge-0").truffle
    _, r0 = truffle.pass_data("fan-0", payload, dedup=True)
    assert not r0.dedup_hit                # first pass pays the transfer
    for i in (1, 2):
        _, r = truffle.pass_data(f"fan-{i}", payload, dedup=True)
        assert r.dedup_hit
        post_place = clock.elapsed_sim(
            max(0.0, r.t_transfer_end - r.t_placed))
        assert post_place < 0.05           # near-zero transfer after placement
    assert cluster.node("edge-1").buffer.stats["dedup_hits"] == 2


def test_sdp_stream_fetch_pipelines_storage_read(fast_clock):
    cluster = Cluster(clock=fast_clock)
    payload = bytes(4 * MB)
    cluster.storage["kvs"].put("obj-s", payload)
    spec = FunctionSpec("sdp-strm", lambda d, inv: d, provision_s=0.5,
                        startup_s=0.1, exec_s=0.01)
    cluster.platform.register(spec)
    req = Request(fn="sdp-strm",
                  content_ref=ContentRef("kvs", "obj-s", len(payload)))
    out, rec = cluster.node("edge-0").truffle.handle_request(req, stream=True)
    assert out == payload
    assert rec.mode == "truffle"
    assert rec.io_visible <= 0.02


def test_sdp_dedup_via_storage_digest(fast_clock):
    """Two SDP requests for the same stored object: the second is aliased
    from the target buffer's digest index (Data Engine skips the fetch)."""
    cluster = Cluster(clock=fast_clock)
    payload = bytes(2 * MB)
    cluster.storage["kvs"].put("obj-d", payload)
    for i in range(2):
        cluster.platform.register(
            FunctionSpec(f"sdp-d{i}", lambda d, inv: d, provision_s=0.3,
                         startup_s=0.05, exec_s=0.01, affinity="edge-1"))
    truffle = cluster.node("edge-0").truffle
    ref = ContentRef("kvs", "obj-d", len(payload))
    _, r0 = truffle.handle_request(Request(fn="sdp-d0", content_ref=ref),
                                   dedup=True)
    _, r1 = truffle.handle_request(Request(fn="sdp-d1", content_ref=ref),
                                   dedup=True)
    assert not r0.dedup_hit
    assert r1.dedup_hit
    eng = cluster.node("edge-1").truffle.engine
    assert eng.stats["dedup_hits"] == 1
    assert eng.stats["fetches"] == 1       # one storage read for two invocations


# ----------------------------------------------------------- stall raises
def test_csp_transfer_stall_recorded_and_raised(fast_clock):
    """Regression: a transfer thread outliving the join budget used to be
    silently swallowed; it must be recorded and raised."""
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("stall-fn", lambda d, inv: d, provision_s=0.2,
                        startup_s=0.05, exec_s=0.01, affinity="edge-1")
    cluster.platform.register(spec)
    truffle = cluster.node("edge-0").truffle
    truffle.csp.join_timeout_s = 0.05

    target_buffer = cluster.node("edge-1").buffer
    orig_set = target_buffer.set

    def slow_set(key, data, **kw):
        orig_set(key, data, **kw)          # input lands (function completes)
        time.sleep(1.0)                    # ...then the thread wedges

    target_buffer.set = slow_set
    try:
        with pytest.raises(TransferStallError) as exc:
            truffle.pass_data("stall-fn", b"payload")
    finally:
        target_buffer.set = orig_set
    assert exc.value.record is not None
    assert exc.value.record.transfer_stalled


def test_sdp_transfer_stall_recorded_and_raised(fast_clock):
    cluster = Cluster(clock=fast_clock)
    spec = FunctionSpec("stall-sdp", lambda d, inv: d, provision_s=0.2,
                        startup_s=0.05, exec_s=0.01, affinity="edge-1")
    cluster.platform.register(spec)
    truffle = cluster.node("edge-0").truffle
    truffle.sdp.join_timeout_s = 0.05

    target_buffer = cluster.node("edge-1").buffer
    orig_set = target_buffer.set

    def slow_set(key, data, **kw):
        orig_set(key, data, **kw)
        time.sleep(1.0)

    target_buffer.set = slow_set
    try:
        with pytest.raises(TransferStallError) as exc:
            truffle.handle_request(Request(fn="stall-sdp", payload=b"x"))
    finally:
        target_buffer.set = orig_set
    assert exc.value.record.transfer_stalled


# -------------------------------------------------------------- model ext
def test_pipelined_model_terms():
    p = tm.PhaseEstimate(alpha=0.1, nu=1.0, eta=0.5, delta=4.0, gamma=2.0)
    # whole-blob truffle: visible IO = delta - beta = 2.5
    assert tm.truffle_time(p) == pytest.approx(0.1 + 4.0 + 2.0)
    # streaming with full exec overlap: visible IO = 4.0 - 1.5 - 2.0 = 0.5
    assert tm.pipelined_io_visible(p, exec_overlap=2.0) == pytest.approx(0.5)
    assert tm.streamed_time(p, exec_overlap=2.0) == pytest.approx(
        0.1 + 1.5 + 0.5 + 2.0)
    # gain over whole-blob = min(overlap, delta - beta)
    assert tm.streamed_improvement(p, exec_overlap=2.0) == pytest.approx(2.0)
    assert tm.streamed_improvement(p, exec_overlap=5.0) == pytest.approx(2.5)
    # transfer shorter than cold start: nothing visible either way
    q = tm.PhaseEstimate(alpha=0.1, nu=1.0, eta=0.5, delta=0.3, gamma=1.0)
    assert tm.pipelined_io_visible(q, exec_overlap=1.0) == 0.0
    assert tm.streamed_improvement(q, exec_overlap=1.0) == 0.0


def test_workflow_runner_stream_dedup_matches_default(fast_clock):
    """The streamed+dedup workflow path returns identical outputs to the
    whole-blob default (behavior flag-gated, results unchanged)."""
    from repro.runtime.workflow import Stage, Workflow, WorkflowRunner

    def spec(name):
        return FunctionSpec(name, lambda d, inv: d + b"!", provision_s=0.2,
                            startup_s=0.05, exec_s=0.01)

    outs = {}
    for stream in (False, True):
        wf = Workflow("w", {"a": Stage(spec(f"wsd-a{stream}")),
                            "b": Stage(spec(f"wsd-b{stream}"), deps=["a"])})
        cluster = Cluster(clock=fast_clock)
        tr = WorkflowRunner(cluster, use_truffle=True, storage="direct",
                            stream=stream, dedup=stream).run(wf, b"in")
        outs[stream] = tr.stages["b"].output
    assert outs[False] == outs[True] == b"in!!"
