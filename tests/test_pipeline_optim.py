"""Data pipeline (SDP loader) + optimizer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import TokenDataset, TruffleDataLoader
from repro.optim import adamw
from repro.runtime.clock import Clock
from repro.storage.base import StorageService
from repro.runtime.netsim import GBPS


def _fast_storage():
    return StorageService("s3", put_bandwidth=100 * GBPS,
                          get_bandwidth=100 * GBPS, latency=0.0001,
                          clock=Clock(0.01))


def test_dataset_deterministic():
    ds = TokenDataset(vocab_size=100, seq_len=16, batch_size=2, seed=3)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    full = ds.batch(5)
    assert full["tokens"].shape == (2, 16)
    b6 = ds.batch(6)
    assert not np.array_equal(b1["tokens"], b6["tokens"])


def test_loader_prefetch_and_resume():
    ds = TokenDataset(50, 8, 2)
    loader = TruffleDataLoader(ds, _fast_storage(), prefetch_depth=2)
    b0 = loader.get(0)
    np.testing.assert_array_equal(b0["tokens"], ds.batch(0)["tokens"])
    # resume from an arbitrary step (checkpoint restart path)
    b7 = loader.get(7)
    np.testing.assert_array_equal(b7["tokens"], ds.batch(7)["tokens"])
    loader.stop()


def test_loader_serialize_roundtrip():
    ds = TokenDataset(50, 8, 2)
    data = ds.serialize(3)
    out = TokenDataset.deserialize(data)
    np.testing.assert_array_equal(out["tokens"], ds.batch(3)["tokens"])


# ------------------------------------------------------------------- adamw
def test_adamw_optimizes_quadratic():
    cfg = adamw.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
    params = {"x": jnp.asarray([3.0, -2.0])}
    opt = adamw.init_state(params)
    for _ in range(60):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, opt, m = adamw.apply_updates(cfg, params, grads, opt)
    assert float(jnp.sum(params["x"] ** 2)) < 0.1
    assert int(opt["step"]) == 60


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in (1, 5, 10, 55, 100)]
    assert lrs[0] < lrs[1] < lrs[2]              # warmup ramps
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[2] > lrs[3] > lrs[4]              # cosine decays
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)  # floor at 10%
