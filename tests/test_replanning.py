"""Mid-flight re-planning + telemetry-driven speculation budgets.

Covers, bottom-up:
  * the Eq. 4/5 drift terms (``remaining_time``/``drift``/``should_replan``)
    and ``ReplanPolicy`` validation,
  * LinkTelemetry's EWMA variance (the ``speculation="auto"`` signal) and
    ``LinkEstimate.variability``,
  * planner resolution of ``speculation="auto"`` (steady links never pay a
    backup; flappy links re-dispatch earlier) and the compile-time
    ``StagePlan.speculation_budget_s``,
  * ``Planner.predict_remaining`` / ``recompile_remaining`` (subgraph-only:
    dispatched stages keep their StagePlan verbatim),
  * the ``ReplanController`` rate limits (``max_replans``/``min_interval``)
    against scripted drift sequences,
  * runner end-to-end under ``tests/harness.py`` fault timelines: a
    degraded WAN hop flips the remaining edges mid-run, in-flight stages
    keep their plan, ``plan.replanned`` events and per-record
    ``replan_count`` record the trail, ``predicted_s`` is stamped from the
    plan in force at dispatch, and auto-speculation fires on the
    high-variance link only,
  * properties (hypothesis, or the deterministic fallback shim): a replan
    never makes the predicted remaining time worse; frozen telemetry never
    drifts; flapping links cannot exceed the replan rate limits.
"""
import dataclasses
import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

from harness import FaultTimeline, LinkFaults
from repro.core import model as tm
from repro.runtime.clock import Clock
from repro.runtime.cluster import Cluster
from repro.runtime.events import EventBus
from repro.runtime.function import FunctionSpec
from repro.runtime.netsim import GBPS, LinkEstimate, LinkTelemetry
from repro.runtime.planner import (AdaptivePlanner, EdgeProfile, Planner,
                                   SPECULATION_CV_TRIGGER,
                                   SPECULATION_MAX_FACTOR,
                                   SPECULATION_MIN_FACTOR)
from repro.runtime.policy import DataPolicy, ReplanPolicy, WorkflowBuilder
from repro.runtime.workflow import ReplanController, WorkflowRunner

MB = 1 << 20
AUTO = DataPolicy(strategy="auto")


def _spec(name, *, provision_s=0.3, startup_s=0.05, exec_s=0.05,
          affinity=None, handler=None, streaming=False):
    return FunctionSpec(name, handler or (lambda d, inv: d),
                        provision_s=provision_s, startup_s=startup_s,
                        exec_s=exec_s, affinity=affinity,
                        streaming=streaming)


def _chain(tag, names=("a", "b", "c"), *, default=AUTO, specs=None,
           payload=None):
    """Linear workflow over ``names``; root emits ``payload`` when given."""
    b = WorkflowBuilder(f"rp-{tag}", default_policy=default)
    prev = None
    for i, n in enumerate(names):
        spec = (specs or {}).get(n)
        if spec is None:
            handler = None
            if i == 0 and payload is not None:
                handler = lambda d, inv, _p=payload: _p
            spec = _spec(f"rp-{tag}-{n}", handler=handler)
        sb = b.stage(n, spec)
        if prev is not None:
            sb.after(prev)
        prev = n
    return b.build()


def _seeded_planner(bw=0.2 * GBPS, rtt=0.02, link=("s", "d")):
    tel = LinkTelemetry()
    tel.seed(link_key=link, bandwidth=bw, rtt=rtt)
    return Planner(telemetry=tel), tel


# ===================================================== drift terms (model)
def test_remaining_time_sums_and_skips_unprofiled():
    assert tm.remaining_time([1.0, None, 2.5]) == pytest.approx(3.5)
    assert tm.remaining_time([]) is None
    assert tm.remaining_time([None, None]) is None


def test_drift_is_symmetric():
    """Degradation (fresh > frozen) and recovery (fresh < frozen) drift by
    the same ratio — both strand the plan on a now-wrong policy."""
    assert tm.drift(2.0, 1.0) == pytest.approx(2.0)
    assert tm.drift(1.0, 2.0) == pytest.approx(2.0)
    assert tm.drift(1.0, 1.0) == pytest.approx(1.0)


def test_drift_without_evidence_is_one():
    """Missing or degenerate predictions are 'no signal', never drift."""
    for fresh, frozen in ((None, 1.0), (1.0, None), (0.0, 1.0), (1.0, 0.0),
                          (None, None)):
        assert tm.drift(fresh, frozen) == 1.0
        assert not tm.should_replan(fresh, frozen, 1.01)


def test_should_replan_thresholds_inclusive():
    assert tm.should_replan(1.3, 1.0, 1.3)          # at the threshold
    assert not tm.should_replan(1.29, 1.0, 1.3)
    assert tm.should_replan(1.0, 1.3, 1.3)          # recovery direction


# ==================================================== ReplanPolicy surface
def test_replan_policy_validation():
    with pytest.raises(ValueError, match="drift_ratio"):
        ReplanPolicy(drift_ratio=1.0)
    with pytest.raises(ValueError, match="drift_ratio"):
        ReplanPolicy(drift_ratio=0.5)
    with pytest.raises(ValueError, match="min_interval"):
        ReplanPolicy(min_interval=-1.0)
    with pytest.raises(ValueError, match="max_replans"):
        ReplanPolicy(max_replans=-1)
    with pytest.raises(ValueError, match="max_replans"):
        ReplanPolicy(max_replans=1.5)


def test_replan_policy_defaults_are_sane():
    pol = ReplanPolicy()
    assert pol.drift_ratio > 1.0
    assert pol.min_interval == 0.0
    assert pol.max_replans >= 1


def test_speculation_auto_policy_validation():
    assert DataPolicy(speculation="auto").speculation == "auto"
    with pytest.raises(ValueError, match="speculation"):
        DataPolicy(speculation="bogus")
    with pytest.raises(ValueError, match="speculation"):
        DataPolicy(speculation=-1.0)


# ============================================== telemetry variance tracking
def test_variance_tracks_spread_then_decays():
    tel = LinkTelemetry(alpha=0.25)
    key = ("a", "b")
    # alternating RTTs build variance…
    for i in range(40):
        tel.observe_transfer(key, None, nbytes=1000, seconds=1e-5,
                             rtt=0.01 if i % 2 else 0.05)
    est = tel.link("a", "b")
    assert est.rtt_var > 0
    spread_cv = est.variability
    assert spread_cv > SPECULATION_CV_TRIGGER
    # …and a steady link decays it back toward zero
    for _ in range(80):
        tel.observe_transfer(key, None, nbytes=1000, seconds=1e-5, rtt=0.03)
    est = tel.link("a", "b")
    assert est.variability < spread_cv / 10


def test_bandwidth_variance_tracked_independently():
    tel = LinkTelemetry(alpha=0.25)
    key = ("a", "b")
    for i in range(40):                      # same rtt, flapping bandwidth
        tel.observe_transfer(key, None, nbytes=1000,
                             seconds=1e-3 if i % 2 else 1e-2, rtt=0.01)
    est = tel.link("a", "b")
    assert est.bandwidth_var > 0
    assert est.rtt_var == pytest.approx(0.0, abs=1e-12)
    assert est.variability > SPECULATION_CV_TRIGGER


def test_seed_and_reseed_reset_variance():
    tel = LinkTelemetry()
    key = ("a", "b")
    for i in range(20):
        tel.observe_transfer(key, ("edge", "edge"), nbytes=1000,
                             seconds=1e-3 if i % 2 else 1e-2, rtt=0.01)
    assert tel.link("a", "b").bandwidth_var > 0
    tel.seed(link_key=key, bandwidth=1e8, rtt=0.01)
    est = tel.link("a", "b")
    assert est.samples == 0 and est.bandwidth_var == 0 and est.rtt_var == 0
    assert tel.link(None, None, tiers=("edge", "edge")).bandwidth_var > 0
    tel.reseed({("edge", "edge"): (2e8, 0.02)})
    tier = tel.link(None, None, tiers=("edge", "edge"))
    assert tier.bandwidth == 2e8 and tier.samples == 0
    assert tier.bandwidth_var == 0 and tier.rtt_var == 0


def test_linkestimate_variability_is_max_cv():
    est = LinkEstimate(bandwidth=100.0, rtt=0.01, samples=5,
                       bandwidth_var=25.0, rtt_var=0.0)
    assert est.variability == pytest.approx(0.05)       # 5/100
    est = LinkEstimate(bandwidth=100.0, rtt=0.01, samples=5,
                       bandwidth_var=25.0, rtt_var=1e-4)
    assert est.variability == pytest.approx(1.0)        # 0.01/0.01 wins
    assert LinkEstimate(bandwidth=0.0, rtt=0.0).variability == 0.0


# ======================================= speculation="auto" resolution
def _est(cv, samples=10):
    """LinkEstimate with exactly ``cv`` bandwidth variability."""
    return LinkEstimate(bandwidth=100.0, rtt=0.0, samples=samples,
                        bandwidth_var=(cv * 100.0) ** 2)


def test_auto_speculation_steady_and_blind_links_resolve_zero():
    p = Planner()
    assert p._auto_speculation(None) == 0.0
    assert p._auto_speculation(_est(0.0)) == 0.0
    assert p._auto_speculation(_est(SPECULATION_CV_TRIGGER * 0.9)) == 0.0
    # a seed-only estimate (samples=0) is a prior, not evidence of flap
    assert p._auto_speculation(_est(5.0, samples=0)) == 0.0


def test_auto_speculation_factor_bounds_and_monotonicity():
    p = Planner()
    cvs = [SPECULATION_CV_TRIGGER, 0.5, 1.0, 2.0, 5.0]
    factors = [p._auto_speculation(_est(cv)) for cv in cvs]
    for f in factors:
        assert SPECULATION_MIN_FACTOR <= f <= SPECULATION_MAX_FACTOR
    # flappier links re-dispatch earlier (factor never increases with cv)
    assert all(a >= b for a, b in zip(factors, factors[1:]))


def test_auto_speculation_resolved_per_edge_at_compile():
    planner, tel = _seeded_planner()
    # build real variance on the link (alternating effective bandwidth)
    for i in range(40):
        tel.observe_transfer(("s", "d"), None, nbytes=MB,
                             seconds=0.01 if i % 2 else 0.1, rtt=0.02)
    wf = _chain("specauto", ("a", "b"),
                default=DataPolicy(strategy="auto", speculation="auto"))
    plan = planner.compile(wf, profiles={
        ("a", "b"): EdgeProfile(size=4 * MB, src_node="s", dst_node="d")})
    pol = plan.stages["b"].edge_policy("a")
    assert isinstance(pol.speculation, float)
    assert SPECULATION_MIN_FACTOR <= pol.speculation <= SPECULATION_MAX_FACTOR
    # and the compile stamped a budget = factor × the stage's Eq. 4 time
    sp = plan.stages["b"]
    assert sp.speculation_budget_s == pytest.approx(
        pol.speculation * sp.predicted_s)


def test_auto_speculation_stable_link_no_budget():
    planner, tel = _seeded_planner()
    for _ in range(30):                              # steady traffic
        tel.observe_transfer(("s", "d"), None, nbytes=MB, seconds=0.05,
                             rtt=0.02)
    wf = _chain("specstable", ("a", "b"),
                default=DataPolicy(strategy="auto", speculation="auto"))
    plan = planner.compile(wf, profiles={
        ("a", "b"): EdgeProfile(size=4 * MB, src_node="s", dst_node="d")})
    assert plan.stages["b"].edge_policy("a").speculation == 0.0
    assert plan.stages["b"].speculation_budget_s is None


def test_fixed_speculation_budget_from_prediction():
    planner, _ = _seeded_planner()
    wf = _chain("specfix", ("a", "b"),
                default=DataPolicy(speculation=2.0))
    plan = planner.compile(wf, profiles={
        ("a", "b"): EdgeProfile(size=4 * MB, src_node="s", dst_node="d")})
    sp = plan.stages["b"]
    assert sp.predicted_s is not None
    assert sp.speculation_budget_s == pytest.approx(2.0 * sp.predicted_s)
    # unprofiled compile: speculation still declared, but no budget to arm
    bare = Planner().compile(_chain("specbare", ("a", "b"),
                                    default=DataPolicy(speculation=2.0)))
    assert bare.stages["b"].speculation_budget_s is None


# ========================================= planner re-planning primitives
def test_plan_carries_profiles_and_generation():
    planner, _ = _seeded_planner()
    profiles = {("a", "b"): EdgeProfile(size=MB, src_node="s", dst_node="d")}
    plan = planner.compile(_chain("gen", ("a", "b")), profiles=profiles)
    assert dict(plan.profiles) == profiles
    assert plan.generation == 0 and not plan.replanned
    with pytest.raises(TypeError):          # immutable, like plan.stages
        plan.profiles[("a", "b")] = None


def test_predict_remaining_follows_telemetry():
    planner, tel = _seeded_planner(bw=1e8, rtt=0.001)
    wf = _chain("drift", ("a", "b"))
    plan = planner.compile(wf, profiles={
        ("a", "b"): EdgeProfile(size=32 * MB, src_node="s", dst_node="d")})
    fresh, frozen = planner.predict_remaining(wf, plan, ["b"])
    assert fresh == pytest.approx(frozen)            # nothing moved yet
    for _ in range(30):                              # link collapses 100x
        tel.observe_transfer(("s", "d"), None, nbytes=MB, seconds=MB / 1e6)
    fresh, frozen = planner.predict_remaining(wf, plan, ["b"])
    assert fresh > frozen * 2
    assert tm.should_replan(fresh, frozen, 1.3)
    # stages with no comparable edge produce no signal
    assert planner.predict_remaining(wf, plan, ["a"]) is None


def test_recompile_remaining_keeps_dispatched_stageplans():
    planner, tel = _seeded_planner(bw=10 * GBPS, rtt=0.0002)
    wf = _chain("keep", ("a", "b", "c"))
    profiles = {
        ("a", "b"): EdgeProfile(size=32 * MB, src_node="s", dst_node="d",
                                compress_ratio=0.05),
        ("b", "c"): EdgeProfile(size=32 * MB, src_node="s", dst_node="d",
                                compress_ratio=0.05),
    }
    plan = planner.compile(wf, profiles=profiles)
    # 10 Gbit/s: codec-bound, auto says uncompressed
    assert plan.stages["c"].edge_policy("b").compression == "none"
    for _ in range(30):                              # degrade to ~10 MB/s
        tel.observe_transfer(("s", "d"), None, nbytes=MB, seconds=0.1)
    new = planner.recompile_remaining(wf, plan, dispatched={"a", "b"})
    # dispatched stages keep their StagePlan OBJECTS (not equal — same)
    assert new.stages["a"] is plan.stages["a"]
    assert new.stages["b"] is plan.stages["b"]
    # the remaining edge flipped to compression on the now-slow link
    assert new.stages["c"].edge_policy("b").compression == "lz4-like"
    assert new.generation == 1 and new.replanned
    assert new.order == plan.order and new.workflow == plan.workflow


def test_recompile_remaining_refreshes_predictions():
    planner, tel = _seeded_planner(bw=1e8, rtt=0.001)
    wf = _chain("refresh", ("a", "b"))
    plan = planner.compile(wf, profiles={
        ("a", "b"): EdgeProfile(size=32 * MB, src_node="s", dst_node="d")})
    before = plan.stages["b"].predicted_s
    for _ in range(30):
        tel.observe_transfer(("s", "d"), None, nbytes=MB, seconds=MB / 1e6)
    new = planner.recompile_remaining(wf, plan, dispatched={"a"})
    after = new.stages["b"].predicted_s
    assert after is not None and after > before
    # and the refreshed prediction matches a from-scratch compile now
    scratch = planner.compile(wf, profiles=dict(plan.profiles))
    assert after == pytest.approx(scratch.stages["b"].predicted_s)


def test_recompile_remaining_generation_accumulates():
    planner, _ = _seeded_planner()
    wf = _chain("gen2", ("a", "b"))
    plan = planner.compile(wf, profiles={
        ("a", "b"): EdgeProfile(size=MB, src_node="s", dst_node="d")})
    g1 = planner.recompile_remaining(wf, plan, dispatched=set())
    g2 = planner.recompile_remaining(wf, g1, dispatched={"a"})
    assert (plan.generation, g1.generation, g2.generation) == (0, 1, 2)


# ============================================== ReplanController contract
class _ScriptedPlanner:
    """predict_remaining returns scripted (fresh, frozen) pairs; recompile
    just bumps the generation — isolates the controller's rate limiting."""

    def __init__(self, preds):
        self.preds = list(preds)
        self.recompiles = 0

    def predict_remaining(self, wf, plan, remaining):
        return self.preds.pop(0) if self.preds else (1.0, 1.0)

    def recompile_remaining(self, wf, plan, dispatched):
        self.recompiles += 1
        return dataclasses.replace(plan, generation=plan.generation + 1)


def _tiny_plan():
    return Planner().compile(_chain("ctl", ("a", "b"), default=DataPolicy()))


def test_controller_quiet_under_frozen_telemetry():
    planner, _ = _seeded_planner()
    wf = _chain("ctl-frozen", ("a", "b"))
    plan = planner.compile(wf, profiles={
        ("a", "b"): EdgeProfile(size=8 * MB, src_node="s", dst_node="d")})
    ctl = ReplanController(planner, ReplanPolicy(drift_ratio=1.01), wf)
    for dispatched in (set(), {"a"}):
        assert ctl.consider(plan, dispatched, now=float(len(dispatched))) \
            is None
    assert ctl.count == 0 and ctl.events == []


def test_controller_max_replans_is_a_hard_cap():
    wf = _chain("ctl-cap", ("a", "b"))
    scripted = _ScriptedPlanner([(10.0, 1.0)] * 8)
    ctl = ReplanController(scripted, ReplanPolicy(drift_ratio=1.3,
                                                  max_replans=2), wf)
    plan = _tiny_plan()
    flips = 0
    for i in range(8):
        new = ctl.consider(plan, set(), now=float(i))
        if new is not None:
            plan, flips = new, flips + 1
    assert flips == 2 and ctl.count == 2 and scripted.recompiles == 2
    assert plan.generation == 2


def test_controller_min_interval_damps_flapping():
    wf = _chain("ctl-damp", ("a", "b"))
    scripted = _ScriptedPlanner([(10.0, 1.0)] * 10)
    ctl = ReplanController(scripted,
                           ReplanPolicy(drift_ratio=1.3, min_interval=5.0,
                                        max_replans=10), wf)
    plan = _tiny_plan()
    replan_times = []
    for t in range(10):                       # drift present every second
        if ctl.consider(plan, set(), now=float(t)) is not None:
            replan_times.append(t)
    assert replan_times == [0, 5]             # once per interval, not 10x
    # nothing remaining -> never considers, regardless of drift
    assert ctl.consider(plan, {"a", "b"}, now=100.0) is None


def test_controller_publishes_trail():
    bus = EventBus()
    wf = _chain("ctl-trail", ("a", "b"))
    scripted = _ScriptedPlanner([(2.0, 1.0)])
    ctl = ReplanController(scripted, ReplanPolicy(drift_ratio=1.5),
                           wf, bus=bus)
    new = ctl.consider(_tiny_plan(), {"a"}, now=1.0)
    assert new is not None and new.generation == 1
    assert len(ctl.events) == 1
    ev = ctl.events[0]
    assert ev["generation"] == 1 and ev["remaining"] == ["b"]
    assert ev["drift"] == pytest.approx(2.0)
    assert bus.history("plan.replanned") == [ev]


# ==================================================== runner end-to-end
def _e2e_cluster(scale=0.02):
    return Cluster(node_specs=[("cloud-0", "cloud"), ("cloud-1", "cloud")],
                   clock=Clock(scale))


def _e2e_chain(tag, size):
    payload = bytes(size)                        # compressible
    specs = {
        "s0": _spec(f"rp-{tag}-s0", affinity="cloud-0",
                    handler=lambda d, inv: payload),
        "s1": _spec(f"rp-{tag}-s1", affinity="cloud-0"),
        "s2": _spec(f"rp-{tag}-s2", affinity="cloud-1"),
    }
    wf = _chain(tag, ("s0", "s1", "s2"), specs=specs)
    profiles = {
        ("s1", "s2"): EdgeProfile(size=size, src_node="cloud-0",
                                  dst_node="cloud-1", compress_ratio=0.05),
    }
    return wf, profiles


def test_runner_replans_on_midrun_degradation():
    """The full loop: a fat link degrades after wave 1 (with ambient probe
    traffic converging telemetry), the next wave's check replans the
    remaining subgraph only, the trail is on the bus/trace/records, and
    the dispatched-before stage keeps generation 0."""
    cluster = _e2e_cluster()
    wf, profiles = _e2e_chain("e2e", 24 * MB)
    runner = WorkflowRunner(cluster, use_truffle=True, prewarm_roots=True,
                            replan=ReplanPolicy(drift_ratio=1.3,
                                                max_replans=2))
    plan = runner.compile(wf, profiles=profiles)
    assert plan.stages["s2"].edge_policy("s1").compression == "none"
    frozen_pred = plan.stages["s2"].predicted_s

    with FaultTimeline(cluster) as tl:
        # 0.001x: even the COMPRESSED transfer no longer hides under the
        # cold start, so the replanned prediction visibly differs from the
        # frozen one (at milder degradations both are β-bound and equal)
        tl.degrade_at(1, "cloud-0", "cloud-1", bandwidth_factor=0.001,
                      probes=25, probe_bytes=256 * 1024)
        tr = runner.run(wf, b"go", source_node="cloud-0", plan=plan)

    assert tr.plan_generation == 1
    assert len(tr.replans) == 1
    ev = tr.replans[0]
    assert ev["drift"] >= 1.3 and "s2" in ev["flips"]
    assert cluster.bus.history("plan.replanned") == [ev]
    # in-flight / already-dispatched stages keep the original plan;
    # stages dispatched after the flip carry the new generation
    assert tr.stages["s0"].record.replan_count == 0
    assert tr.stages["s2"].record.replan_count == 1
    # the degraded edge flipped to compression mid-run
    assert tr.stages["s2"].record.compress_ratio is not None
    # predicted_s comes from the plan IN FORCE at dispatch, not the stale
    # compile: the frozen prediction can't know about the degradation
    assert tr.stages["s2"].record.predicted_s != frozen_pred


def test_runner_predicted_stays_honest_across_replan(fast_clock):
    """The ≤10%-error contract survives a replan only because predicted_s
    is stamped from the post-replan plan (the frozen one is ~7x off)."""
    cluster = _e2e_cluster(scale=0.05)
    clock = cluster.clock
    size = 24 * MB
    wf, profiles = _e2e_chain("honest", size)
    runner = WorkflowRunner(cluster, use_truffle=True, prewarm_roots=True,
                            replan=ReplanPolicy(drift_ratio=1.2))
    plan = runner.compile(wf, profiles=profiles)
    with FaultTimeline(cluster) as tl:
        tl.degrade_at(1, "cloud-0", "cloud-1", bandwidth_factor=0.001,
                      probes=25, probe_bytes=256 * 1024)
        tr = runner.run(wf, b"go", source_node="cloud-0", plan=plan)
    rec = tr.stages["s2"].record
    assert rec.replan_count >= 1 and rec.cold
    measured = clock.elapsed_sim(rec.total)
    err = abs(rec.predicted_s - measured) / measured
    assert err <= 0.15, (rec.predicted_s, measured)
    frozen_err = abs(plan.stages["s2"].predicted_s - measured) / measured
    assert frozen_err > err        # the stale stamp would have been a lie


def test_runner_quiet_without_drift():
    cluster = _e2e_cluster()
    wf, profiles = _e2e_chain("quiet", 8 * MB)
    runner = WorkflowRunner(cluster, use_truffle=True, prewarm_roots=True,
                            replan=ReplanPolicy(drift_ratio=1.2))
    plan = runner.compile(wf, profiles=profiles)
    tr = runner.run(wf, b"go", source_node="cloud-0", plan=plan)
    assert tr.plan_generation == 0 and tr.replans == []
    assert cluster.bus.history("plan.replanned") == []
    assert all(sr.record.replan_count == 0 for sr in tr.stages.values())


def test_runner_flap_respects_max_replans():
    cluster = _e2e_cluster()
    names = tuple(f"s{i}" for i in range(6))
    size = 8 * MB
    specs = {n: _spec(f"rp-flap-{n}",
                      affinity="cloud-0" if i % 2 == 0 else "cloud-1",
                      handler=(lambda d, inv, _p=bytes(size): _p))
             for i, n in enumerate(names)}
    wf = _chain("flap", names, specs=specs)
    profiles = {
        (a, b): EdgeProfile(size=size,
                            src_node=specs[a].affinity,
                            dst_node=specs[b].affinity, compress_ratio=0.05)
        for a, b in zip(names, names[1:])}
    runner = WorkflowRunner(cluster, use_truffle=True, prewarm_roots=True,
                            replan=ReplanPolicy(drift_ratio=1.2,
                                                max_replans=1))
    plan = runner.compile(wf, profiles=profiles)
    with FaultTimeline(cluster) as tl:
        tl.flap("cloud-0", "cloud-1", waves=(1, 2, 3, 4),
                bandwidth_factor=0.005, probes=20, probe_bytes=MB)
        tr = runner.run(wf, b"go", source_node="cloud-0", plan=plan)
    assert tr.plan_generation <= 1
    assert len(tr.replans) == 1               # flapped 2x, replanned once
    assert len(tr.stages) == len(names)       # the run still completed


def test_runner_stage_done_wave_events():
    cluster = _e2e_cluster()
    wf, profiles = _e2e_chain("waves", 1 * MB)
    runner = WorkflowRunner(cluster, use_truffle=True, prewarm_roots=True)
    tr = runner.run(wf, b"go", source_node="cloud-0",
                    plan=runner.compile(wf, profiles=profiles))
    evs = cluster.bus.history("workflow.stage_done")
    assert [e["wave"] for e in evs] == [1, 2, 3]
    assert [e["stage"] for e in evs] == ["s0", "s1", "s2"]
    assert all(e["workflow"] == wf.name and e["node"] for e in evs)
    assert len(tr.stages) == 3


def test_speculation_auto_fires_on_variable_link_only(fast_clock):
    """End-to-end: variance built on edge-0->edge-1 resolves a real backup
    budget for the stage behind it; a steady link resolves 0 and never
    speculates. When the flappy link then collapses mid-dispatch, the
    backup fires, is counted by the scheduler, and wins off-node."""
    cluster = Cluster(clock=fast_clock)
    faults = LinkFaults(cluster)
    # history: the edge-0->edge-1 link flaps (ambient traffic observes it)
    src, dst = cluster.node("edge-0"), cluster.node("edge-1")
    for i in range(24):
        if i % 2:
            faults.degrade("edge-0", "edge-1", bandwidth_factor=0.05)
        else:
            faults.restore()
        cluster.transfer(src, dst, bytes(MB))
    faults.restore()
    assert cluster.telemetry.link("edge-0", "edge-1").variability \
        > SPECULATION_CV_TRIGGER

    size = 4 * MB
    specs = {
        "a": _spec("rp-sa-a", affinity="edge-0",
                   handler=lambda d, inv: bytes(size),
                   provision_s=0.1, exec_s=0.01),
        "b": _spec("rp-sa-b", provision_s=0.1, exec_s=0.01),   # unpinned
    }
    wf = _chain("sa", ("a", "b"), specs=specs,
                default=DataPolicy(strategy="auto", speculation="auto"))
    profiles = {("a", "b"): EdgeProfile(size=size, src_node="edge-0",
                                        dst_node="edge-1")}
    planner = AdaptivePlanner(cluster)
    plan = planner.compile(wf, profiles=profiles)
    factor = plan.stages["b"].edge_policy("a").speculation
    assert SPECULATION_MIN_FACTOR <= factor <= SPECULATION_MAX_FACTOR
    assert plan.stages["b"].speculation_budget_s is not None

    # steer the first attempt onto edge-1, then kill its ingress link
    with cluster.scheduler._lock:
        cluster.scheduler._load["edge-0"] = 5
        cluster.scheduler._load["edge-2"] = 5
    runner = WorkflowRunner(cluster, use_truffle=True, plan=plan)
    with faults:
        faults.degrade("edge-0", "edge-1", bandwidth_factor=1e-5)
        tr = runner.run(wf, b"go", source_node="edge-0")
    sr = tr.stages["b"]
    assert sr.speculated and sr.record.node != "edge-1"
    assert sr.record.speculation_budget_s == pytest.approx(
        plan.stages["b"].speculation_budget_s)
    assert cluster.scheduler.stats["speculative_placements"] >= 1
    placed = cluster.bus.history("scheduling.placed")
    assert any(e.get("speculative") for e in placed)


def test_speculation_stable_link_never_pays_backup(fast_clock):
    """Control arm: same topology, steady link -> factor 0, no budget, no
    speculative placement even though speculation='auto' was requested."""
    cluster = Cluster(clock=fast_clock)
    src, dst = cluster.node("edge-0"), cluster.node("edge-1")
    for _ in range(24):
        cluster.transfer(src, dst, bytes(MB))    # steady traffic
    size = 4 * MB
    specs = {
        "a": _spec("rp-ss-a", affinity="edge-0",
                   handler=lambda d, inv: bytes(size),
                   provision_s=0.1, exec_s=0.01),
        "b": _spec("rp-ss-b", provision_s=0.1, exec_s=0.01),
    }
    wf = _chain("ss", ("a", "b"), specs=specs,
                default=DataPolicy(strategy="auto", speculation="auto"))
    plan = AdaptivePlanner(cluster).compile(wf, profiles={
        ("a", "b"): EdgeProfile(size=size, src_node="edge-0",
                                dst_node="edge-1")})
    assert plan.stages["b"].edge_policy("a").speculation == 0.0
    assert plan.stages["b"].speculation_budget_s is None
    runner = WorkflowRunner(cluster, use_truffle=True, plan=plan)
    tr = runner.run(wf, b"go", source_node="edge-0")
    assert not tr.stages["b"].speculated
    assert tr.stages["b"].record.speculation_budget_s is None
    assert cluster.scheduler.stats["speculative_placements"] == 0


# ============================================================= properties
N_EDGES = 3      # chain a->b->c->d


def _prop_setup(sizes_mb, bws, rtts, ratios):
    tel = LinkTelemetry()
    names = ("a", "b", "c", "d")
    profiles = {}
    for k, (s, d) in enumerate(zip(names, names[1:])):
        tel.seed(link_key=(f"n{k}", f"n{k+1}"),
                 bandwidth=bws[k], rtt=rtts[k])
        profiles[(s, d)] = EdgeProfile(size=int(sizes_mb[k] * MB),
                                       src_node=f"n{k}", dst_node=f"n{k+1}",
                                       compress_ratio=ratios[k])
    planner = Planner(telemetry=tel)
    wf = _chain("prop", names)
    return planner, tel, wf, profiles


@settings(max_examples=30, deadline=None)
@given(
    st.tuples(*[st.floats(min_value=0.5, max_value=128.0)] * N_EDGES),
    st.tuples(*[st.floats(min_value=1e6, max_value=2e9)] * N_EDGES),
    st.tuples(*[st.floats(min_value=0.0, max_value=0.05)] * N_EDGES),
    st.tuples(*[st.floats(min_value=0.03, max_value=1.0)] * N_EDGES),
    st.tuples(*[st.floats(min_value=0.01, max_value=100.0)] * N_EDGES),
)
def test_property_replan_never_worse_than_frozen_plan(sizes_mb, bws, rtts,
                                                      ratios, shifts):
    """Property: after ANY telemetry shift, the recompiled remaining
    subgraph's predicted time (under current telemetry) never exceeds the
    frozen plan's — re-running the per-edge argmin can only help."""
    planner, tel, wf, profiles = _prop_setup(sizes_mb, bws, rtts, ratios)
    plan = planner.compile(wf, profiles=profiles)
    for k, shift in enumerate(shifts):               # links drift anywhere
        tel.seed(link_key=(f"n{k}", f"n{k+1}"),
                 bandwidth=max(bws[k] * shift, 1e3), rtt=rtts[k])
    remaining = ["b", "c", "d"]
    frozen_now = planner.predict_remaining(wf, plan, remaining)
    new = planner.recompile_remaining(wf, plan, dispatched={"a"})
    fresh_now = planner.predict_remaining(wf, new, remaining)
    assert frozen_now is not None and fresh_now is not None
    # each pair is (under-current-telemetry, at-own-compile-time); compare
    # both plans under CURRENT telemetry
    assert fresh_now[0] <= frozen_now[0] + 1e-9


@settings(max_examples=30, deadline=None)
@given(
    st.tuples(*[st.floats(min_value=0.5, max_value=128.0)] * N_EDGES),
    st.tuples(*[st.floats(min_value=1e6, max_value=2e9)] * N_EDGES),
    st.tuples(*[st.floats(min_value=0.0, max_value=0.05)] * N_EDGES),
    st.tuples(*[st.floats(min_value=0.03, max_value=1.0)] * N_EDGES),
)
def test_property_no_drift_under_frozen_telemetry(sizes_mb, bws, rtts,
                                                  ratios):
    """Property: with telemetry untouched since compile, the re-predicted
    remaining time is EXACTLY the frozen prediction — drift 1.0, so no
    ReplanPolicy (whose drift_ratio > 1 by construction) can fire."""
    planner, _, wf, profiles = _prop_setup(sizes_mb, bws, rtts, ratios)
    plan = planner.compile(wf, profiles=profiles)
    for remaining in (["b", "c", "d"], ["c", "d"], ["d"]):
        fresh, frozen = planner.predict_remaining(wf, plan, remaining)
        assert fresh == frozen
        assert tm.drift(fresh, frozen) == 1.0
        assert not tm.should_replan(fresh, frozen, 1.0 + 1e-9)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.floats(min_value=1.0, max_value=5.0), min_size=1,
             max_size=12),
    st.integers(min_value=0, max_value=3),
    st.floats(min_value=0.0, max_value=10.0),
    st.floats(min_value=1.05, max_value=2.0),
)
def test_property_flap_respects_rate_limits(drifts, max_replans,
                                            min_interval, drift_ratio):
    """Property: under ANY drift sequence (flapping included), the
    controller never exceeds max_replans, never replans more than once per
    min_interval, and never replans on sub-threshold drift."""
    wf = _chain("prop-limits", ("a", "b"))
    scripted = _ScriptedPlanner([(d, 1.0) for d in drifts])
    ctl = ReplanController(
        scripted, ReplanPolicy(drift_ratio=drift_ratio,
                               min_interval=min_interval,
                               max_replans=max_replans), wf)
    plan = _tiny_plan()
    times = []
    for i, d in enumerate(drifts):
        new = ctl.consider(plan, set(), now=float(i))
        if new is not None:
            plan = new
            times.append(float(i))
            assert d >= drift_ratio          # sub-threshold never replans
    assert len(times) <= max_replans
    assert all(b - a >= min_interval for a, b in zip(times, times[1:]))
    assert plan.generation == len(times) == ctl.count
