"""Scan-vs-unrolled equivalence: the dry-run cost probes assume the unrolled
(scan_layers=False) program computes the same function as the production
lax.scan stack — verify bit-level (fp32) agreement per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import api, lm


@pytest.mark.parametrize("arch", ["qwen3-4b", "olmoe-1b-7b", "jamba-v0.1-52b",
                                  "xlstm-125m"])
def test_unrolled_matches_scan(arch):
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    params = api.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)

    h_scan, _, _ = lm.forward(cfg, params, toks, mode="train")
    h_unrolled, _, _ = lm.forward(cfg.replace(scan_layers=False,
                                              unroll_scans=True),
                                  params, toks, mode="train")
    np.testing.assert_allclose(np.asarray(h_scan, np.float32),
                               np.asarray(h_unrolled, np.float32),
                               atol=1e-4, rtol=1e-4)  # unroll reorders reductions


def test_loss_matches_between_modes():
    cfg = get_config("qwen3-4b", smoke=True).replace(dtype="float32")
    params = api.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = api.loss_fn(cfg, params, batch)
    l2, _ = api.loss_fn(cfg.replace(scan_layers=False, unroll_scans=True),
                        params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
