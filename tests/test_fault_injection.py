"""Fault injection (tests/harness.py): degraded and wedged fabric links.

Regression surface: a stalled chunk stream surfaces TransferStallError
(not a silent daemon-thread leak), speculation steers the backup attempt
off the node behind the degraded link, telemetry EWMAs converge onto the
degraded link values, and an adaptive re-plan against the converged
telemetry flips the edge policy the degradation invalidated."""
import pytest

from harness import LinkFaults
from repro.core.errors import TransferStallError
from repro.core.model import PhaseEstimate
from repro.runtime.clock import Clock
from repro.runtime.cluster import Cluster
from repro.runtime.function import FunctionSpec
from repro.runtime.netsim import GBPS
from repro.runtime.planner import AdaptivePlanner, EdgeProfile
from repro.runtime.policy import DataPolicy, WorkflowBuilder
from repro.runtime.workflow import Stage, Workflow, WorkflowRunner

MB = 1 << 20


def test_stalled_chunk_surfaces_transfer_stall_error(fast_clock):
    """A link that wedges mid-stream: the function consumes what arrived,
    but the data-path thread outlives its join budget — recorded on the
    lifecycle record and raised, never silently leaked."""
    cluster = Cluster(clock=fast_clock)

    def first_chunk_only(_d, inv):
        next(iter(inv.get_input_stream(timeout=30)))
        return b"partial"

    cluster.platform.register(
        FunctionSpec("stall-strm", first_chunk_only, provision_s=0.2,
                     startup_s=0.05, exec_s=0.01, streaming=True,
                     affinity="edge-1"))
    truffle = cluster.node("edge-0").truffle
    truffle.csp.join_timeout_s = 0.3
    with LinkFaults(cluster) as faults:
        faults.stall_streams("edge-0", "edge-1", after_chunks=1)
        with pytest.raises(TransferStallError) as exc:
            truffle.pass_data("stall-strm", bytes(4 * MB),
                              policy=DataPolicy(stream=True))
    assert exc.value.record.transfer_stalled


def test_speculation_steers_off_degraded_node(fast_clock):
    """The first attempt lands behind a near-dead link and straggles; the
    speculative backup carries an avoid hint for that node and finishes
    elsewhere."""
    cluster = Cluster(clock=fast_clock)
    # edge-0 is the source (loaded out of contention), so the first attempt
    # places on edge-1 — whose ingress link we then kill
    with cluster.scheduler._lock:
        cluster.scheduler._load["edge-0"] = 5
    spec = FunctionSpec("spec-fn", lambda d, inv: d[:4], provision_s=0.1,
                        startup_s=0.05, exec_s=0.01)
    wf = Workflow("w", {"s": Stage(spec, policy=DataPolicy(speculation=2.0))})
    est = {"s": PhaseEstimate(alpha=0.15, nu=0.1, eta=0.05, delta=0.05,
                              gamma=0.01)}
    runner = WorkflowRunner(cluster, use_truffle=True, estimates=est)
    with LinkFaults(cluster) as faults:
        faults.degrade("edge-0", "edge-1", bandwidth_factor=1e-5)
        tr = runner.run(wf, bytes(4 * MB), source_node="edge-0")
    sr = tr.stages["s"]
    assert sr.speculated                      # the backup won
    assert sr.record.node != "edge-1"         # steered off the straggler
    assert sr.output == bytes(4)


def test_telemetry_converges_to_degraded_link(fast_clock):
    """Passive measurement tracks the fault: after a bandwidth drop + RTT
    spike, the EWMA estimates converge onto the degraded values."""
    cluster = Cluster(clock=Clock(0.0))
    src, dst = cluster.node("edge-0"), cluster.node("edge-1")
    bw0, _ = cluster.network.tier_links[("edge", "edge")]
    with LinkFaults(cluster) as faults:
        faults.degrade("edge-0", "edge-1", bandwidth_factor=0.1,
                       extra_rtt=0.05)
        for _ in range(30):
            cluster.transfer(src, dst, bytes(MB))
        est = cluster.telemetry.link("edge-0", "edge-1")
        assert est.samples == 30
        assert est.bandwidth == pytest.approx(0.1 * bw0, rel=0.05)
        assert est.rtt == pytest.approx(0.0505, rel=0.1)
    # restore + fresh traffic converges back up
    for _ in range(40):
        cluster.transfer(src, dst, bytes(MB))
    est = cluster.telemetry.link("edge-0", "edge-1")
    assert est.bandwidth == pytest.approx(bw0, rel=0.05)


def test_replan_after_degradation_flips_edge_policy():
    """Re-planning between stages is just compiling again: a fat link that
    made compression codec-bound (auto says none) degrades into a
    bandwidth-bound one, telemetry converges, and the next compile flips
    the same edge to stream+lz4."""
    cluster = Cluster(node_specs=[("cloud-0", "cloud"), ("cloud-1", "cloud")],
                      clock=Clock(0.0))
    b = WorkflowBuilder("replan",
                        default_policy=DataPolicy(strategy="auto"))
    b.stage("a", FunctionSpec("rp-a", lambda d, inv: d, provision_s=0.2,
                              startup_s=0.05, exec_s=0.05))
    b.stage("b", FunctionSpec("rp-b", lambda d, inv: d, provision_s=0.2,
                              startup_s=0.05, exec_s=0.05)).after("a")
    wf = b.build()
    profiles = {("a", "b"): EdgeProfile(size=32 * MB, src_node="cloud-0",
                                        dst_node="cloud-1",
                                        compress_ratio=0.05)}
    planner = AdaptivePlanner(cluster)

    plan = planner.compile(wf, profiles=profiles)
    # 10 Gbit/s link: the codec is the bottleneck — ship uncompressed
    assert plan.stages["b"].edge_policy("a").compression == "none"

    src, dst = cluster.node("cloud-0"), cluster.node("cloud-1")
    faults = LinkFaults(cluster)
    faults.degrade("cloud-0", "cloud-1", bandwidth_factor=1e-3)
    for _ in range(30):
        cluster.transfer(src, dst, bytes(MB))
    est = cluster.telemetry.link("cloud-0", "cloud-1")
    assert est.bandwidth == pytest.approx(1e-3 * 10 * GBPS, rel=0.05)

    replanned = planner.compile(wf, profiles=profiles)
    pol = replanned.stages["b"].edge_policy("a")
    # now bandwidth-bound: compression (and pipelining) win the argmin
    assert pol.compression == "lz4-like"
    assert plan.stages["b"].predicted_s is not None
    assert replanned.stages["b"].predicted_s is not None
    faults.restore()
