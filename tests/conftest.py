import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS in a
# separate process); fail fast if something polluted the env.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Opt-in runtime lock-discipline checker (TRUFFLE_LOCKCHECK=1): must install
# BEFORE any repro import so every runtime lock is created instrumented.
_LOCKCHECK = os.environ.get("TRUFFLE_LOCKCHECK") == "1"
if _LOCKCHECK:
    from repro.analysis import lockcheck as _lockcheck

    _lockcheck.install()

import pytest  # noqa: E402

try:                                     # nightly soak: --hypothesis-profile=ci
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", max_examples=200, deadline=None)
except ImportError:                      # fallback shim has no profiles
    pass

from repro.runtime.clock import Clock  # noqa: E402


def pytest_sessionfinish(session, exitstatus):
    """With TRUFFLE_LOCKCHECK=1: fail the run on any lock-order inversion."""
    if not _LOCKCHECK:
        return
    invs = _lockcheck.inversions()
    rep = _lockcheck.report()
    print("\n[lockcheck] %d order edges, %d inversions, %d long holds"
          % (rep["order_edges"], len(invs), len(rep["long_holds"])))
    for h in rep["long_holds"]:
        print("[lockcheck] long hold: %(site)s held %(held_s)ss (%(thread)s)"
              % h)
    if invs:
        print(_lockcheck.format_inversions(invs))
        session.exitstatus = 1


@pytest.fixture()
def fast_clock():
    """Simulated delays shrunk 100x — orderings preserved, tests fast."""
    return Clock(scale=0.01)


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)
