import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS in a
# separate process); fail fast if something polluted the env.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402

try:                                     # nightly soak: --hypothesis-profile=ci
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", max_examples=200, deadline=None)
except ImportError:                      # fallback shim has no profiles
    pass

from repro.runtime.clock import Clock  # noqa: E402


@pytest.fixture()
def fast_clock():
    """Simulated delays shrunk 100x — orderings preserved, tests fast."""
    return Clock(scale=0.01)


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)
